"""GPipe-style pipeline parallelism (paper R2) on 4 stages.

Shows: forward pipeline via collective_permute, automatic backward pipeline
through autodiff, and the bubble fraction vs microbatch count trade-off.

    PYTHONPATH=src python examples/pipeline_parallel.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import pipeline  # noqa: E402


def main():
    S, d = 4, 256
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((S,), ("stage",))
    Ws = jax.random.normal(jax.random.PRNGKey(0), (S, d, d)) / d ** 0.5
    Ws = jax.device_put(Ws, NamedSharding(mesh, P("stage")))

    def stage_fn(W, x):
        return jnp.tanh(x @ W)

    fn = jax.jit(pipeline.make_pipeline_fn(stage_fn, mesh))

    print(f"{'micro':>6s} {'bubble':>8s} {'ms/call':>9s}")
    for M in (1, 2, 4, 8, 16):
        x = jax.random.normal(jax.random.PRNGKey(1), (M, 32, d))
        fn(Ws, x)[0].block_until_ready()  # compile
        t0 = time.time()
        for _ in range(5):
            fn(Ws, x)[0].block_until_ready()
        dt = (time.time() - t0) / 5 / M  # per microbatch
        print(f"{M:6d} {pipeline.bubble_fraction(S, M):8.2%} {dt * 1e3:9.2f}")

    # training through the pipeline: backward schedule comes from autodiff
    def loss(Ws, x, y):
        return jnp.mean((fn(Ws, x) - y) ** 2)

    x = jax.random.normal(jax.random.PRNGKey(2), (8, 32, d))
    y = jnp.roll(x, 1, axis=-1)
    lg = jax.jit(jax.value_and_grad(loss))
    for it in range(10):
        l, g = lg(Ws, x, y)
        Ws = jax.tree.map(lambda w, gg: w - 0.1 * gg, Ws, g)
        if it % 3 == 0:
            print(f"pp-train step {it}: loss {float(l):.5f}")


if __name__ == "__main__":
    main()
