"""The futurized execution tree, end to end on one CPU device.

Walks the frontend and the ``core.futures`` API underneath it:

  0. ``@futurize``: plain Python traced into the tree - calls become nodes,
     control flow stays in Python, untraced calls run inline
  1. a small dependency DAG (``defer`` discovers edges by pytree traversal)
  2. combinators: ``when_all`` / ``when_any`` / ``tree_join``
  3. error propagation along edges (a poisoned branch, an intact one)
  4. a miniature overlapped train loop: prefetch nodes + in-flight steps +
     a checkpoint node that depends on step retirement - then the runtime
     stats that show what actually overlapped.

    PYTHONPATH=src python examples/futurized_overlap.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core.futures import FuturizedGraph, Pipeline
from repro.data.pipeline import LMStream, Prefetcher
from repro.frontend import futurize, tracing


@futurize
def load(i):
    return i * 2


@futurize
def grad(x):
    return x + 1


@futurize
def apply_update(*grads):
    return sum(grads)


def main():
    # 0. the decorator view: a plain-Python "step loop" becomes a futurized
    #    tree; outside tracing() the same calls run inline.
    assert load(3) == 6                     # untraced fallback: inline
    with tracing(max_workers=2, name="traced-demo") as tr:
        total = apply_update(*[grad(load(i)) for i in range(3)])
        print("futurize :", total.result(), "<- tree",
              [n.name for n in tr.nodes])

    g = FuturizedGraph(max_workers=4, name="demo")

    # 1. constraint-based sync: c runs only once a and b resolved - the
    #    caller never forces anything until the very end.
    a = g.defer(lambda: 2, name="a")
    b = g.defer(lambda x: x * 3, a, name="b")
    c = g.defer(lambda x, y: x + y, a, b, name="c")
    print("dag      : a=2, b=a*3, c=a+b ->", c.result())

    # 2. combinators + the tree of futures: futures nested anywhere inside
    #    a pytree become edges.
    squares = [g.defer(lambda i=i: i * i, name=f"sq:{i}") for i in range(5)]
    print("when_all :", g.when_all(squares).result())
    idx, val = g.when_any(squares).result()
    print(f"when_any : index {idx} -> {val}")
    tree = {"x": squares[3], "static": 42, "nested": [squares[1], "str"]}
    print("tree_join:", g.tree_join(tree).result())

    # 3. an error poisons exactly its transitive dependents.
    bad = g.defer(lambda: 1 / 0, name="bad")
    hit = g.defer(lambda x: x + 1, bad, name="hit")
    ok = g.defer(lambda: "unaffected", name="ok")
    try:
        hit.result()
    except ZeroDivisionError as e:
        print(f"poisoned : hit.result() raised {type(e).__name__}: {e}")
    print("intact   :", ok.result())

    # 4. the overlapped loop in miniature (what launch/train.py does).
    @jax.jit
    def step(w, batch):
        h = jnp.tanh(w[batch["tokens"]])
        return {"loss": -jnp.mean(h), "w": w}

    stream = LMStream(vocab=64, batch=8, seq=256)
    prefetch = Prefetcher(stream, graph=g)      # Lane.PREFETCH nodes
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, graph=g)    # Lane.CHECKPOINT nodes
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        inflight = Pipeline(depth=2)
        t0 = time.perf_counter()
        for it in range(20):
            out = step(w, prefetch.get(it))
            inflight.push(it, out)
            if (it + 1) % 10 == 0:
                retired = g.defer(jax.block_until_ready, out,
                                  name=f"retire:{it}")
                ckpt.save(it + 1, {"w": w}, deps=(retired,))
        inflight.drain()
        ckpt.wait()
        print(f"loop     : 20 steps in {time.perf_counter() - t0:.3f}s, "
              f"checkpoints on disk: {ckpt.all_steps()}")

    st = g.stats()
    print(f"stats    : submitted={st.submitted} completed={st.completed} "
          f"failed={st.failed} max_in_flight={st.max_in_flight}")
    print(f"per lane : {st.per_lane}")
    g.shutdown(wait=True)


if __name__ == "__main__":
    main()
