"""Batched serving with continuous slot refill on a (data=2, model=2) mesh.

    PYTHONPATH=src python examples/serve_batched.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import sys  # noqa: E402

from repro.launch import serve as serve_mod  # noqa: E402


def main():
    args = serve_mod.parser().parse_args(
        ["--arch", "qwen3-4b", "--requests", "12", "--slots", "4",
         "--prompt-len", "32", "--gen-len", "16", "--data", "2",
         "--model", "2"] + sys.argv[1:])
    serve_mod.run(args)


if __name__ == "__main__":
    main()
