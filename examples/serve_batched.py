"""Batched serving with continuous slot refill on a (data=2, model=2) mesh,
through Plan/Session: every wave runs as a futurized tree of one prefill
node plus chained, named decode nodes.

    PYTHONPATH=src python examples/serve_batched.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

from repro.frontend import Plan  # noqa: E402


def main():
    plan = Plan(arch="qwen3-4b", tiny=True, data=2, model=2)
    with plan.compile() as session:
        out = session.serve(requests=12, slots=4, prompt_len=32, gen_len=16)
        waves = {n.split(":")[1] for n in out["nodes"]
                 if n.startswith("decode:")}
        print(f"{len(waves)} waves of decode graph nodes, "
              f"{out['tokens_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
