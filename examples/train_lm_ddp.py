"""End-to-end distributed training through Plan/Session:

  * 8 host devices as a (data=4, model=2) mesh
  * paper-faithful "phylanx" strategy (fused bucketed async collectives)
  * async checkpointing every ~steps/5 steps
  * an injected node failure mid-run, then automatic restart from the
    latest checkpoint ON THE SAME SESSION (the fault-tolerance drill)

Scale knobs: larger --steps trains longer; the default trains the reduced
config on CPU.  ``--localities N`` runs the same loop with batch builds
on N-1 worker processes (the multi-locality runtime, DESIGN.md §9) -
the loss trajectory is identical because distribution changes where
host work runs, never what it computes.

``--ddp`` switches to *fabric DDP* (DESIGN.md §11): every locality
trains its own batch shards and gradients are summed by a ring
all-reduce of active messages - with ``--grad-codec onebit`` the wire
carries 1-bit signs + error feedback (~1/31 of the fp32 bytes); the
report's ``grad-wire`` line prints the exact payload count.

    PYTHONPATH=src python examples/train_lm_ddp.py [--steps 200]
    PYTHONPATH=src python examples/train_lm_ddp.py --localities 2
    PYTHONPATH=src python examples/train_lm_ddp.py --ddp --localities 2 \
        --grad-codec onebit
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import sys  # noqa: E402

from repro.core.steps import Strategy  # noqa: E402
from repro.frontend import Plan  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--ckpt", default="/tmp/phyrax_ddp_ckpt")
    ap.add_argument("--localities", type=int, default=1)
    ap.add_argument("--ddp", action="store_true")
    ap.add_argument("--grad-codec", dest="grad_codec", default="onebit",
                    choices=["fp32", "onebit"])
    args, _ = ap.parse_known_args(argv)

    if args.ddp:                      # fabric DDP (DESIGN.md §11)
        plan = Plan(arch=args.arch, tiny=True, batch=16, seq=64, ddp=True,
                    localities=max(args.localities, 2),
                    grad_codec=args.grad_codec)
        with plan.compile() as session:
            out = session.train(steps=args.steps, log_every=10)
        print(f"fabric DDP ({args.grad_codec}) finished: final loss "
              f"{out['final_loss']:.4f}, gradient wire "
              f"{out['grad_wire_bytes']}B")
        return

    every = max(5, args.steps // 5)   # checkpoints exist before the failure
    plan = Plan(arch=args.arch, tiny=True, data=4, model=2,
                batch=16, seq=64, strategy=Strategy(name="phylanx"),
                localities=args.localities)
    with plan.compile() as session:
        print("=== phase 1: train until an injected node failure ===")
        try:
            session.train(steps=args.steps, ckpt_dir=args.ckpt,
                          ckpt_every=every, log_every=10,
                          fail_at_step=args.steps // 2)
        except RuntimeError as e:
            print(f"!! {e}")

        print("=== phase 2: restart from the latest checkpoint ===")
        out = session.train(steps=args.steps, ckpt_dir=args.ckpt,
                            ckpt_every=every, log_every=10, resume=True)
        print(f"recovered and finished: final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main(sys.argv[1:])
