"""End-to-end distributed training driver (deliverable b):

  * 8 host devices as a (data=4, model=2) mesh
  * paper-faithful "phylanx" strategy (fused bucketed async collectives)
  * async checkpointing every 25 steps
  * an injected node failure mid-run, then automatic restart from the
    latest checkpoint (the fault-tolerance drill)

Scale knobs: --full trains the real config (needs a real cluster); the
default trains the reduced config for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_lm_ddp.py [--steps 200]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import sys  # noqa: E402

from repro.launch import train as train_mod  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--ckpt", default="/tmp/phyrax_ddp_ckpt")
    args, _ = ap.parse_known_args(argv)

    every = max(5, args.steps // 5)   # checkpoints exist before the failure
    base = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", "16", "--seq", "64", "--data", "4", "--model", "2",
            "--strategy", "phylanx", "--ckpt", args.ckpt,
            "--ckpt-every", str(every), "--log-every", "10"]

    print("=== phase 1: train until an injected node failure ===")
    half = args.steps // 2
    try:
        train_mod.run(train_mod.parser().parse_args(
            base + ["--fail-at-step", str(half)]))
    except RuntimeError as e:
        print(f"!! {e}")

    print("=== phase 2: restart from the latest checkpoint ===")
    out = train_mod.run(train_mod.parser().parse_args(base + ["--resume"]))
    print(f"recovered and finished: final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main(sys.argv[1:])
