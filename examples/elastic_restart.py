"""Elastic restart: checkpoint on one mesh, resume on a DIFFERENT mesh -
survive losing a worker LOCALITY without restarting - and reshard a
checkpoint written by N localities into M.

Phase 1 trains on (data=2, model=2); phase 2 restores the same checkpoint
onto (data=4, model=1) - checkpoint resharding makes the cluster size an
execution detail, which is the paper's architecture-agnostic requirement
applied to fault tolerance / elasticity.

Phase 3 uses the multi-locality runtime (DESIGN.md §9): a 2-process run
where one worker locality is SIGKILLed mid-run.  Its in-flight tasks are
re-spawned on a surviving locality, so training finishes WITHOUT the
checkpoint round-trip phases 1-2 needed - locality loss degrades
capacity, not correctness.

Phase 4 closes the loop on the checkpoint side (DESIGN.md §10): a
2-locality run where each locality writes its OWN checkpoint shards
(verified via the manifest's shard->locality ownership map), then the
checkpoint is restored into a 1-locality run (N=2 -> M=1 resharding)
whose subsequent loss is bit-identical to an uninterrupted run.

Phase 5 is the multi-host SPMD variant (DESIGN.md §10, --spmd): both
processes join one jax.distributed world and each persists only the
ADDRESSABLE SHARDS of its global persistence view - leaves split into
device-shard segments, zero checkpoint leaf bytes on the messaging
layer (the printed wire counter proves it) - then the N=2 checkpoint
resumes on 1 process, again bit-identically.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import json
import os
import re
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
CKPT = "/tmp/phyrax_elastic_ckpt"


def run_phase(data, model, steps, extra, ckpt=CKPT):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen2.5-3b", "--steps", str(steps),
           "--batch", "8", "--seq", "32",
           "--data", str(data), "--model", str(model),
           "--ckpt", ckpt, "--ckpt-every", "10", "--log-every", "10"] + extra
    print(f"$ data={data} model={model} {' '.join(extra)}")
    p = subprocess.run(cmd, env=env, text=True, capture_output=True)
    print(p.stdout)
    if p.returncode != 0 and "--fail-at-step" not in " ".join(extra):
        print(p.stderr[-2000:])
        raise SystemExit(1)
    return p.stdout


def final_loss(out: str) -> float:
    return float(re.findall(r"final loss ([0-9.]+)", out)[-1])


def main():
    import shutil
    shutil.rmtree(CKPT, ignore_errors=True)
    print("=== phase 1: (data=2, model=2), dies at step 25 ===")
    run_phase(2, 2, 40, ["--fail-at-step", "25"])
    print("=== phase 2: resume the SAME checkpoint on (data=4, model=1) ===")
    run_phase(4, 1, 40, ["--resume"])
    print("elastic restart complete: params were resharded onto a new mesh")

    print("=== phase 3: 2 localities, worker SIGKILLed at step 20 ===")
    shutil.rmtree(CKPT, ignore_errors=True)
    run_phase(4, 1, 40, ["--localities", "2",
                         "--kill-locality-at-step", "20"])
    print("locality loss survived in-run: tasks re-spawned, no restart")

    print("=== phase 4: 2 localities write their OWN shards; "
          "restore into 1 ===")
    shutil.rmtree(CKPT, ignore_errors=True)
    run_phase(4, 1, 20, ["--localities", "2"])
    manifest_path = os.path.join(CKPT, "step_00000020", "manifest.json")
    with open(manifest_path) as f:
        ownership = json.load(f)["ownership"]
    print(f"shard ownership map (locality -> shards): {ownership}")
    assert len(ownership) >= 2, \
        f"expected shards written by driver AND worker, got {ownership}"
    resumed = run_phase(4, 1, 40, ["--resume"])          # N=2 -> M=1
    straight = run_phase(4, 1, 40, [], ckpt=CKPT + "_ref")
    a, b = final_loss(resumed), final_loss(straight)
    assert abs(a - b) < 1e-4, (a, b)
    print(f"resharded restore matched: resumed loss {a:.4f} == "
          f"uninterrupted {b:.4f}")
    print("each locality persisted its own shards; N->M restore is exact")

    print("=== phase 5: SPMD - each host saves only its ADDRESSABLE "
          "shards ===")
    shutil.rmtree(CKPT, ignore_errors=True)
    out = run_phase(4, 1, 20, ["--localities", "2", "--spmd"])
    assert "ckpt-leaf-wire 0B" in out, \
        "SPMD save shipped checkpoint leaf bytes over the wire"
    with open(os.path.join(CKPT, "step_00000020", "manifest.json")) as f:
        manifest = json.load(f)
    segments = [leaf for s in manifest["shards"] for leaf in s["leaves"]]
    sliced = sum("slice" in leaf for leaf in segments)
    print(f"ownership {manifest['ownership']}; {sliced} of "
          f"{len(segments)} segments are device shards; 0 leaf bytes "
          f"on the wire")
    assert len(manifest["ownership"]) == 2 and sliced > 0
    resumed = run_phase(4, 1, 40, ["--resume"])          # N=2 -> M=1
    straight = run_phase(4, 1, 40, [], ckpt=CKPT + "_ref2")
    a, b = final_loss(resumed), final_loss(straight)
    assert abs(a - b) < 1e-4, (a, b)
    print(f"SPMD addressable-shard restore matched: {a:.4f} == {b:.4f}")


if __name__ == "__main__":
    main()
