"""Quickstart: declare a Plan, compile it to a Session, train on the
synthetic bigram stream, then serve greedy tokens - the whole frontend API
on one CPU device, no launcher involved.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.steps import Strategy
from repro.frontend import Plan
from repro.optim.optimizers import OptConfig


def main():
    # 1. a Plan is the declarative run description: arch (any of the 10
    #    registry ids) + mesh axes + strategy + shapes
    plan = Plan(arch="qwen3-4b", tiny=True, data=1, model=1,
                batch=8, seq=64,
                strategy=Strategy(name="phylanx",   # fused async collectives
                                  opt=OptConfig(lr=1e-3)))
    cfg = plan.config()
    print(f"arch={cfg.name} family={cfg.family} "
          f"params~{cfg.n_params()[0] / 1e6:.1f}M (tiny)")

    # 2. compile() builds the Session: mesh + jitted steps + one futurized
    #    runtime for every host-side task (prefetch, logging, checkpoints)
    with plan.compile() as session:
        # 3. train on the default synthetic stream for this architecture
        out = session.train(steps=30, log_every=5)
        print(f"trained: final loss {out['final_loss']:.4f}")

        # 4. serve through the same session: each wave is a futurized tree
        #    of one prefill node + chained, *named* decode nodes
        served = session.serve(requests=4, slots=2, prompt_len=16,
                               gen_len=8)
        decode_nodes = [n for n in served["nodes"]
                        if n.startswith("decode:")]
        print(f"served : {served['tokens']} tokens at "
              f"{served['tokens_per_s']:.1f} tok/s")
        print(f"decode graph nodes: {decode_nodes[:4]} ... "
              f"({len(decode_nodes)} total)")


if __name__ == "__main__":
    main()
