"""Quickstart: build an architecture from the registry, train it on the
synthetic bigram stream, then serve a few greedy tokens - all through the
public API, all on one CPU device.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import steps as steps_lib
from repro.data.pipeline import LMStream
from repro.launch.mesh import make_local_mesh
from repro.optim.optimizers import OptConfig


def main():
    # 1. pick an architecture (any of the 10 registry ids) at smoke scale
    cfg = get_config("qwen3-4b", tiny=True)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params~{cfg.n_params()[0] / 1e6:.1f}M (tiny)")

    # 2. a mesh + a strategy = a distributed training step
    mesh = make_local_mesh()                       # 1 device here; same code
    strategy = steps_lib.Strategy(name="phylanx",  # fused async collectives
                                  opt=OptConfig(lr=1e-3))
    shape = {"seq_len": 64, "global_batch": 8, "kind": "train"}
    step = steps_lib.make_train_step(cfg, mesh, strategy, shape)

    # 3. train on the synthetic stream
    stream = LMStream(vocab=64, batch=8, seq=64, seed=0)
    params, opt = step.init(jax.random.PRNGKey(0))
    for it in range(30):
        metrics, params, opt = step.fn(params, opt, stream.batch_at(it))
        if (it + 1) % 5 == 0:
            print(f"step {it + 1:3d}  loss {float(metrics['loss']):.4f}")

    # 4. serve: prefill a prompt, decode greedily with the KV cache
    model = step.model
    prompt = stream.batch_at(999)["tokens"][:1, :16]
    logits, cache = model.prefill(params, {"tokens": prompt}, 32)
    toks = [int(jnp.argmax(logits[0]))]
    cur = jnp.array([[toks[-1]]], jnp.int32)
    for t in range(8):
        logits, cache = model.decode_step(params, cache, {"tokens": cur},
                                          jnp.int32(16 + t))
        toks.append(int(jnp.argmax(logits[0])))
        cur = jnp.array([[toks[-1]]], jnp.int32)
    print("prompt tail :", list(map(int, prompt[0, -6:])))
    print("generated   :", toks)
    want = [(31 * prompt[0, -1].item() + 7) % 64]
    for _ in range(8):
        want.append((31 * want[-1] + 7) % 64)
    print("bigram rule :", want, " (model should start matching this)")


if __name__ == "__main__":
    main()
