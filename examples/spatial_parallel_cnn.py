"""Spatial parallelism with overlapped tiling (paper §4.1: "Phylanx supports
overlapped tiling, which is beneficial in spatial parallelization. A halo
exchange is needed in forward and backward pass").

The HAR CNN's time axis is sharded across 4 devices; each shard holds its
tile plus halo ghost rows exchanged via collective_permute, so a k=3 VALID
conv over the halo-extended tiles equals the unsharded conv exactly.

    PYTHONPATH=src python examples/spatial_parallel_cnn.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import collectives  # noqa: E402
from repro.core.sharding import init_params  # noqa: E402
from repro.models import cnn  # noqa: E402


def main():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("data",))
    params = init_params(cnn.har_cnn_specs(), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 9))

    # --- unsharded reference: one conv over the full window ---------------
    ref = cnn._conv1d(x, params["conv1"]["w"], params["conv1"]["b"])

    # --- spatially sharded: tile the time axis, exchange k-1 halo rows ----
    halo = 1  # (k - 1) // 2 for k=3

    def sharded_conv(x_tile, w, b):
        xt = collectives.halo_exchange(x_tile, "data", halo, dim=1)
        y = cnn._conv1d(xt, w, b)
        return y  # [B, tile, Cout] after VALID conv over the halo'd tile

    from repro.core.compat import shard_map
    fn = jax.jit(shard_map(
        lambda x, w, b: sharded_conv(x, w, b), mesh=mesh,
        in_specs=(P(None, "data"), P(), P()),
        out_specs=P(None, "data"), check_vma=False))
    xs = jax.device_put(x, NamedSharding(mesh, P(None, "data")))
    y = fn(xs, params["conv1"]["w"], params["conv1"]["b"])

    # interior rows must match exactly (edge tiles see zero-padded ghosts,
    # so compare the valid interior of each tile)
    y_np, ref_np = np.asarray(y), np.asarray(ref)
    tile = 128 // 4
    max_err = 0.0
    for s in range(4):
        # tile s's outputs cover global rows [s*tile - halo, ...] except at
        # the edges; compare the overlap with the reference
        for j in range(tile):
            g = s * tile - halo + j      # global output row index
            if 0 <= g < ref_np.shape[1]:
                max_err = max(max_err, float(
                    np.abs(y_np[:, s * tile + j] - ref_np[:, g]).max()))
    print(f"spatial-parallel conv vs unsharded: max_err={max_err:.2e}")
    assert max_err < 1e-5
    print("overlapped tiling (halo exchange) reproduces the unsharded conv")


if __name__ == "__main__":
    main()
