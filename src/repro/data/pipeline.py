"""Data pipeline: deterministic synthetic streams, host prefetch, sharding.

Unified into the framework (paper R6): batches come out already placed with
the step's batch shardings, prefetched on a background thread so host data
work overlaps device compute (R3 at the input edge).  Under the
multi-locality runtime (DESIGN.md §9) the build half of each prefetch
moves to a worker process and streams back; placement stays local.

Synthetic LM stream: a noisy affine bigram process
    x_{t+1} = (a * x_t + b) mod V   with prob (1 - noise), else uniform
- deterministic per (seed, step), learnable (examples show loss dropping),
and unbounded.  HAR stream: labelled multi-channel sinusoid windows for the
paper's 4-layer CNN (Fig. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

from ..core.futures import FuturizedGraph, Lane


@dataclasses.dataclass
class LMStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    noise: float = 0.1
    a: int = 31
    b: int = 7
    frames_dim: int = 0            # >0: also emit encoder frames (enc-dec)
    frames_len: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        x = np.empty((self.batch, self.seq + 1), np.int32)
        x[:, 0] = rng.integers(0, self.vocab, self.batch)
        noise_mask = rng.random((self.batch, self.seq)) < self.noise
        noise_tok = rng.integers(0, self.vocab, (self.batch, self.seq))
        for t in range(self.seq):
            nxt = (self.a * x[:, t] + self.b) % self.vocab
            x[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        out = {"tokens": x[:, :-1], "labels": x[:, 1:]}
        if self.frames_dim:
            out["frames"] = rng.standard_normal(
                (self.batch, self.frames_len, self.frames_dim)
            ).astype(np.float32) * 0.1
        return out


def stream_for(cfg, *, batch: int, seq: int, seed: int = 0) -> "LMStream":
    """The default synthetic stream for an architecture: bigram LM tokens,
    plus encoder frames for enc-dec families.  ``Session.train`` uses this
    when no stream is supplied."""
    encdec = cfg.family == "encdec"
    return LMStream(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed,
                    frames_dim=cfg.d_model if encdec else 0,
                    frames_len=cfg.enc_frames if encdec else 0)


@dataclasses.dataclass
class HARStream:
    """Windows of 9-channel signals; class = dominant frequency band."""
    batch: int
    length: int = 128
    channels: int = 9
    classes: int = 6
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        y = rng.integers(0, self.classes, self.batch)
        t = np.arange(self.length)[None, :, None] / self.length
        freq = (y[:, None, None] + 1) * 2.0
        phase = rng.random((self.batch, 1, self.channels)) * 6.28
        x = np.sin(6.28 * freq * t + phase) + \
            0.3 * rng.standard_normal((self.batch, self.length,
                                       self.channels))
        return {"x": x.astype(np.float32), "y": y.astype(np.int32)}


class Prefetcher:
    """Double-buffered prefetch as futurized-graph nodes: batch step+k is
    built on a worker while step runs on device, then device_put with the
    step's shardings (arrives already tiled).  Each outstanding batch is a
    ``Lane.PREFETCH`` node, so on a shared runtime prefetch yields to
    step-critical compute but beats checkpoint I/O.

    With ``dgraph`` (a ``repro.distrib.DistributedGraph``) the host build
    moves to a *worker locality*: ``stream.batch_at`` - which must then
    be picklable, as every registry stream is - runs in another process
    and streams the raw batch back, while the ``device_put`` placement
    stays on the driver (device state never crosses the wire).  The
    local node keeps the ``prefetch:{s}`` name, so consumers and traces
    are unchanged by distribution.

    Trade-off, deliberate: the bound method ships the stream object with
    every build, which keeps builds round-robining over *all* workers
    (registry streams are a few scalars, so the per-build cost is noise).
    A stream with heavy state should instead be pinned once
    (``dgraph.defer(make_stream, pin=True)``) and consumed via a
    module-level ``build(stream_ref, step)`` - ref affinity then keeps
    every build on the owning worker and only gids cross the wire."""

    def __init__(self, stream, shardings: Optional[dict] = None,
                 depth: int = 2, graph: Optional[FuturizedGraph] = None,
                 dgraph: Optional[Any] = None):
        self.stream = stream
        self.shardings = shardings
        self._own_graph = graph is None
        self.graph = graph if graph is not None else FuturizedGraph(
            max_workers=2, name="prefetch")
        self.dgraph = dgraph
        self._futs: dict[int, Any] = {}
        self.depth = depth

    def _place(self, b: dict):
        if self.shardings:
            b = {k: jax.device_put(v, self.shardings.get(k))
                 for k, v in b.items()}
        return b

    def _make(self, step: int):
        return self._place(self.stream.batch_at(step))

    def schedule(self, step: int):
        """Ensure batches [step, step+depth) are in flight as graph nodes."""
        for s in range(step, step + self.depth):
            if s not in self._futs:
                if self.dgraph is not None:
                    built = self.dgraph.defer(
                        self.stream.batch_at, s, lane=Lane.PREFETCH,
                        name=f"build:{s}")
                    self._futs[s] = self.graph.defer(
                        self._place, built, lane=Lane.PREFETCH,
                        name=f"prefetch:{s}")
                else:
                    self._futs[s] = self.graph.defer(
                        self._make, s, lane=Lane.PREFETCH,
                        name=f"prefetch:{s}")

    def get_future(self, step: int):
        """The batch's future - lets a consumer depend on it by edge
        instead of blocking here."""
        self.schedule(step)
        return self._futs.pop(step)

    def get(self, step: int) -> dict:
        return self.get_future(step).result()

    def close(self):
        for f in self._futs.values():
            if not f.cancel():
                # lookahead batch finished before the cancel landed: observe
                # it so the node doesn't read as silently dropped (PHY004)
                f.exception()
        self._futs.clear()
        if self._own_graph:
            self.graph.shutdown(wait=True)
