"""Solvers (paper terminology): synchronous SGD / momentum / AdamW.

Two state layouts:
  * dense   - m/v mirror the parameter tree (replicated over data axes like
              the params); used by the "horovod" and "phylanx" strategies.
  * zero1   - ZeRO stage 1: the parameter tree is flattened through the same
              fusion plan used for gradient collectives, and m/v/updates
              live only on each rank's shard of every bucket; the train step
              reduce-scatters gradients into the shard and all-gathers
              updated parameters (core/overlap.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.sharding import ParamSpec


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"          # adamw | momentum | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    grad_clip: float = 1.0


# ---------------------------------------------------------------------------
# Dense layout
# ---------------------------------------------------------------------------
def init_specs(param_specs, oc: OptConfig):
    """ParamSpec tree for the optimizer state (so it shards like params)."""
    f32 = lambda s: ParamSpec(s.shape, s.dims, jnp.float32, "zeros")
    zeros = lambda: jax.tree.map(f32, param_specs,
                                 is_leaf=lambda x: isinstance(x, ParamSpec))
    st = {"count": ParamSpec((), (), jnp.int32, "zeros")}
    if oc.kind == "adamw":
        st["m"] = zeros()
        st["v"] = zeros()
    elif oc.kind == "momentum":
        st["m"] = zeros()
    return st


def init(params, oc: OptConfig):
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    st = {"count": jnp.zeros((), jnp.int32)}
    if oc.kind == "adamw":
        st["m"] = zeros()
        st["v"] = zeros()
    elif oc.kind == "momentum":
        st["m"] = zeros()
    return st


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def _adamw_leaf(g, p, m, v, count, oc: OptConfig):
    g = g.astype(jnp.float32)
    m = oc.b1 * m + (1 - oc.b1) * g
    v = oc.b2 * v + (1 - oc.b2) * g * g
    mh = m / (1 - oc.b1 ** count)
    vh = v / (1 - oc.b2 ** count)
    upd = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
    new_p = (p.astype(jnp.float32) - oc.lr * upd).astype(p.dtype)
    return new_p, m, v


def update(grads, state, params, oc: OptConfig):
    """Dense update. Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, oc.grad_clip)
    count = state["count"] + 1
    if oc.kind == "adamw":
        out = jax.tree.map(
            lambda g, p, m, v: _adamw_leaf(g, p, m, v, count, oc),
            grads, params, state["m"], state["v"])
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_p = jax.tree.unflatten(treedef, [l[0] for l in leaves])
        new_m = jax.tree.unflatten(treedef, [l[1] for l in leaves])
        new_v = jax.tree.unflatten(treedef, [l[2] for l in leaves])
        return new_p, {"count": count, "m": new_m, "v": new_v}, {"grad_norm": gn}
    if oc.kind == "momentum":
        new_m = jax.tree.map(lambda m, g: oc.momentum * m + g.astype(jnp.float32),
                             state["m"], grads)
        new_p = jax.tree.map(lambda p, m: (p.astype(jnp.float32) - oc.lr * m
                                           ).astype(p.dtype), params, new_m)
        return new_p, {"count": count, "m": new_m}, {"grad_norm": gn}
    # plain sgd
    new_p = jax.tree.map(lambda p, g: (p.astype(jnp.float32)
                                       - oc.lr * g.astype(jnp.float32)
                                       ).astype(p.dtype), params, grads)
    return new_p, {"count": count}, {"grad_norm": gn}


# ---------------------------------------------------------------------------
# ZeRO-1 sharded layout (used inside the shard_map train step)
# ---------------------------------------------------------------------------
def zero1_shard_update(g_shard, p_shard, m, v, count, oc: OptConfig,
                       clip_scale):
    """AdamW on 1-D bucket shards (all fp32)."""
    g = g_shard.astype(jnp.float32) * clip_scale
    m = oc.b1 * m + (1 - oc.b1) * g
    v = oc.b2 * v + (1 - oc.b2) * g * g
    mh = m / (1 - oc.b1 ** count)
    vh = v / (1 - oc.b2 ** count)
    upd = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p_shard
    return p_shard - oc.lr * upd, m, v
