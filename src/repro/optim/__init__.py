from . import optimizers  # noqa: F401
from .optimizers import OptConfig  # noqa: F401
