"""Gradient compression for the data-parallel wire (beyond-paper trick,
credited by the paper to CNTK's 1-bit SGD, §3.7).

1-bit exchange with error feedback over fused buckets:
  1. pack local gradient buckets to sign bits (uint32 bitmaps) + per-row
     L1 scales, folding the running quantization error in first;
  2. all-gather the bitmaps+scales across the dp axes (wire ~ 1/30 of f32);
  3. dequantize every rank's contribution and average locally.

The jnp pack/unpack here mirror kernels/onebit.py bit-for-bit (tested);
on TPU the Pallas kernels take over via kernels/ops.py.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core import compat, fusion

ROW = 1024  # bucket rows are reshaped to [R, ROW] for per-row scales


def pack_bits(signs):
    """bool [R, C] -> uint32 [R, C/32] (little-endian bit order)."""
    R, C = signs.shape
    bits = signs.reshape(R, C // 32, 32).astype(jnp.uint32)
    weights = jnp.left_shift(jnp.uint32(1), jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(bits * weights[None, None, :], axis=-1, dtype=jnp.uint32)


def unpack_bits(packed):
    """uint32 [R, C/32] -> bool [R, C]."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = jnp.bitwise_and(
        jnp.right_shift(packed[:, :, None], shifts[None, None, :]),
        jnp.uint32(1))
    return bits.reshape(packed.shape[0], -1).astype(bool)


def pack_signs(signs):
    """bool [n] (any n, ragged ok) -> uint32 [ceil(n/32)] bitmap.

    The 1-D face of :func:`pack_bits` for wire payloads whose length is
    not a multiple of 32: pads with zeros, packs little-endian.  Inverse
    is ``unpack_signs(packed, n)``.
    """
    n = signs.shape[0]
    pad = (-n) % 32
    flat = jnp.pad(signs.astype(bool), (0, pad))
    return pack_bits(flat.reshape(1, -1))[0]


def unpack_signs(packed, n: int):
    """uint32 [ceil(n/32)] -> bool [n]; inverse of :func:`pack_signs`."""
    return unpack_bits(packed.reshape(1, -1))[0, :n]


def quantize_bucket(buf, err):
    """1-D bucket (len % ROW*32 == 0) -> (packed, scales, new_err)."""
    q = buf.astype(jnp.float32).reshape(-1, ROW) + err
    scale = jnp.mean(jnp.abs(q), axis=1, keepdims=True)
    signs = q >= 0
    deq = jnp.where(signs, scale, -scale)
    new_err = (q - deq).reshape(err.shape)
    return pack_bits(signs), scale, new_err


def dequantize_bucket(packed, scale, n: int):
    signs = unpack_bits(packed)
    deq = jnp.where(signs, scale, -scale)
    return deq.reshape(-1)[:n]


def make_plan(grads_structs, dp_degree: int) -> fusion.FusionPlan:
    """Fusion plan whose buckets are divisible by both the dp axes and the
    [R, 1024] quantization view."""
    import math
    pad = math.lcm(max(dp_degree, 1), ROW * 32)
    return fusion.make_plan(grads_structs, cap_bytes=32 << 20, pad_to=pad)


def init_error_state(plan: fusion.FusionPlan):
    return [jnp.zeros((b.size // ROW, ROW), jnp.float32)
            for b in plan.buckets]


def exchange_onebit(grads, err_state, dp_axes, plan):
    """Inside shard_map: compressed all-gather + local average.

    Returns (mean gradients, new error state).  Wire per bucket:
    size/32 (bits) + size/1024 (scales) floats vs size floats uncompressed.
    """
    axes = tuple(dp_axes)
    ndp = 1
    for a in axes:
        ndp *= compat.axis_size(a)
    bufs = fusion.pack(grads, plan)
    out_bufs, new_err = [], []
    for buf, err in zip(bufs, err_state):
        packed, scale, err2 = quantize_bucket(buf, err)
        all_packed = compat.all_gather(packed, axes, tiled=False)  # [ndp, R, C/32]
        all_scale = compat.all_gather(scale, axes, tiled=False)    # [ndp, R, 1]
        signs = unpack_bits(all_packed.reshape(-1, packed.shape[-1]))
        signs = signs.reshape((ndp,) + packed.shape[:1] + (-1,))
        deq = jnp.where(signs, all_scale, -all_scale)      # [ndp, R, ROW]
        mean = jnp.mean(deq, axis=0).reshape(-1)[:buf.shape[0]]
        out_bufs.append(mean.astype(buf.dtype))
        new_err.append(err2)
    return fusion.unpack(out_bufs, plan), new_err
