"""AGAS-style object directory: global ids for locality-owned values.

HPX's Active Global Address Space names every distributed object with a
global id (gid) and resolves gids to owning localities, so tasks can be
co-located with their data instead of the data moving to the task.  The
analogue here (DESIGN.md §9):

  * a ``gid`` is ``(owner_rank, index)`` - ownership is encoded in the
    id itself, so resolution is a tuple read, never a lookup round-trip.
    Under *failure* we still re-create rather than migrate (a dead
    locality's values die with it), but elastic scale-out migrates:
    ``rebalance`` moves a contiguous block of live objects to newcomer
    localities and leaves a ``_Forward`` stub per moved gid, so a stale
    ``RemoteRef`` derefs through one extra hop until refreshed
    (DESIGN.md §13);
  * ``ObjectDirectory.put`` registers a value owned by this locality and
    returns a ``RemoteRef`` others can hold, ship, or deref;
  * ``fetch`` resolves a ref: a local dictionary hit when this locality
    owns it, one active-message request (``agas_fetch``) otherwise;
  * the distributed scheduler uses ref ownership for *data affinity*:
    a task whose arguments hold refs is placed on the majority owner,
    where every deref is local.

Pinned task results (``DistributedGraph.defer(..., pin=True)``) live
here: the worker keeps the value and streams back only the ref, so a
consumer chain touring one locality never ships intermediates.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, Optional

import numpy as np

from ..analysis import sanitize as _san
from .messaging import Endpoint, PeerLostError

__all__ = ["ObjectDirectory", "RemoteRef", "rebalance_plan"]


def _nbytes(value: Any) -> int:
    """Rough payload size: summed array bytes over the value's leaves
    (used for reporting only, never for correctness)."""
    import jax

    total = 0
    for leaf in jax.tree.leaves(value):
        if isinstance(leaf, np.ndarray) or hasattr(leaf, "nbytes"):
            total += int(getattr(leaf, "nbytes", 0))
    return total


@dataclasses.dataclass(frozen=True)
class RemoteRef:
    """A global name for a value owned by one locality.

    Ships freely over the wire (it is just the id plus bookkeeping);
    holding a ref does not keep the owner alive.  Deref via
    ``ObjectDirectory.fetch`` or by passing it as an argument to a
    distributed task - the worker dereferences refs before calling the
    task function.
    """
    gid: tuple[int, int]        # (owner_rank, index)
    nbytes: int = 0
    summary: str = ""

    @property
    def owner(self) -> int:
        """Rank of the owning locality (encoded in the gid)."""
        return self.gid[0]

    def __repr__(self):
        return (f"<RemoteRef {self.gid[0]}:{self.gid[1]} "
                f"{self.summary or 'value'} ~{self.nbytes}B>")


@dataclasses.dataclass(frozen=True)
class _Forward:
    """Owner-side forwarding stub left behind by ``rebalance``: the
    value migrated to ``ref``'s locality; a deref of the old gid chases
    the stub one hop.  Stored in ``_store`` in place of the value, so a
    stale ``RemoteRef`` held anywhere keeps resolving."""
    ref: RemoteRef


def rebalance_plan(indices: list[int], owner: int,
                   newcomers: list[int]) -> dict[int, list[int]]:
    """Contiguous-block reassignment of one owner's live object indices
    across ``[owner] + newcomers``.

    Same ownership math as ``checkpoint.format.assign_shards`` (blocks
    as even as possible, at most one element of spread); the owner keeps
    the first block, each newcomer adopts one of the rest.  Pure - the
    property suite checks totality / contiguity / balance on it
    directly.

    Returns:
        ``{newcomer_rank: [indices to migrate]}`` (owner's keep-block is
        implied; empty blocks are omitted).
    """
    from ..checkpoint.format import assign_shards

    idxs = sorted(indices)
    plan: dict[int, list[int]] = {}
    for _sid, rank, block in assign_shards(len(idxs), [owner, *newcomers]):
        if rank != owner and block:
            plan[rank] = [idxs[i] for i in block]
    return plan


class ObjectDirectory:
    """This locality's slice of the global address space.

    Args:
        rank: owning locality rank, baked into every gid issued here.
        endpoint: active-message endpoint; ``agas_fetch``/``agas_free``
            handlers are registered on it so any peer can deref/free.
    """

    def __init__(self, rank: int, endpoint: Optional[Endpoint] = None):
        self.rank = rank
        self.endpoint = endpoint
        self._store: dict[int, Any] = {}
        self._lock = threading.Lock()
        self._counter = itertools.count()
        # pin/deref accounting (always on; audited by the sanitizer):
        # every local put/fetch/free of gids owned HERE, plus the set of
        # indices that were freed - so a late fetch can be classified as
        # use-after-free rather than never-registered (PHY105)
        self.puts = 0
        self.local_fetches = 0
        self.frees = 0
        # elastic rebalance accounting: objects migrated away from here,
        # and derefs that chased a forwarding stub (one extra hop)
        self.migrated = 0
        self.forwarded_fetches = 0
        self._freed: set[int] = set()
        if endpoint is not None:
            endpoint.register("agas_fetch", self._on_fetch)
            endpoint.register("agas_free", self._on_free)
            endpoint.register("agas_adopt", self._on_adopt)

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    # -- registration -------------------------------------------------------
    def put(self, value: Any, *, summary: str = "") -> RemoteRef:
        """Register ``value`` as owned by this locality.

        Returns:
            A ``RemoteRef`` naming it globally; the value stays here
            until ``free``d or the locality shuts down.
        """
        with self._lock:
            idx = next(self._counter)
            self._store[idx] = value
            self.puts += 1
        return RemoteRef(gid=(self.rank, idx), nbytes=_nbytes(value),
                         summary=summary)

    # -- resolution ---------------------------------------------------------
    def fetch(self, ref: RemoteRef, *, timeout: float = 60.0) -> Any:
        """Deref: local dictionary hit when owned here, one
        ``agas_fetch`` round-trip to the owner otherwise.

        A gid whose value migrated away (elastic rebalance) resolves
        through its forwarding stub transparently - one extra hop.

        Raises:
            KeyError: the gid was never registered or already freed.
            PeerLostError: the owning locality is gone (its values die
                with it - the failure model's re-create-not-migrate rule).
        """
        owner, idx = ref.gid
        if owner == self.rank:
            with self._lock:
                if idx not in self._store:
                    self._diagnose_miss(idx, self.rank)
                    raise KeyError(f"gid {ref.gid} not in directory")
                value = self._store[idx]
                if not isinstance(value, _Forward):
                    self.local_fetches += 1
                    return value
            return self._chase(ref, value, timeout)
        if self.endpoint is None:
            raise KeyError(f"gid {ref.gid} is remote and this directory "
                           f"has no endpoint")
        out = self.endpoint.request(owner, "agas_fetch", list(ref.gid),
                                    timeout=timeout)
        if isinstance(out, _Forward):
            return self._chase(ref, out, timeout)
        return out

    def _chase(self, ref: RemoteRef, fwd: _Forward, timeout: float) -> Any:
        """Deref one hop through a forwarding stub.  A chase that lands
        on a dead locality or a freed target means the stub outlived the
        migrated value: PHY107."""
        with self._lock:
            self.forwarded_fetches += 1
        try:
            return self.fetch(fwd.ref, timeout=timeout)
        except (KeyError, ConnectionError) as e:
            if _san.active():
                _san.get().record(
                    "PHY107",
                    f"locality {self.rank}: deref of gid {ref.gid} chased "
                    f"a forwarding stub to dead gid {fwd.ref.gid}: {e}",
                    once_key=f"fwd:{self.rank}:{ref.gid}")
            raise

    def free(self, ref: RemoteRef):
        """Drop the value behind ``ref`` (idempotent; remote owners get
        a fire-and-forget ``agas_free``)."""
        owner, idx = ref.gid
        if owner == self.rank:
            self._free_local(idx)
        elif self.endpoint is not None:
            self.endpoint.post(owner, "agas_free", list(ref.gid))

    def _free_local(self, idx: int):
        with self._lock:
            value = self._store.pop(idx, None)
            present = value is not None
            if present:
                self.frees += 1
                self._freed.add(idx)
            # double-free is idempotent by contract; freeing an index
            # that was never issued is an accounting bug (PHY105)
            unknown = not present and idx not in self._freed
        if unknown and _san.active():
            _san.get().record(
                "PHY105",
                f"locality {self.rank}: free of never-registered gid "
                f"({self.rank}, {idx})",
                once_key=f"free:{self.rank}:{idx}")
        if isinstance(value, _Forward) and self.endpoint is not None:
            # freeing a migrated gid frees the migrated value too
            try:
                self.endpoint.post(value.ref.owner, "agas_free",
                                   list(value.ref.gid))
            except PeerLostError:
                pass                  # new owner already gone; nothing held

    def _diagnose_miss(self, idx: int, requester) -> None:
        """Classify a fetch miss for the sanitizer (caller raises)."""
        if not _san.active():
            return
        kind = ("fetch after free" if idx in self._freed
                else "fetch of never-registered gid")
        _san.get().record(
            "PHY105",
            f"locality {self.rank}: {kind} ({self.rank}, {idx}) "
            f"requested by locality {requester}",
            once_key=f"fetch:{self.rank}:{idx}")

    def audit(self) -> dict:
        """Pin/deref accounting for this locality's slice of the address
        space: informational (surfaced in runtime stats); imbalances that
        are provable bugs are reported as PHY105 diagnostics instead."""
        with self._lock:
            return {"live": len(self._store), "puts": self.puts,
                    "local_fetches": self.local_fetches,
                    "frees": self.frees, "migrated": self.migrated,
                    "forwarded_fetches": self.forwarded_fetches}

    # -- elastic rebalance ---------------------------------------------------
    def rebalance(self, newcomers: list[int]) -> int:
        """Migrate contiguous tail blocks of this locality's live
        objects onto ``newcomers`` (``rebalance_plan`` math), leaving a
        forwarding stub per moved gid so stale refs keep resolving.

        Values that cannot cross the wire (unpicklable locals) and gids
        freed mid-pass simply stay put - migration is best-effort and
        never required for correctness.

        Returns:
            Number of objects migrated away.
        """
        newcomers = [r for r in newcomers if r != self.rank]
        if not newcomers or self.endpoint is None:
            return 0
        with self._lock:
            live = [i for i, v in self._store.items()
                    if not isinstance(v, _Forward)]
        moved = 0
        for rank, idxs in rebalance_plan(live, self.rank, newcomers).items():
            for idx in idxs:
                with self._lock:
                    value = self._store.get(idx)
                if value is None or isinstance(value, _Forward):
                    continue          # freed or migrated concurrently
                try:
                    new_ref = self.endpoint.request(
                        rank, "agas_adopt",
                        {"value": value,
                         "summary": f"migrated:{self.rank}:{idx}"})
                except Exception:  # noqa: BLE001 - unshippable value or
                    continue       # unreachable newcomer: keep it home
                with self._lock:
                    still_here = idx in self._store
                    if still_here:
                        self._store[idx] = _Forward(ref=new_ref)
                        self.migrated += 1
                        moved += 1
                if not still_here:
                    # freed while in flight: release the adopted copy
                    try:
                        self.endpoint.post(rank, "agas_free",
                                           list(new_ref.gid))
                    except PeerLostError:
                        pass
        return moved

    # -- handlers ------------------------------------------------------------
    def _on_fetch(self, src: int, gid) -> Any:
        _, idx = gid
        with self._lock:
            present = idx in self._store
            if present:
                self.local_fetches += 1
                return self._store[idx]
        self._diagnose_miss(idx, src)
        raise KeyError(f"gid {tuple(gid)} not in directory of "
                       f"locality {self.rank}")

    def _on_free(self, src: int, gid):
        _, idx = gid
        self._free_local(idx)

    def _on_adopt(self, src: int, p: dict) -> RemoteRef:
        """Rebalance target side: take ownership of a migrated value and
        return its new ref (the old owner stores it in a stub)."""
        return self.put(p["value"], summary=p.get("summary", ""))
