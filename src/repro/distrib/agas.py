"""AGAS-style object directory: global ids for locality-owned values.

HPX's Active Global Address Space names every distributed object with a
global id (gid) and resolves gids to owning localities, so tasks can be
co-located with their data instead of the data moving to the task.  The
analogue here (DESIGN.md §9):

  * a ``gid`` is ``(owner_rank, index)`` - ownership is encoded in the
    id itself, so resolution is a tuple read, never a lookup round-trip
    (a deliberate simplification of full AGAS, which also supports
    migration; we do not migrate, we re-create - see the failure model);
  * ``ObjectDirectory.put`` registers a value owned by this locality and
    returns a ``RemoteRef`` others can hold, ship, or deref;
  * ``fetch`` resolves a ref: a local dictionary hit when this locality
    owns it, one active-message request (``agas_fetch``) otherwise;
  * the distributed scheduler uses ref ownership for *data affinity*:
    a task whose arguments hold refs is placed on the majority owner,
    where every deref is local.

Pinned task results (``DistributedGraph.defer(..., pin=True)``) live
here: the worker keeps the value and streams back only the ref, so a
consumer chain touring one locality never ships intermediates.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, Optional

import numpy as np

from ..analysis import sanitize as _san
from .messaging import Endpoint

__all__ = ["ObjectDirectory", "RemoteRef"]


def _nbytes(value: Any) -> int:
    """Rough payload size: summed array bytes over the value's leaves
    (used for reporting only, never for correctness)."""
    import jax

    total = 0
    for leaf in jax.tree.leaves(value):
        if isinstance(leaf, np.ndarray) or hasattr(leaf, "nbytes"):
            total += int(getattr(leaf, "nbytes", 0))
    return total


@dataclasses.dataclass(frozen=True)
class RemoteRef:
    """A global name for a value owned by one locality.

    Ships freely over the wire (it is just the id plus bookkeeping);
    holding a ref does not keep the owner alive.  Deref via
    ``ObjectDirectory.fetch`` or by passing it as an argument to a
    distributed task - the worker dereferences refs before calling the
    task function.
    """
    gid: tuple[int, int]        # (owner_rank, index)
    nbytes: int = 0
    summary: str = ""

    @property
    def owner(self) -> int:
        """Rank of the owning locality (encoded in the gid)."""
        return self.gid[0]

    def __repr__(self):
        return (f"<RemoteRef {self.gid[0]}:{self.gid[1]} "
                f"{self.summary or 'value'} ~{self.nbytes}B>")


class ObjectDirectory:
    """This locality's slice of the global address space.

    Args:
        rank: owning locality rank, baked into every gid issued here.
        endpoint: active-message endpoint; ``agas_fetch``/``agas_free``
            handlers are registered on it so any peer can deref/free.
    """

    def __init__(self, rank: int, endpoint: Optional[Endpoint] = None):
        self.rank = rank
        self.endpoint = endpoint
        self._store: dict[int, Any] = {}
        self._lock = threading.Lock()
        self._counter = itertools.count()
        # pin/deref accounting (always on; audited by the sanitizer):
        # every local put/fetch/free of gids owned HERE, plus the set of
        # indices that were freed - so a late fetch can be classified as
        # use-after-free rather than never-registered (PHY105)
        self.puts = 0
        self.local_fetches = 0
        self.frees = 0
        self._freed: set[int] = set()
        if endpoint is not None:
            endpoint.register("agas_fetch", self._on_fetch)
            endpoint.register("agas_free", self._on_free)

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    # -- registration -------------------------------------------------------
    def put(self, value: Any, *, summary: str = "") -> RemoteRef:
        """Register ``value`` as owned by this locality.

        Returns:
            A ``RemoteRef`` naming it globally; the value stays here
            until ``free``d or the locality shuts down.
        """
        with self._lock:
            idx = next(self._counter)
            self._store[idx] = value
            self.puts += 1
        return RemoteRef(gid=(self.rank, idx), nbytes=_nbytes(value),
                         summary=summary)

    # -- resolution ---------------------------------------------------------
    def fetch(self, ref: RemoteRef, *, timeout: float = 60.0) -> Any:
        """Deref: local dictionary hit when owned here, one
        ``agas_fetch`` round-trip to the owner otherwise.

        Raises:
            KeyError: the gid was never registered or already freed.
            PeerLostError: the owning locality is gone (its values die
                with it - the failure model's re-create-not-migrate rule).
        """
        owner, idx = ref.gid
        if owner == self.rank:
            with self._lock:
                if idx not in self._store:
                    self._diagnose_miss(idx, self.rank)
                    raise KeyError(f"gid {ref.gid} not in directory")
                self.local_fetches += 1
                return self._store[idx]
        if self.endpoint is None:
            raise KeyError(f"gid {ref.gid} is remote and this directory "
                           f"has no endpoint")
        return self.endpoint.request(owner, "agas_fetch", list(ref.gid),
                                     timeout=timeout)

    def free(self, ref: RemoteRef):
        """Drop the value behind ``ref`` (idempotent; remote owners get
        a fire-and-forget ``agas_free``)."""
        owner, idx = ref.gid
        if owner == self.rank:
            self._free_local(idx)
        elif self.endpoint is not None:
            self.endpoint.post(owner, "agas_free", list(ref.gid))

    def _free_local(self, idx: int):
        with self._lock:
            present = self._store.pop(idx, None) is not None
            if present:
                self.frees += 1
                self._freed.add(idx)
            # double-free is idempotent by contract; freeing an index
            # that was never issued is an accounting bug (PHY105)
            unknown = not present and idx not in self._freed
        if unknown and _san.active():
            _san.get().record(
                "PHY105",
                f"locality {self.rank}: free of never-registered gid "
                f"({self.rank}, {idx})",
                once_key=f"free:{self.rank}:{idx}")

    def _diagnose_miss(self, idx: int, requester) -> None:
        """Classify a fetch miss for the sanitizer (caller raises)."""
        if not _san.active():
            return
        kind = ("fetch after free" if idx in self._freed
                else "fetch of never-registered gid")
        _san.get().record(
            "PHY105",
            f"locality {self.rank}: {kind} ({self.rank}, {idx}) "
            f"requested by locality {requester}",
            once_key=f"fetch:{self.rank}:{idx}")

    def audit(self) -> dict:
        """Pin/deref accounting for this locality's slice of the address
        space: informational (surfaced in runtime stats); imbalances that
        are provable bugs are reported as PHY105 diagnostics instead."""
        with self._lock:
            return {"live": len(self._store), "puts": self.puts,
                    "local_fetches": self.local_fetches,
                    "frees": self.frees}

    # -- handlers ------------------------------------------------------------
    def _on_fetch(self, src: int, gid) -> Any:
        _, idx = gid
        with self._lock:
            present = idx in self._store
            if present:
                self.local_fetches += 1
                return self._store[idx]
        self._diagnose_miss(idx, src)
        raise KeyError(f"gid {tuple(gid)} not in directory of "
                       f"locality {self.rank}")

    def _on_free(self, src: int, gid):
        _, idx = gid
        self._free_local(idx)
