"""TCP active messages: the parcel layer of the multi-locality runtime.

HPX moves work between localities with *parcels* - messages that carry an
action (what to run) plus its arguments, and invoke that action at the
receiver.  This module is the socket-level analogue (DESIGN.md §9):

  * **Frames.**  Length-prefixed: a 4-byte big-endian length, then a
    msgpack-encoded envelope ``{kind, action, seq, src, ok, payload}``
    where ``payload`` is a pickled Python value (msgpack handles the
    fixed envelope cheaply; pickle handles arbitrary arguments - numpy
    arrays, dataclasses, top-level functions).  When msgpack is absent
    the whole envelope is pickled; both ends must agree, which they do
    because every process runs this same module.
  * **Request/ack.**  ``request()`` sends a ``req`` frame and blocks for
    the matching ``ack`` (by ``seq``); the handler's return value rides
    back in the ack, its exception rides back pickled and re-raises at
    the caller.  ``post()`` is fire-and-forget - the active-message
    spawn path, where completion comes back later as its own post.
  * **Peers.**  Every endpoint listens; connections are dialed on demand
    and identified by an ``__ident__`` post carrying the dialer's rank
    and listen address, so either side can initiate.  A dead peer fails
    its pending requests with ``PeerLostError`` and fires
    ``on_peer_lost(rank)`` exactly once - the hook the distributed
    scheduler uses to re-spawn a lost locality's tasks.

Handlers run on a small thread pool, never on the reader thread, so a
slow handler cannot stall frame delivery (or heartbeats) from the same
peer.
"""
from __future__ import annotations

import collections
import logging
import pickle
import socket
import struct
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Optional

from ..analysis import sanitize as _san

try:
    import msgpack
except ImportError:                  # pragma: no cover - container has it
    msgpack = None

log = logging.getLogger("repro.distrib")

__all__ = ["Endpoint", "PeerLostError", "raw_request", "recv_frame",
           "send_frame"]

_LEN = struct.Struct("!I")           # frame length prefix; frames < 4 GiB


class PeerLostError(ConnectionError):
    """The connection to a locality died with requests still pending."""


def _pack(env: dict) -> bytes:
    if msgpack is not None:
        return msgpack.packb(env, use_bin_type=True)
    return pickle.dumps(env, protocol=pickle.HIGHEST_PROTOCOL)


def _unpack(body: bytes) -> dict:
    if msgpack is not None:
        return msgpack.unpackb(body, raw=False)
    return pickle.loads(body)


def send_frame(sock: socket.socket, env: dict):
    """Serialize ``env`` and write one length-prefixed frame.

    Args:
        sock: a connected stream socket.
        env: the envelope dict (``payload`` must already be bytes).
    Raises:
        OSError: the peer is gone; the caller maps this to peer loss.
    """
    body = _pack(env)
    sock.sendall(_LEN.pack(len(body)) + body)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed the connection")
        buf.extend(chunk)
    return bytes(buf)


def recv_frame(sock: socket.socket) -> dict:
    """Read one length-prefixed frame and return the decoded envelope.

    Raises:
        ConnectionError: the peer closed mid-frame or before one.
    """
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    return _unpack(_recv_exact(sock, n))


def dumps(obj: Any) -> bytes:
    """Payload serializer (pickle, highest protocol) - one definition so
    the wire format is specified in exactly one module."""
    return pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)


def loads(data: bytes) -> Any:
    """Inverse of ``dumps``."""
    return pickle.loads(data)


def raw_request(address: tuple[str, int], action: str, payload: Any = None,
                *, timeout: float = 60.0) -> Any:
    """One-shot request over a fresh socket, no ``Endpoint`` required.

    The dial-in join handshake (DESIGN.md §13) runs before the joiner has
    a rank, so it cannot own an endpoint yet; it sends a single ``req``
    with ``src=-1`` and the receiver acks back over this same socket
    (see ``_dispatch``'s anonymous-requester fallback).

    Args:
        address: the listening ``(host, port)`` of a live endpoint.
        action: registered handler name there.
        payload: any picklable value.
        timeout: seconds for connect and for the ack.
    Returns:
        The remote handler's return value.
    Raises:
        Exception: whatever the remote handler raised, re-raised here.
        ConnectionError / TimeoutError: transport failure.
    """
    sock = socket.create_connection(tuple(address), timeout=timeout)
    try:
        sock.settimeout(timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        send_frame(sock, {"kind": "req", "action": action, "seq": 1,
                          "src": -1, "payload": dumps(payload)})
        env = recv_frame(sock)
        value = loads(env["payload"])
        if not env.get("ok", True):
            raise value
        return value
    finally:
        try:
            sock.close()
        except OSError:
            pass


class _Pending:
    __slots__ = ("event", "raw", "ok", "exc", "rank")

    def __init__(self, rank: int):
        self.event = threading.Event()
        self.raw: Optional[bytes] = None  # undecoded ack payload
        self.ok = True
        self.exc: Optional[BaseException] = None   # transport-level error
        self.rank = rank                 # destination, for targeted failure


class Endpoint:
    """One locality's active-message endpoint: a listener, a connection
    cache keyed by peer rank, and an action registry.

    Args:
        rank: this locality's rank (0 is the driver).
        host: interface to bind; loopback by default (single-node CI).
        port: listen port; 0 (the default) picks an ephemeral one.  A
            fixed port lets elastic joiners dial a known driver address
            (``--elastic-port`` / ``--join``).
        handler_threads: size of the pool handlers run on.

    Handlers are registered per action name via ``register`` and called
    as ``handler(src_rank, payload)``; for ``req`` frames the return
    value is shipped back in the ack.  ``bytes_sent`` / ``bytes_recv``
    count serialized frame bytes - the benchmark's wire-cost counters.
    """

    def __init__(self, rank: int, host: str = "127.0.0.1", *, port: int = 0,
                 handler_threads: int = 4):
        self.rank = rank
        self._handlers: dict[str, Callable[[int, Any], Any]] = {}
        self._conns: dict[int, socket.socket] = {}
        self._send_locks: dict[int, threading.Lock] = {}
        self._pending: dict[int, _Pending] = {}
        self._lost: set[int] = set()
        self._lock = threading.RLock()
        # (host, port) addresses with a dial in flight: a second dialer
        # to the same address waits on the condition instead of opening
        # a duplicate socket
        self._dialing: set[tuple[str, int]] = set()
        self._dial_cond = threading.Condition(self._lock)
        self._seq = 0
        self._closed = False
        self.on_peer_lost: Optional[Callable[[int], None]] = None
        # rank -> (host, port): lets _send dial lazily (worker-to-worker
        # AGAS fetches) instead of requiring pre-built connections
        self.address_book: dict[int, tuple[str, int]] = {}
        self.bytes_sent = 0
        self.bytes_recv = 0
        # posts to unregistered actions: a req gets its error acked back,
        # but a post has nobody to tell - so every drop is counted here
        # (surfaced through runtime stats) and warned once per action
        self.unhandled_posts: collections.Counter = collections.Counter()
        self._warned_unhandled: set[str] = set()
        self._pool = ThreadPoolExecutor(
            max_workers=handler_threads,
            thread_name_prefix=f"am{rank}-handler")
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(32)
        self.address: tuple[str, int] = self._listener.getsockname()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name=f"am{rank}-accept")
        self._accept_thread.start()
        self.register("__ident__", lambda src, p: None)

    # -- registry -----------------------------------------------------------
    def register(self, action: str, handler: Callable[[int, Any], Any]):
        """Bind ``handler(src_rank, payload)`` to ``action`` frames."""
        self._handlers[action] = handler

    # -- connections --------------------------------------------------------
    def connect(self, rank: int, address: tuple[str, int]):
        """Ensure a live connection to ``rank`` at ``address`` (no-op if
        one exists); identifies this endpoint to the peer.

        Idempotent under concurrency: dials to the same (host, port)
        collapse to one socket - a second local dialer waits for the
        first, and a dial that loses to a simultaneous inbound
        connection from the same peer (both sides of a join dialing
        each other) closes its duplicate instead of adopting it.
        """
        address = (address[0], int(address[1]))
        with self._dial_cond:
            if rank in self._conns or self._closed:
                return
            while address in self._dialing:
                self._dial_cond.wait(timeout=35)
                if rank in self._conns or self._closed:
                    return
            self._dialing.add(address)
        # dial OUTSIDE the endpoint lock: a slow handshake must not
        # stall unrelated sends / acks / reader registration
        try:
            sock = socket.create_connection(address, timeout=30)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            with self._dial_cond:
                self._dialing.discard(address)
                self._dial_cond.notify_all()
            raise
        with self._dial_cond:
            self._dialing.discard(address)
            self._dial_cond.notify_all()
            adopt = not self._closed and rank not in self._conns
            if adopt:
                self._adopt(rank, sock)
        if not adopt:
            # lost the race (inbound connection from the peer, or the
            # endpoint closed): discard the duplicate quietly
            try:
                sock.close()
            except OSError:
                pass
            return
        self._send(rank, {"kind": "post", "action": "__ident__", "seq": 0,
                          "src": self.rank,
                          "payload": dumps({"rank": self.rank,
                                            "addr": list(self.address)})})

    def _adopt(self, rank: int, sock: socket.socket):
        self._conns[rank] = sock
        self._send_locks[rank] = threading.Lock()
        self._lost.discard(rank)
        threading.Thread(target=self._read_loop, args=(rank, sock),
                         daemon=True,
                         name=f"am{self.rank}-read-{rank}").start()

    def peers(self) -> list[int]:
        """Ranks with a live connection right now."""
        with self._lock:
            return sorted(self._conns)

    # -- messaging ----------------------------------------------------------
    def post(self, rank: int, action: str, payload: Any = None):
        """Fire-and-forget active message: run ``action`` at ``rank``.

        Raises:
            PeerLostError: no live connection to ``rank``.
        """
        self._send(rank, {"kind": "post", "action": action, "seq": 0,
                          "src": self.rank, "payload": dumps(payload)})

    def request(self, rank: int, action: str, payload: Any = None, *,
                timeout: float = 60.0) -> Any:
        """Run ``action`` at ``rank`` and block for its reply.

        Args:
            rank: destination locality.
            action: registered handler name at the destination.
            payload: any picklable value.
            timeout: seconds to wait for the ack.
        Returns:
            The remote handler's return value.
        Raises:
            PeerLostError: the peer died before acking.
            TimeoutError: no ack within ``timeout``.
            Exception: whatever the remote handler raised, re-raised here.
        """
        with self._lock:
            self._seq += 1
            seq = self._seq
            pend = self._pending[seq] = _Pending(rank)
        try:
            self._send(rank, {"kind": "req", "action": action, "seq": seq,
                              "src": self.rank, "payload": dumps(payload)})
            if not pend.event.wait(timeout):
                raise TimeoutError(
                    f"no ack for {action!r} from locality {rank} "
                    f"within {timeout}s")
        finally:
            with self._lock:
                self._pending.pop(seq, None)
        if pend.exc is not None:
            raise pend.exc
        # decode on the caller's thread (never the reader's): an
        # undecodable ack is this request's problem, not the peer's
        try:
            value = loads(pend.raw)
        except Exception as e:  # noqa: BLE001 - surfaced to the caller
            raise RuntimeError(
                f"undecodable ack payload for {action!r} from locality "
                f"{rank}: {e}") from e
        if not pend.ok:
            raise value
        return value

    def _send(self, rank: int, env: dict):
        body = _pack(env)
        for attempt in (0, 1):
            with self._lock:
                sock = self._conns.get(rank)
                lock = self._send_locks.get(rank)
            if sock is None and rank in self.address_book:
                try:
                    self.connect(rank, self.address_book[rank])
                except OSError as e:
                    raise PeerLostError(
                        f"cannot reach locality {rank}: {e}") from e
                with self._lock:
                    sock = self._conns.get(rank)
                    lock = self._send_locks.get(rank)
            if sock is None or lock is None:
                raise PeerLostError(f"no connection to locality {rank}")
            try:
                with lock:
                    sock.sendall(_LEN.pack(len(body)) + body)
            except OSError as e:
                self._drop(rank, sock)
                with self._lock:
                    swapped = self._conns.get(rank) is not None
                if swapped and attempt == 0:
                    # the connection was canonicalized to a different
                    # socket mid-send (concurrent-dial dedupe): retry
                    # once on the surviving one
                    continue
                raise PeerLostError(
                    f"send to locality {rank} failed: {e}") from e
            with self._lock:
                self.bytes_sent += len(body)
            return

    # -- internals ----------------------------------------------------------
    def _accept_loop(self):
        while True:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return                      # listener closed
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            # peer is anonymous until its __ident__ arrives
            threading.Thread(target=self._read_loop, args=(None, sock),
                             daemon=True,
                             name=f"am{self.rank}-read-anon").start()

    def _read_loop(self, rank: Optional[int], sock: socket.socket):
        try:
            while True:
                (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
                env = _unpack(_recv_exact(sock, n))
                with self._lock:
                    self.bytes_recv += n
                if env["action"] == "__ident__":
                    ident = loads(env["payload"])
                    rank = ident["rank"]
                    self.address_book.setdefault(rank,
                                                 tuple(ident["addr"]))
                    loser = None
                    with self._lock:
                        cur = self._conns.get(rank)
                        if cur is None:
                            self._adopt_identified(rank, sock)
                        elif cur is not sock and rank < self.rank:
                            # concurrent bidirectional dial: both sides
                            # converge on the socket dialed by the LOWER
                            # rank (this inbound one here; the peer keeps
                            # its own dial and drops ours when our ident
                            # reaches it) - deterministic, so exactly one
                            # logical connection survives
                            loser = cur
                            self._adopt_identified(rank, sock)
                    if loser is not None:
                        try:
                            loser.close()
                        except OSError:
                            pass
                    continue
                self._dispatch(rank if rank is not None else env.get("src"),
                               sock, env)
        except (ConnectionError, OSError):
            pass
        finally:
            if rank is not None:
                self._drop(rank, sock)

    def _adopt_identified(self, rank: int, sock: socket.socket):
        # adopted from accept: register without spawning another reader
        self._conns[rank] = sock
        self._send_locks[rank] = threading.Lock()
        self._lost.discard(rank)

    def _dispatch(self, src: Optional[int], sock: socket.socket, env: dict):
        kind = env["kind"]
        if kind == "ack":
            with self._lock:
                pend = self._pending.get(env["seq"])
            if pend is not None:
                pend.raw = env["payload"]
                pend.ok = env.get("ok", True)
                pend.event.set()
            return
        handler = self._handlers.get(env["action"])

        def run():
            # decode on the pool, never the reader thread: a large or
            # undecodable payload must not stall (or kill) the connection
            try:
                payload = loads(env["payload"])
            except Exception as e:  # noqa: BLE001 - shipped back as error
                payload, decode_err = None, RuntimeError(
                    f"locality {self.rank}: undecodable payload for "
                    f"{env['action']!r}: {e}")
            else:
                decode_err = None
            if decode_err is not None:
                ok, value = False, decode_err
            elif handler is None:
                err: Any = RuntimeError(
                    f"locality {self.rank}: no handler for "
                    f"{env['action']!r}")
                ok, value = False, err
                if kind == "post":   # a req acks the error back; a post
                    self._note_unhandled(env["action"], src)  # cannot
            else:
                try:
                    ok, value = True, handler(src, payload)
                except BaseException as e:  # noqa: BLE001 - shipped back
                    ok, value = False, e
            if kind == "req" and src is not None:
                try:
                    ack = {"kind": "ack", "seq": env["seq"],
                           "src": self.rank, "action": "",
                           "ok": ok, "payload": dumps(value)}
                    try:
                        self._send(src, ack)
                    except PeerLostError:
                        # an unregistered requester - the dial-in join
                        # handshake posts from src=-1 before it has an
                        # endpoint - gets its ack back over the socket
                        # the request arrived on
                        send_frame(sock, ack)
                        with self._lock:
                            self.bytes_sent += len(ack["payload"])
                except (OSError, pickle.PicklingError, TypeError) as e:
                    # requester is gone or the value is unpicklable; the
                    # reply is undeliverable either way (PHY104)
                    if _san.active():
                        _san.get().record(
                            "PHY104",
                            f"locality {self.rank}: ack for "
                            f"{env['action']!r} to locality {src} "
                            f"dropped: {e}",
                            once_key=f"{self.rank}:{src}:{env['action']}")

        if self._closed:
            return
        self._pool.submit(run)

    def _note_unhandled(self, action: str, src: Optional[int]):
        with self._lock:
            self.unhandled_posts[action] += 1
            first = action not in self._warned_unhandled
            if first:
                self._warned_unhandled.add(action)
        if first:
            log.warning(
                "locality %d: dropped post to unregistered action %r "
                "(from locality %s); further drops to it are counted "
                "in unhandled_posts without logging", self.rank, action,
                src)
        if _san.active():
            _san.get().record(
                "PHY102",
                f"locality {self.rank}: post to unregistered action "
                f"{action!r} (from locality {src})",
                once_key=f"{self.rank}:{action}")

    def _drop(self, rank: int, sock: Optional[socket.socket] = None):
        """Tear down the connection to ``rank``.

        With ``sock`` given, acts only if it IS the registered
        connection: a deduped duplicate socket dying (the loser of a
        concurrent bidirectional dial) must not take the live connection
        - or fire a spurious peer-lost - with it.
        """
        cb = None
        with self._lock:
            cur = self._conns.get(rank)
            if sock is not None and cur is not None and cur is not sock:
                dead = sock            # a duplicate died, not the conn
                fire = False
                pend: list[_Pending] = []
            else:
                self._conns.pop(rank, None)
                self._send_locks.pop(rank, None)
                dead = cur if cur is not None else sock
                fire = (cur is not None and rank not in self._lost
                        and not self._closed)
                if fire:
                    self._lost.add(rank)
                    cb = self.on_peer_lost
                pend = [p for p in self._pending.values()
                        if p.rank == rank]
        if dead is not None:
            try:
                dead.close()
            except OSError:
                pass
        if fire:
            for p in pend:      # fail requests that may be waiting on it
                if not p.event.is_set():
                    p.exc = PeerLostError(f"locality {rank} disconnected")
                    p.event.set()
            if cb is not None:
                self._pool.submit(cb, rank)

    def close(self):
        """Stop accepting, close every connection, drain the handler pool.
        Idempotent; pending requests fail with ``PeerLostError``."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            conns = list(self._conns.items())
            self._conns.clear()
            self._send_locks.clear()
        try:
            self._listener.close()
        except OSError:
            pass
        for _, sock in conns:
            try:
                sock.close()
            except OSError:
                pass
        with self._lock:
            for p in self._pending.values():
                if not p.event.is_set():
                    p.exc = PeerLostError("endpoint closed")
                    p.event.set()
        self._pool.shutdown(wait=False)
