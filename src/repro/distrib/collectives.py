"""Gradient collectives over the active-message fabric (DESIGN.md §11).

True data-parallel training needs an all-reduce that runs on OUR wire -
the TCP active messages of ``messaging.py`` - not on a jax collective
(the CPU backend cannot execute one jit across processes).  This module
provides it as a **ring all-gather with deterministic local combine**:

  * every locality encodes its per-bucket gradient partial with a
    pluggable :class:`GradCodec` and posts one ``grad_ring`` active
    message per bucket to its ring successor;
  * a received segment is stored and *relayed* to the successor until it
    has made ``world - 1`` hops, so after ``world - 1`` relay rounds
    every locality holds every origin's payload;
  * each locality then decodes the contributions and sums them **in
    origin-rank order** - float addition commutes but does not
    associate, so a fixed combine order is what makes every locality
    (and a single-process reference run) produce bit-identical sums.

A reduce-scatter ring would halve the traffic but cannot sum payloads
in the compressed domain (1-bit signs do not add) and sums different
chunks in different rank rotations; the all-gather form keeps the codec
pluggable and the result bitwise reproducible across world sizes.

Codecs (:data:`CODECS`): ``fp32`` ships raw little-endian float32 bucket
bytes (``decode(encode(x))`` is bitwise ``x``); ``onebit`` quantizes
each bucket to sign bits + per-row L1 scales via the
``kernels/onebit.py`` Pallas kernels (interpreter mode on CPU), carrying
the persistent per-locality error-feedback residual of
``optim.compression.init_error_state`` across steps - wire cost drops to
1 bit/element plus one float per 1024 elements (~1/31 of fp32).

Failure model: **abort, never hang**.  A peer lost mid-exchange poisons
the ring (``peer_lost``/``abort``); blocked ``allreduce`` calls raise
``LocalityLostError`` and the driver broadcasts ``ddp_abort`` so
survivors with no direct connection to the dead rank abort too
(``distrib.runtime``).  Re-forming the ring is a policy decision left to
a resume run - consistent with the SPMD save-abort story of §10.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..analysis import sanitize as _san
from .messaging import Endpoint, PeerLostError

__all__ = ["CODECS", "Fp32Codec", "GradCodec", "OneBitCodec",
           "RingAllReduce", "get_codec"]

#: action name of ring segments on the active-message wire
GRAD_RING_ACTION = "grad_ring"


def _lost_error():
    from .runtime import LocalityLostError   # circular at import time only
    return LocalityLostError


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------
class GradCodec:
    """Payload codec for one locality's per-bucket gradient partials.

    A codec turns the fused f32 bucket buffers of a ``FusionPlan``
    (``optim.compression.make_plan``) into wire bytes and back.  It may
    be stateful per locality (the onebit codec owns the error-feedback
    residual); ``reset(plan)`` re-initializes that state at run start.
    ``decode`` must be deterministic - every rank decodes every origin's
    payload with it, and the rank-ordered sum must agree bitwise across
    the world.
    """

    name = "base"

    def reset(self, plan) -> None:
        """(Re-)initialize per-run codec state for ``plan``'s buckets."""

    def encode(self, bufs) -> list[bytes]:
        """f32 bucket buffers -> one wire payload per bucket."""
        raise NotImplementedError

    def decode(self, data: bytes, bucket) -> np.ndarray:
        """One wire payload -> f32[bucket.size] contribution."""
        raise NotImplementedError

    def wire_bytes(self, plan) -> int:
        """Exact payload bytes of one full encode over ``plan`` - the
        number ``grad_wire_bytes`` accounting is asserted against."""
        raise NotImplementedError


class Fp32Codec(GradCodec):
    """Passthrough codec: raw little-endian float32 bucket bytes.

    ``decode(encode(x))`` is bitwise ``x``, which is what makes the
    2-locality fp32 DDP run bit-identical in loss to a single-process
    run over the same batch shards (tests/test_ddp.py parity drill).
    """

    name = "fp32"

    def encode(self, bufs) -> list[bytes]:
        return [np.ascontiguousarray(np.asarray(b, dtype=np.float32))
                .tobytes() for b in bufs]

    def decode(self, data: bytes, bucket) -> np.ndarray:
        return np.frombuffer(data, np.float32)

    def wire_bytes(self, plan) -> int:
        return sum(4 * b.size for b in plan.buckets)


class OneBitCodec(GradCodec):
    """1-bit sign quantization with persistent error feedback.

    Each bucket buffer is viewed as ``[R, 1024]`` (the plan pads buckets
    to a multiple of ``ROW * 32``); the running residual is folded in,
    then the ``kernels/onebit.py`` Pallas kernels (interpreter mode off
    TPU, via ``kernels.ops`` ``impl="interpret"``) produce the packed
    sign bitmap, per-row L1 scales, and the new residual.  Wire format
    per bucket: ``size/8`` bytes of little-endian uint32 sign words,
    then ``R`` little-endian float32 scales.  The residual lives on this
    locality only - it is never exchanged or checkpointed, and resets
    with ``reset`` at run (or resume) start.
    """

    name = "onebit"

    def __init__(self):
        self._err: list = []

    def reset(self, plan) -> None:
        from ..optim import compression
        self._err = compression.init_error_state(plan)

    def encode(self, bufs) -> list[bytes]:
        from ..kernels import ops
        from ..optim.compression import ROW
        out = []
        for i, buf in enumerate(bufs):
            g2d = jnp.reshape(jnp.asarray(buf, jnp.float32), (-1, ROW))
            packed, scale, self._err[i] = ops.onebit_quantize(
                g2d, self._err[i], block_rows=g2d.shape[0],
                impl="interpret")
            # the kernel returns scales lane-replicated [R, 128]; one
            # column is the wire form
            out.append(np.asarray(packed).tobytes()
                       + np.asarray(scale[:, :1]).tobytes())
        return out

    def decode(self, data: bytes, bucket) -> np.ndarray:
        from ..kernels import ops
        from ..optim.compression import ROW
        rows = bucket.size // ROW
        nb = rows * (ROW // 32) * 4
        packed = np.frombuffer(data[:nb], np.uint32).reshape(rows, ROW // 32)
        scale = np.frombuffer(data[nb:], np.float32).reshape(rows, 1)
        deq = ops.onebit_dequantize(
            jnp.asarray(packed),
            jnp.broadcast_to(jnp.asarray(scale), (rows, 128)),
            block_rows=rows, impl="interpret")
        return np.asarray(deq).reshape(-1)

    def wire_bytes(self, plan) -> int:
        from ..optim.compression import ROW
        return sum(b.size // 8 + 4 * (b.size // ROW) for b in plan.buckets)


CODECS: dict[str, type] = {Fp32Codec.name: Fp32Codec,
                           OneBitCodec.name: OneBitCodec}


def get_codec(name: str) -> GradCodec:
    """A fresh codec instance by name (``fp32`` | ``onebit``).

    Raises:
        ValueError: unknown codec name.
    """
    try:
        return CODECS[name]()
    except KeyError:
        raise ValueError(f"unknown grad codec {name!r} "
                         f"(have: {sorted(CODECS)})") from None


# ---------------------------------------------------------------------------
# Ring all-reduce
# ---------------------------------------------------------------------------
class RingAllReduce:
    """Chunked ring all-reduce of gradient buckets as active messages.

    One instance lives on each locality's endpoint for the process
    lifetime (``Locality``/``DistributedGraph`` construct it so the
    ``grad_ring`` handler exists before any peer can send - posts to an
    unregistered action are dropped, counted in ``unhandled_posts`` and
    warned about, but never delivered late).  ``configure`` arms it
    for one DDP run: it picks the codec, resets codec state, bumps the
    generation (stale segments of an aborted earlier run are dropped by
    generation), and zeroes the per-run ``wire_bytes`` counter.

    Args:
        endpoint: this locality's active-message endpoint (None is
            allowed when ``world == 1`` - nothing crosses the wire).
        world: ring size = total locality count, driver included.
        account: optional callback receiving payload byte counts as they
            are sent (the driver wires this to
            ``DistributedGraph.account_grad_wire_bytes``).
    """

    def __init__(self, endpoint: Optional[Endpoint], world: int, *,
                 account: Optional[Callable[[int], None]] = None):
        self.endpoint = endpoint
        self.world = max(int(world), 1)
        self.rank = endpoint.rank if endpoint is not None else 0
        self.account = account
        self.wire_bytes = 0          # payload bytes sent this run
        self._codec: Optional[GradCodec] = None
        self._plan = None
        self._gen = 0
        self._active = False
        self._dead: Optional[str] = None
        self._cond = threading.Condition()
        # (gen, step, origin, bucket) -> (payload bytes, meta | None)
        self._inbox: dict[tuple, tuple] = {}
        if endpoint is not None:
            endpoint.register(GRAD_RING_ACTION, self._on_seg)

    @property
    def active(self) -> bool:
        """True between ``configure`` and ``deactivate`` - peer loss only
        poisons an active ring."""
        return self._active

    @property
    def gen(self) -> int:
        """Current run generation (segments of earlier gens are dropped)."""
        return self._gen

    # -- run lifecycle -------------------------------------------------------
    def configure(self, codec_name: str, plan, *,
                  gen: Optional[int] = None) -> GradCodec:
        """Arm the ring for one DDP run.

        Args:
            codec_name: a :data:`CODECS` key (``fp32`` | ``onebit``).
            plan: the run's gradient ``FusionPlan`` (every rank must
                build the identical plan from the same ``Plan``).
            gen: explicit generation.  The driver configures first and
                ships its generation in the ``ddp_train`` spec so every
                ring keys segments identically - even a ring on a
                freshly respawned locality, whose local counter restarts
                at 0.  None increments the local counter (driver use).
        Returns:
            The codec instance (with freshly-reset state).
        """
        codec = get_codec(codec_name)
        codec.reset(plan)
        with self._cond:
            if gen is not None and int(gen) < self._gen and _san.active():
                # a regressed generation would resurrect stale inbox
                # segments this ring already agreed to drop (PHY103)
                _san.get().record(
                    "PHY103",
                    f"rank {self.rank}: ring generation regressed "
                    f"{self._gen} -> {int(gen)} in configure()",
                    once_key=f"{self.rank}:{self._gen}:{gen}")
            self._gen = int(gen) if gen is not None else self._gen + 1
            gen = self._gen
            self._inbox = {k: v for k, v in self._inbox.items()
                           if k[0] >= gen}
            self._codec, self._plan = codec, plan
            self._dead = None
            self._active = True
            self.wire_bytes = 0
            self._cond.notify_all()
        return codec

    def deactivate(self):
        """Disarm after a run: later peer losses (normal teardown) no
        longer poison the ring."""
        with self._cond:
            self._active = False

    def abort(self, reason: str):
        """Poison the ring: blocked and future ``allreduce`` calls of
        this generation raise ``LocalityLostError(reason)``."""
        with self._cond:
            if not self._active or self._dead is not None:
                return
            self._dead = str(reason)
            self._cond.notify_all()

    def peer_lost(self, rank: int):
        """Endpoint peer-loss hook: abort the step if a run is active."""
        if self._active:
            self.abort(f"locality {rank} died mid-all-reduce; "
                       f"the step aborted (DESIGN.md §11 failure model)")

    # -- the collective ------------------------------------------------------
    def allreduce(self, step: int, bufs, meta: Any = None, *,
                  timeout: float = 300.0):
        """Sum ``bufs`` (this rank's f32 bucket partials) across the ring.

        Every contribution - this rank's included - passes through the
        codec (``decode(encode(...))``), and the per-bucket sum is
        accumulated in origin-rank order 0..world-1, so all localities
        compute bitwise-identical totals.  The caller divides by its
        shard count; this method only sums.

        Args:
            step: monotone step index (keys segment matching).
            bufs: list of 1-D f32 buffers, one per plan bucket.
            meta: small picklable sidecar (e.g. the shard loss) carried
                on the bucket-0 segment; NOT counted as gradient wire
                bytes.
            timeout: seconds to wait for the other ranks' segments.
        Returns:
            ``(summed_bufs, metas)`` - the rank-ordered per-bucket sums
            (np.float32) and ``{origin_rank: meta}``.
        Raises:
            LocalityLostError: a peer died mid-exchange (ring poisoned).
            TimeoutError: segments missing after ``timeout``.
            RuntimeError: the ring was never ``configure``d.
        """
        with self._cond:
            if self._codec is None:
                raise RuntimeError("RingAllReduce.configure must run "
                                   "before allreduce")
            codec, plan, gen = self._codec, self._plan, self._gen
        payloads = codec.encode(bufs)
        if self.world > 1:
            assert self.endpoint is not None  # world > 1 requires a fabric
            succ = (self.rank + 1) % self.world
            for i, data in enumerate(payloads):
                try:
                    self.endpoint.post(succ, GRAD_RING_ACTION, {
                        "gen": gen, "step": int(step), "origin": self.rank,
                        "hop": 1, "bucket": i, "data": data,
                        "meta": meta if i == 0 else None})
                except PeerLostError as e:
                    self.abort(f"locality {succ} died mid-all-reduce "
                               f"at step {step}: {e}")
                    break
                self._count(len(data))
            need = [(gen, int(step), o, i)
                    for o in range(self.world) if o != self.rank
                    for i in range(len(payloads))]
            with self._cond:
                ok = self._cond.wait_for(
                    lambda: self._dead is not None
                    or all(k in self._inbox for k in need),
                    timeout)
                if self._dead is not None:
                    raise _lost_error()(
                        f"all-reduce at step {step}: {self._dead}")
                if not ok:
                    missing = [k for k in need if k not in self._inbox]
                    raise TimeoutError(
                        f"all-reduce at step {step}: {len(missing)} "
                        f"segment(s) missing after {timeout}s "
                        f"(first: origin {missing[0][2]} bucket "
                        f"{missing[0][3]})")
                got = {o: [self._inbox.pop((gen, int(step), o, i))
                           for i in range(len(payloads))]
                       for o in range(self.world) if o != self.rank}
        else:
            got = {}
        acc: list = [None] * len(payloads)
        metas: dict[int, Any] = {}
        for origin in range(self.world):          # fixed combine order
            if origin == self.rank:
                datas, metas[origin] = payloads, meta
            else:
                datas = [d for d, _ in got[origin]]
                metas[origin] = got[origin][0][1]
            for i, data in enumerate(datas):
                dec = codec.decode(data, plan.buckets[i])
                acc[i] = dec.copy() if acc[i] is None else acc[i] + dec
        return acc, metas

    # -- wire handler --------------------------------------------------------
    def _on_seg(self, src: int, msg: dict):
        key = (msg["gen"], msg["step"], msg["origin"], msg["bucket"])
        with self._cond:
            if msg["gen"] < self._gen:
                return                             # stale run: drop
            self._inbox[key] = (msg["data"], msg.get("meta"))
            self._cond.notify_all()
        if msg["hop"] < self.world - 1:            # relay around the ring
            assert self.endpoint is not None  # world > 1 requires a fabric
            succ = (self.rank + 1) % self.world
            fwd = dict(msg, hop=msg["hop"] + 1)
            try:
                self.endpoint.post(succ, GRAD_RING_ACTION, fwd)
            except PeerLostError as e:
                self.abort(f"locality {succ} died relaying step "
                           f"{msg['step']}: {e}")
                return
            self._count(len(msg["data"]))

    def _count(self, n: int):
        with self._cond:
            self.wire_bytes += int(n)
        if self.account is not None:
            self.account(int(n))
