"""Multi-locality scheduling: ``Locality`` workers + ``DistributedGraph``.

This is the paper's HPX mapping carried across process boundaries
(DESIGN.md §9).  A *locality* is one Python process with its own
``FuturizedGraph``; the driver (rank 0) holds a ``DistributedGraph``
whose ``defer`` mirrors the local one but may place the task on any
locality:

  * **Placement = lane + data affinity.**  Explicit ``locality=`` wins;
    otherwise tasks whose arguments hold remote futures / ``RemoteRef``s
    go to the majority owner (derefs become local dictionary hits), and
    everything else round-robins over the worker localities per lane -
    so PREFETCH and CHECKPOINT streams interleave fairly instead of
    convoying on one worker.
  * **Futures span the wire.**  ``defer`` returns an ordinary
    ``PhyFuture`` (a promise node of the driver's graph); a dispatch
    node waits for the task's *local* dependency edges, then ships
    ``(fn, resolved args)`` in a ``spawn`` active message.  The worker
    defers it onto its own graph and streams the result back in a
    ``task_done`` post as soon as it resolves - fulfilling the promise,
    which releases the driver-side dependents through the normal edge
    machinery.  Errors come back as the original exception and poison
    exactly the transitive dependents; cancellation crosses the wire in
    both directions.
  * **Failure model: re-create, not migrate.**  When a worker dies, its
    in-flight idempotent tasks are re-spawned on a surviving locality
    (or run on the driver when none is left); tasks holding refs owned
    by the dead locality - state that died with it - are poisoned with
    ``LocalityLostError`` instead.  This extends the elastic-restart
    story of ``examples/elastic_restart.py`` to locality loss *without*
    a checkpoint round-trip.

Task functions must be picklable (module-level functions or bound
methods of picklable objects); closures raise a clear error at dispatch
time.  ``jax`` state never crosses the wire: workers build host values
(numpy), the driver does all ``device_put``/dispatch.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import multiprocessing as mp
import os
import pickle
import threading
import time
from concurrent.futures import CancelledError
from typing import Any, Callable, Optional

import jax

from ..core.futures import FuturizedGraph, Lane, PhyFuture
from ..core.resilience import tree_checksum
from .agas import ObjectDirectory, RemoteRef
from .collectives import RingAllReduce
from .messaging import Endpoint, PeerLostError

__all__ = ["DistributedGraph", "Locality", "LocalityGroup",
           "LocalityLostError", "RemoteTaskError", "worker_main"]


class RemoteTaskError(RuntimeError):
    """A remote task failed and its exception could not be shipped back
    verbatim (unpicklable); carries the remote repr instead."""


class LocalityLostError(RuntimeError):
    """A task (or data it needed) was lost with its locality and could
    not be re-created elsewhere."""


def _is_ref(x) -> bool:
    return isinstance(x, RemoteRef)


def _deref_tree(argskw, directory: ObjectDirectory):
    """Replace every ``RemoteRef`` leaf with its value (local hit on the
    owner, one AGAS fetch otherwise)."""
    return jax.tree.map(
        lambda x: directory.fetch(x) if _is_ref(x) else x,
        argskw, is_leaf=_is_ref)


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------
class Locality:
    """One worker process of the multi-locality runtime.

    Owns an ``Endpoint`` (active messages), a ``FuturizedGraph`` (local
    lanes + workers), and an ``ObjectDirectory`` (this locality's slice
    of the address space).  ``serve`` registers the task handlers and
    blocks until a ``shutdown`` message (or loss of the driver).

    Args:
        rank: this locality's rank (>= 1 for spawned workers).
        world: total locality count, driver included.
        max_workers: local graph worker threads.
    """

    def __init__(self, rank: int, world: int, *, max_workers: int = 2):
        self.rank = rank
        self.world = world
        self.endpoint = Endpoint(rank)
        self.graph = FuturizedGraph(max_workers=max_workers,
                                    name=f"locality{rank}")
        self.directory = ObjectDirectory(rank, self.endpoint)
        self._tasks: dict[str, PhyFuture] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        ep = self.endpoint
        ep.register("spawn", self._on_spawn)
        ep.register("cancel", self._on_cancel)
        ep.register("peers", self._on_peers)
        ep.register("shutdown", lambda src, p: self._stop.set())
        ep.register("ping", lambda src, p: p)
        ep.register("stats", self._on_stats)
        ep.register("spmd_train", self._on_spmd_train)
        # the ring registers its own "grad_ring" handler: it must exist
        # BEFORE any peer can send (posts to an unregistered action are
        # dropped - counted and warned, never delivered late), so it is
        # born with the locality
        self.grad_ring = RingAllReduce(ep, world)
        ep.register("ddp_train", self._on_ddp_train)
        ep.register("ddp_abort",
                    lambda src, reason: self.grad_ring.abort(reason))
        ep.on_peer_lost = self._on_peer_lost

    # -- handlers ------------------------------------------------------------
    def _on_spawn(self, src: int, p: dict):
        node = self.graph.defer(self._run, p["fn"], p["args"], p["kwargs"],
                                lane=Lane(p["lane"]), name=p["name"])
        with self._lock:
            self._tasks[p["tid"]] = node
        node.add_done_callback(
            lambda n, tid=p["tid"], pin=p["pin"], src=src:
            self._report(src, tid, pin, n))

    def _run(self, fn, args, kwargs):
        a, kw = _deref_tree((args, kwargs), self.directory)
        return fn(*a, **kw)

    def _report(self, src: int, tid: str, pin: bool, node: PhyFuture):
        with self._lock:
            self._tasks.pop(tid, None)
        exc = node.exception()
        if exc is None:
            value = node.result()
            if pin:
                value = self.directory.put(value, summary=node.name)
            msg = {"tid": tid, "status": "ok", "value": value}
        elif isinstance(exc, CancelledError):
            msg = {"tid": tid, "status": "cancelled"}
        else:
            msg = {"tid": tid, "status": "error", "exc": exc}
        # serialize exactly once: post() pickles the message before any
        # bytes hit the socket, so a pickling failure here is recoverable
        # and we retry with a shippable error instead
        try:
            self.endpoint.post(src, "task_done", msg)
            return
        except PeerLostError:
            return                  # driver is gone; nobody to tell
        except Exception as e:  # noqa: BLE001 - unshippable value/exc
            msg = {"tid": tid, "status": "error",
                   "exc": RemoteTaskError(
                       f"{node.name}: result not shippable ({e}); "
                       f"pin large/custom values with pin=True")}
        try:
            self.endpoint.post(src, "task_done", msg)
        except PeerLostError:
            pass

    def _on_cancel(self, src: int, tid: str):
        with self._lock:
            node = self._tasks.get(tid)
        if node is not None:
            node.cancel()

    def _on_peers(self, src: int, book: dict):
        self.endpoint.address_book.update(
            {int(r): tuple(a) for r, a in book.items()})

    def _on_stats(self, src: int, p) -> dict:
        out = self.graph.stats().to_json()
        out["directory_objects"] = len(self.directory)
        out["directory_audit"] = self.directory.audit()
        out["bytes_sent"] = self.endpoint.bytes_sent
        out["bytes_recv"] = self.endpoint.bytes_recv
        out["unhandled_posts"] = dict(self.endpoint.unhandled_posts)
        return out

    def _on_peer_lost(self, rank: int):
        self.grad_ring.peer_lost(rank)   # abort a blocked all-reduce
        if rank == 0:               # driver died: nothing left to serve
            self._stop.set()

    def _on_spmd_train(self, src: int, spec: dict):
        """Run the SPMD shadow train loop (DESIGN.md §10) on its own
        thread: this locality mirrors the driver's device computation
        in lockstep and writes its own addressable checkpoint shards,
        posting back only the manifest entries.  Completion (or the
        failure) is reported via a ``spmd_done`` post."""
        def run():
            try:
                from ..frontend.spmd import shadow_train
                step = shadow_train(spec, endpoint=self.endpoint)
                msg = {"rank": self.rank, "ok": True, "step": step}
            except BaseException as e:  # noqa: BLE001 - shipped back
                msg = {"rank": self.rank, "ok": False,
                       "error": f"{type(e).__name__}: {e}"}
            try:
                self.endpoint.post(src, "spmd_done", msg)
            except PeerLostError:
                pass
        threading.Thread(target=run, daemon=True,
                         name=f"spmd-shadow-{self.rank}").start()

    def _on_ddp_train(self, src: int, spec: dict):
        """Run the fabric-DDP train loop (DESIGN.md §11) on its own
        thread: this locality computes gradients for its shard block,
        all-reduces them over the ring, and applies the identical
        optimizer step.  Completion - and the locality's
        ``grad_wire_bytes`` - is reported via a ``ddp_done`` post."""
        def run():
            try:
                from ..frontend.ddp import ddp_shadow_train
                out = ddp_shadow_train(spec, endpoint=self.endpoint,
                                       ring=self.grad_ring)
                msg = dict(out, rank=self.rank, ok=True)
            except BaseException as e:  # noqa: BLE001 - shipped back
                msg = {"rank": self.rank, "ok": False,
                       "grad_wire_bytes": int(self.grad_ring.wire_bytes),
                       "error": f"{type(e).__name__}: {e}"}
            try:
                self.endpoint.post(src, "ddp_done", msg)
            except PeerLostError:
                pass
        threading.Thread(target=run, daemon=True,
                         name=f"ddp-{self.rank}").start()

    # -- lifecycle -----------------------------------------------------------
    def serve(self, driver_addr: tuple[str, int]):
        """Connect to the driver, announce ourselves, and serve active
        messages until shut down (blocking)."""
        self.endpoint.address_book[0] = tuple(driver_addr)
        self.endpoint.connect(0, tuple(driver_addr))
        self.endpoint.request(0, "hello",
                              {"rank": self.rank,
                               "addr": list(self.endpoint.address)})
        self._stop.wait()
        self.graph.shutdown(wait=True, cancel_pending=True)
        self.endpoint.close()


def worker_main(rank: int, world: int, driver_addr, env: Optional[dict] = None):
    """Spawned-process entry point: become locality ``rank`` and serve.

    ``env`` entries are exported before any device work so spawn-time
    configuration (e.g. ``PHYRAX_JAX_COORDINATOR``) lands in the child;
    ``launch.mesh.maybe_init_jax_distributed`` is then given a chance to
    initialize ``jax.distributed`` (a no-op unless configured).

    ``PHYRAX_LOCALITY_RANK`` is always exported, so locality-owned work
    records its executing rank (checkpoint shard entries name their
    actual writer - DESIGN.md §10); when the session forwards a
    checkpoint directory as ``PHYRAX_CKPT_DIR``, it is created here at
    spawn, so a misconfigured or unwritable checkpoint mount fails the
    worker immediately (surfacing at ``LocalityGroup`` startup) instead
    of mid-training at the first shard write.
    """
    for k, v in (env or {}).items():
        os.environ[k] = v
    os.environ["PHYRAX_LOCALITY_RANK"] = str(rank)
    ckpt_dir = os.environ.get("PHYRAX_CKPT_DIR")
    if ckpt_dir:
        os.makedirs(ckpt_dir, exist_ok=True)
    from ..launch.mesh import maybe_init_jax_distributed

    spmd = maybe_init_jax_distributed(process_id=rank, num_processes=world)
    if spmd:
        # the multi-process CPU backend exchanges local topologies over
        # the coordination service: every process must CREATE its
        # backend before any of them can.  Warm ours on a thread so
        # serve() (and the hello the driver is waiting on) is not gated
        # on the driver reaching its own first jax call.
        def _warm():
            try:
                jax.local_devices()
            except Exception:  # noqa: BLE001 - surfaces at first jax use
                pass
        threading.Thread(target=_warm, daemon=True,
                         name=f"jax-backend-warm-{rank}").start()
    Locality(rank, world).serve(tuple(driver_addr))
    if spmd:
        # coordinated teardown: the jax.distributed shutdown barrier
        # needs every process; the driver joins it in Session.close
        try:
            jax.distributed.shutdown()
        except Exception:  # noqa: BLE001 - best-effort on the way out
            pass


# ---------------------------------------------------------------------------
# Driver side
# ---------------------------------------------------------------------------
class LocalityGroup:
    """Driver-side handle on the spawned worker localities.

    Spawns ``n_workers`` processes (ranks 1..n) via
    ``multiprocessing.spawn``, waits for each to report in, then
    broadcasts the address book so workers can reach each other (AGAS
    fetches).  ``kill`` is the failure-drill seam.

    Args:
        n_workers: worker process count (world size is ``n_workers + 1``).
        worker_env: extra environment for the children (exported before
            jax device setup in the child).
        start_timeout: seconds to wait for all workers to report in.
    """

    def __init__(self, n_workers: int, *,
                 worker_env: Optional[dict] = None,
                 start_timeout: float = 120.0):
        self.endpoint = Endpoint(0)
        self.world = n_workers + 1
        self._addrs: dict[int, tuple[str, int]] = {}
        self._alive: set[int] = set()
        self._cond = threading.Condition()
        self.endpoint.register("hello", self._on_hello)
        ctx = mp.get_context("spawn")
        self.procs: dict[int, Any] = {}
        for rank in range(1, self.world):
            p = ctx.Process(
                target=worker_main, daemon=True,
                args=(rank, self.world, tuple(self.endpoint.address),
                      worker_env))
            p.start()
            self.procs[rank] = p
        with self._cond:
            ok = self._cond.wait_for(
                lambda: len(self._addrs) == n_workers, start_timeout)
        if not ok:
            missing = sorted(set(self.procs) - set(self._addrs))
            self.shutdown()
            raise TimeoutError(
                f"localities {missing} did not report in within "
                f"{start_timeout}s")
        book = {r: list(a) for r, a in self._addrs.items()}
        book[0] = list(self.endpoint.address)
        self.endpoint.address_book.update(
            {r: tuple(a) for r, a in self._addrs.items()})
        for rank in sorted(self._addrs):
            self.endpoint.post(rank, "peers", book)

    def _on_hello(self, src: int, p: dict):
        with self._cond:
            self._addrs[p["rank"]] = tuple(p["addr"])
            self._alive.add(p["rank"])
            self._cond.notify_all()

    # -- liveness ------------------------------------------------------------
    def alive_workers(self) -> list[int]:
        """Worker ranks believed alive (updated on connection loss)."""
        with self._cond:
            return sorted(self._alive)

    def note_lost(self, rank: int):
        with self._cond:
            self._alive.discard(rank)

    def kill(self, rank: int):
        """SIGKILL a worker - the locality-loss drill.  The death is
        observed through its connection, same as a real crash."""
        proc = self.procs.get(rank)
        if proc is not None and proc.is_alive():
            proc.kill()
        self.note_lost(rank)

    def shutdown(self, join_timeout: float = 10.0):
        """Ask every live worker to exit, then reap the processes and
        close the endpoint.  Idempotent."""
        for rank in self.alive_workers():
            try:
                self.endpoint.post(rank, "shutdown")
            except PeerLostError:
                pass
        for rank, proc in self.procs.items():
            proc.join(timeout=join_timeout)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=5.0)
        self.endpoint.close()


@dataclasses.dataclass
class _TaskRecord:
    tid: str
    name: str
    lane: Lane
    fn: Callable
    pin: bool
    idempotent: bool
    target: int
    promise: PhyFuture
    payload: Optional[tuple] = None     # (args, kwargs) resolved at dispatch
    sent: bool = False
    # serializes target/sent mutation between the dispatching thread and
    # a concurrent peer-loss respawn (no double-spawn on two localities)
    lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock)


class DistributedGraph:
    """The driver's view of the multi-locality futurized graph.

    Wraps a local ``FuturizedGraph`` (usually the session runtime) and a
    ``LocalityGroup``; ``defer`` mirrors ``FuturizedGraph.defer`` but
    may place the task on any locality, returning a promise-backed
    ``PhyFuture`` that resolves when the remote result streams back.

    Args:
        localities: total process count, driver included; 1 means no
            workers are spawned and every task runs locally.
        graph: the local graph promises live on (owned by the caller);
            one is created - and shut down with this object - if None.
        worker_env: forwarded to ``LocalityGroup``.
        name: display name for an internally-created graph.
    """

    PIN_NONE = 0

    def __init__(self, localities: int = 1, *,
                 graph: Optional[FuturizedGraph] = None,
                 worker_env: Optional[dict] = None,
                 name: str = "distrib"):
        self.localities = localities
        self._own_graph = graph is None
        self._graph = graph if graph is not None else FuturizedGraph(
            max_workers=4, name=name)
        self.group = LocalityGroup(max(0, localities - 1),
                                   worker_env=worker_env)
        self.endpoint = self.group.endpoint
        self.directory = ObjectDirectory(0, self.endpoint)
        self.endpoint.register("task_done", self._on_task_done)
        self.endpoint.register("ckpt_entries", self._on_ckpt_entries)
        self.endpoint.register("spmd_done", self._on_spmd_done)
        self.endpoint.register("ddp_done", self._on_ddp_done)
        self.endpoint.on_peer_lost = self._on_peer_lost
        self._outstanding: dict[str, _TaskRecord] = {}
        self._by_future: dict[int, _TaskRecord] = {}   # id(promise) -> rec
        self._lock = threading.Condition()
        self._tid = itertools.count()
        self._rr = {lane: itertools.count() for lane in Lane}
        self.dispatched = collections.Counter()        # per-locality sends
        self.respawned = 0
        # checkpoint leaf bytes shipped in save payloads (host-copy
        # mode); the SPMD regression test asserts this stays 0 there
        self.ckpt_leaf_wire_bytes = 0
        # gradient payload bytes the DRIVER sent over the ring (its own
        # encodes + relays); the DDP wire test asserts the exact codec
        # formula against this
        self.grad_wire_bytes = 0
        # the driver is ring rank 0; born here for the same
        # register-before-anyone-sends reason as on the Locality side
        self.grad_ring = RingAllReduce(self.endpoint, localities,
                                       account=self.account_grad_wire_bytes)
        self._ddp_done: dict[int, dict] = {}
        # (step, rank) -> entry promise (save registered first) or the
        # buffered entry value (the worker's post arrived first)
        self._spmd_entries: dict[tuple[int, int], Any] = {}
        self._spmd_done: dict[int, dict] = {}
        self._closed = False

    @property
    def graph(self) -> FuturizedGraph:
        """The local ``FuturizedGraph`` distributed promises live on
        (the session runtime when this object was built by a
        ``Session``).  Anything that chains futures onto distributed
        results - e.g. ``CheckpointManager``'s manifest commit - must
        defer onto this graph."""
        return self._graph

    # -- placement -----------------------------------------------------------
    def _pick(self, lane: Lane, argskw, locality: Optional[int]) -> int:
        alive = self.group.alive_workers()
        if locality is not None:
            if locality != 0 and locality not in alive:
                raise ValueError(f"locality {locality} is not alive "
                                 f"(workers: {alive})")
            return locality
        homes: collections.Counter = collections.Counter()
        for leaf in jax.tree.leaves(
                argskw, is_leaf=lambda x: isinstance(x, (PhyFuture,
                                                         RemoteRef))):
            if isinstance(leaf, PhyFuture) and leaf.home is not None:
                if leaf.home == 0 or leaf.home in alive:
                    homes[leaf.home] += 1
            elif isinstance(leaf, RemoteRef):
                if leaf.owner == 0 or leaf.owner in alive:
                    homes[leaf.owner] += 1
        if homes:
            return homes.most_common(1)[0][0]
        if not alive:
            return 0
        return alive[next(self._rr[lane]) % len(alive)]

    # -- task construction ----------------------------------------------------
    def defer(self, fn: Callable, *args, lane: Lane = Lane.COMPUTE,
              name: str = "", locality: Optional[int] = None,
              pin: bool = False, idempotent: bool = True,
              **kwargs) -> PhyFuture:
        """Place ``fn(*args, **kwargs)`` on a locality and return its
        future.

        Args:
            fn: a *picklable* callable (module-level function or bound
                method of a picklable object) for remote placement.
            *args, **kwargs: arguments; local ``PhyFuture`` leaves become
                dependency edges resolved before dispatch, ``RemoteRef``
                leaves are dereferenced at the executing locality.
            lane: priority lane at the executing locality (and the
                round-robin stream the task joins here).
            name: display name; the future is ``name@L<rank>``.
            locality: pin placement to a rank (0 = the driver).
            pin: keep the result in the executing locality's directory
                and resolve the future with a ``RemoteRef`` instead of
                shipping the value back.
            idempotent: safe to re-run on another locality if the
                original dies; False poisons the future on loss instead.
        Returns:
            A ``PhyFuture`` (with ``home`` set to the chosen rank) that
            resolves with the result (or the ``RemoteRef`` when pinned).
        Raises:
            ValueError: ``locality`` names a dead worker.
        """
        if self._closed:
            raise RuntimeError("distributed graph is shut down")
        name = name or getattr(fn, "__name__", "task")
        target = self._pick(lane, (args, kwargs), locality)
        if target == 0:
            node = self._graph.defer(
                _LocalCall(fn, self.directory, pin=pin, summary=name),
                *args, lane=lane, name=f"{name}@L0", **kwargs)
            node.home = 0
            return node
        tid = f"t{next(self._tid)}"
        promise = self._graph.promise(name=f"{name}@L{target}", lane=lane,
                                      producer=f"L{target}")
        promise.home = target
        rec = _TaskRecord(tid=tid, name=name, lane=lane, fn=fn, pin=pin,
                          idempotent=idempotent, target=target,
                          promise=promise)
        with self._lock:
            self._outstanding[tid] = rec
            self._by_future[id(promise)] = rec
        # the dispatch node carries the task's local dependency edges;
        # once they resolve it ships (fn, resolved args) to the target
        try:
            send = self._graph.defer(self._dispatch, tid, (args, kwargs),
                                     lane=lane, name=f"send:{name}")
        except BaseException as e:   # e.g. cross-graph dependency: settle
            self._finish(rec, exc=e)  # the promise or barrier hangs on it
            raise
        # a dispatch node that terminates WITHOUT sending (poisoned by an
        # upstream edge, or cancelled) must settle the promise too, or it
        # would strand forever and hang barrier/shutdown
        send.add_done_callback(lambda n: self._on_dispatch_done(rec, n))
        return promise

    def _on_dispatch_done(self, rec: _TaskRecord, node: PhyFuture):
        with rec.lock:
            if rec.sent:
                return                   # task_done will settle it
        with self._lock:
            if rec.tid not in self._outstanding:
                return
        exc = node.exception()
        if exc is not None:
            self._finish(rec, exc=exc,
                         cancelled=isinstance(exc, CancelledError))
        elif rec.promise.done():         # cancelled before dispatch ran
            self._finish(rec, exc=CancelledError(rec.name), cancelled=True)

    def fetch(self, ref: RemoteRef, **kw) -> Any:
        """Deref a ``RemoteRef`` from the driver (see
        ``ObjectDirectory.fetch``)."""
        return self.directory.fetch(ref, **kw)

    def cancel(self, fut: PhyFuture) -> bool:
        """Cancel a distributed future: locally at once (dependents are
        poisoned through the normal edges) and, if already dispatched,
        best-effort at the executing locality so queued work is shed.

        Returns:
            The local ``PhyFuture.cancel`` result (False once resolved).
        """
        with self._lock:
            rec = self._by_future.get(id(fut))
        out = fut.cancel()
        if rec is not None and rec.sent:
            try:
                self.endpoint.post(rec.target, "cancel", rec.tid)
            except PeerLostError:
                pass
        return out

    # -- resilience across localities ----------------------------------------
    def replicate(self, fn: Callable, *args, n: int = 2,
                  lane: Lane = Lane.COMPUTE, name: str = "",
                  **kwargs) -> PhyFuture:
        """HPX task replication across localities: run ``fn`` on ``n``
        *distinct* localities and vote by checksum (``core.resilience``),
        so silent corruption on one locality is outvoted by the others.

        Returns:
            A future of the majority result.
        Raises:
            ValueError: fewer than ``n`` distinct localities exist.
        """
        name = name or getattr(fn, "__name__", "task")
        domain = self.group.alive_workers() + [0]
        if len(domain) < n:
            raise ValueError(f"replicate(n={n}) needs {n} localities, "
                             f"have {len(domain)}")
        futs = [self.defer(fn, *args, lane=lane, locality=domain[i],
                           name=f"{name}!r{i}", **kwargs) for i in range(n)]
        return self._graph.defer(_checksum_vote, *futs, lane=lane,
                                 name=f"{name}!vote")

    # -- dispatch internals ---------------------------------------------------
    def _dispatch(self, tid: str, argskw):
        with self._lock:
            rec = self._outstanding.get(tid)
        if rec is None or rec.promise.done():
            return                           # cancelled before dispatch
        rec.payload = argskw                 # futures already substituted
        try:
            self._send_spawn(rec)
        except BaseException as e:  # noqa: BLE001 - a stranded promise
            self._finish(rec, exc=e)         # would hang barrier/shutdown
            raise

    def _send_spawn(self, rec: _TaskRecord):
        assert rec.payload is not None  # _dispatch resolved it before sending
        args, kwargs = rec.payload
        with rec.lock:   # one spawner at a time: dispatch vs peer-loss
            while True:
                if rec.target != 0 \
                        and rec.target not in self.group.alive_workers():
                    rec.target = self._fallback(rec.lane)
                if rec.target == 0:
                    self._run_local(rec)
                    return
                try:
                    self.endpoint.post(rec.target, "spawn", {
                        "tid": rec.tid, "name": rec.name,
                        "lane": int(rec.lane), "pin": rec.pin,
                        "fn": rec.fn, "args": args, "kwargs": kwargs})
                except PeerLostError:
                    self.group.note_lost(rec.target)
                    continue
                except (pickle.PicklingError, AttributeError, TypeError) as e:
                    self._finish(rec, exc=RemoteTaskError(
                        f"{rec.name}: not picklable for remote spawn ({e}); "
                        f"use a module-level function and picklable args"))
                    return
                rec.sent = True
                rec.promise.home = rec.target
                with self._lock:
                    self.dispatched[rec.target] += 1
                return

    def _fallback(self, lane: Lane) -> int:
        alive = self.group.alive_workers()
        if not alive:
            return 0
        return alive[next(self._rr[lane]) % len(alive)]

    def _run_local(self, rec: _TaskRecord):
        assert rec.payload is not None  # _dispatch resolved it before sending
        node = self._graph.defer(
            _LocalCall(rec.fn, self.directory, pin=rec.pin,
                       summary=rec.name),
            *rec.payload[0], lane=rec.lane,
            name=f"{rec.name}@L0", **rec.payload[1])
        rec.promise.home = 0
        with self._lock:
            self.dispatched[0] += 1
        node.add_done_callback(lambda n: self._transfer(rec, n))

    def _transfer(self, rec: _TaskRecord, node: PhyFuture):
        exc = node.exception()
        if exc is None:
            self._finish(rec, value=node.result())   # _LocalCall pinned
        else:
            self._finish(rec, exc=exc,
                         cancelled=isinstance(exc, CancelledError))

    def _finish(self, rec: _TaskRecord, *, value=None,
                exc: Optional[BaseException] = None,
                cancelled: bool = False):
        with self._lock:
            self._outstanding.pop(rec.tid, None)
            self._by_future.pop(id(rec.promise), None)
            self._lock.notify_all()
        if exc is None:
            rec.promise.set_result(value)
        else:
            rec.promise.set_exception(exc, cancelled=cancelled)

    # -- SPMD checkpointing (addressable shards; DESIGN.md §10) ---------------
    def account_ckpt_leaf_bytes(self, n: int):
        """Record ``n`` checkpoint leaf bytes about to ship in a task
        payload (host-copy saves); SPMD saves never call this."""
        with self._lock:
            self.ckpt_leaf_wire_bytes += int(n)

    # -- fabric DDP (ring all-reduce; DESIGN.md §11) --------------------------
    def account_grad_wire_bytes(self, n: int):
        """Record ``n`` gradient payload bytes the driver's ring sent
        (own encodes + relays); wired as the driver ring's ``account``
        callback."""
        with self._lock:
            self.grad_wire_bytes += int(n)

    def ddp_train(self, spec: dict):
        """Start the fabric-DDP train loop (``frontend.ddp``) on every
        alive worker locality; the driver runs its own shard block
        in-process via ``Session._train_ddp``.

        Args:
            spec: picklable dict - ``plan``, ``steps``, ``ckpt_dir``,
                ``resume``, ``stream``, ``gen`` (the driver ring's
                generation, so all rings key segments identically).
        """
        with self._lock:
            self._ddp_done.clear()     # completions are per-run
            self.grad_wire_bytes = 0   # accounting too (re-entrant trains)
        for rank in self.group.alive_workers():
            try:
                self.endpoint.post(rank, "ddp_train", spec)
            except PeerLostError:      # died since alive_workers(): the
                pass                   # peer-loss hook aborts the ring

    def ddp_abort(self, reason: str):
        """Poison the whole ring: locally and (best-effort) on every
        alive worker.  Survivor localities with no direct connection to
        a dead rank would otherwise block until timeout."""
        self.grad_ring.abort(reason)
        for rank in self.group.alive_workers():
            try:
                self.endpoint.post(rank, "ddp_abort", reason)
            except PeerLostError:
                pass

    def _on_ddp_done(self, src: int, msg: dict):
        with self._lock:
            self._ddp_done[int(msg["rank"])] = msg
            self._lock.notify_all()

    def wait_ddp_done(self, timeout: float = 600.0) -> dict:
        """Block until every *alive* worker's DDP loop reported
        completion (a killed worker is excused - the run already
        aborted).

        Returns:
            ``{rank: done message}`` as received, each carrying ``ok``
            and ``grad_wire_bytes``.
        Raises:
            TimeoutError: an alive worker's DDP loop did not finish.
        """
        deadline = time.monotonic() + timeout

        def ready():
            alive = set(self.group.alive_workers())
            return all(r in self._ddp_done for r in alive)

        with self._lock:
            ok = self._lock.wait_for(
                ready, timeout=max(0.0, deadline - time.monotonic()))
            done = dict(self._ddp_done)
        if not ok:
            raise TimeoutError("DDP train loops still running after "
                               f"{timeout}s")
        return done

    def spmd_train(self, spec: dict):
        """Start the SPMD shadow train loop (``frontend.spmd``) on every
        alive worker locality: each mirrors the driver's device
        computation in lockstep and writes its own addressable
        checkpoint shards.

        Args:
            spec: picklable dict - ``plan``, ``steps``, ``ckpt_every``,
                ``ckpt_dir``, ``resume``, ``stream``.
        """
        with self._lock:
            self._spmd_done.clear()    # completions are per-run
        for rank in self.group.alive_workers():
            try:
                self.endpoint.post(rank, "spmd_train", spec)
            except PeerLostError:      # died since alive_workers(): its
                pass                   # entry promises poison via peer loss

    def spmd_entry_futures(self, step: int, ranks) -> list[PhyFuture]:
        """One promise per other jax process for its shard manifest
        entry of ``step`` - the metadata-only return channel of an SPMD
        save.  A promise for an already-dead locality (or one whose
        locality dies before posting) is poisoned with
        ``LocalityLostError``: its bytes exist nowhere else, so the save
        must abort, never commit.

        Args:
            step: the save's step number.
            ranks: the non-driver process ranks expected to write.
        Returns:
            List of ``PhyFuture`` resolving to the entries (or None for
            a rank that had nothing to write).
        """
        out = []
        for r in ranks:
            key = (int(step), int(r))
            p = self._graph.promise(name=f"ckpt:entry{r}:{step}",
                                    lane=Lane.CHECKPOINT,
                                    producer=f"L{r}")
            settle = None
            with self._lock:
                done = self._spmd_done.get(int(r))
                if key in self._spmd_entries and not isinstance(
                        self._spmd_entries[key], PhyFuture):
                    settle = ("value", self._spmd_entries.pop(key))
                elif r != 0 and r not in self.group.alive_workers():
                    settle = ("lost", f"locality {r} is not alive")
                elif done is not None and not done.get("ok"):
                    # the shadow ALREADY failed on a live worker: this
                    # entry will never be posted
                    settle = ("lost", f"SPMD shadow on locality {r} "
                                      f"failed: {done.get('error')}")
                else:
                    self._spmd_entries[key] = p
            if settle is None:
                pass
            elif settle[0] == "value":
                p.set_result(settle[1])
            else:
                p.set_exception(LocalityLostError(
                    f"ckpt entry for step {step}: {settle[1]}; its "
                    f"addressable shards exist nowhere else - SPMD "
                    f"save aborted"))
            out.append(p)
        return out

    def _on_ckpt_entries(self, src: int, msg: dict):
        key = (int(msg["step"]), int(msg["rank"]))
        with self._lock:
            cur = self._spmd_entries.get(key)
            if isinstance(cur, PhyFuture):
                del self._spmd_entries[key]
            else:                    # worker ahead of the driver: buffer
                self._spmd_entries[key] = msg["entry"]
                cur = None
        if cur is not None:
            cur.set_result(msg["entry"])

    def _on_spmd_done(self, src: int, msg: dict):
        with self._lock:
            self._spmd_done[int(msg["rank"])] = msg
            self._lock.notify_all()
        if not msg.get("ok"):
            # the shadow died: entries it still owes will never arrive
            self._poison_spmd_entries(
                int(msg["rank"]),
                f"SPMD shadow on locality {msg['rank']} failed: "
                f"{msg.get('error')}")

    def _poison_spmd_entries(self, rank: int, reason: str):
        with self._lock:
            pend = [(k, v) for k, v in self._spmd_entries.items()
                    if k[1] == rank and isinstance(v, PhyFuture)]
            for k, _ in pend:
                del self._spmd_entries[k]
        for _, p in pend:
            p.set_exception(LocalityLostError(reason))

    def wait_spmd_done(self, timeout: float = 600.0) -> dict:
        """Block until every *alive* worker's shadow train loop reported
        completion (a killed worker is excused - its saves aborted).

        Returns:
            ``{rank: done message}`` as received.
        Raises:
            TimeoutError: an alive worker's shadow did not finish.
        """
        deadline = time.monotonic() + timeout

        def ready():
            alive = set(self.group.alive_workers())
            return all(r in self._spmd_done for r in alive)

        with self._lock:
            ok = self._lock.wait_for(
                ready, timeout=max(0.0, deadline - time.monotonic()))
            done = dict(self._spmd_done)
        if not ok:
            raise TimeoutError("SPMD shadow train loops still running "
                               f"after {timeout}s")
        return done

    # -- wire handlers --------------------------------------------------------
    def _on_task_done(self, src: int, msg: dict):
        with self._lock:
            rec = self._outstanding.get(msg["tid"])
        if rec is None:
            return                           # cancelled/re-spawned: stale
        status = msg["status"]
        if status == "ok":
            self._finish(rec, value=msg["value"])
        elif status == "cancelled":
            self._finish(rec, exc=CancelledError(rec.name), cancelled=True)
        else:
            self._finish(rec, exc=msg["exc"])

    def _on_peer_lost(self, rank: int):
        self.group.note_lost(rank)
        if self.grad_ring.active:
            # a DDP exchange is in flight: poison it everywhere - a
            # survivor with no direct connection to the dead rank never
            # observes the loss itself
            self.ddp_abort(f"locality {rank} died mid-all-reduce")
        # SPMD shard entries die with their writer: poison, never re-spawn
        self._poison_spmd_entries(
            rank, f"locality {rank} died before shipping its shard "
                  f"entry; its addressable shards exist nowhere else - "
                  f"SPMD save aborted")
        with self._lock:
            stranded = [r for r in self._outstanding.values()
                        if r.target == rank]
        for rec in stranded:
            with rec.lock:
                # re-check under the record lock: a concurrent dispatch
                # may have already moved it to a live locality
                if rec.promise.done() or rec.target != rank:
                    continue
                if not rec.sent:
                    # never reached the dead locality: just retarget
                    # (_send_spawn re-picks at send time anyway)
                    rec.target = self._fallback(rec.lane)
                    continue
                rec.sent = False
                rec.target = self._fallback(rec.lane)
            lost_refs = any(
                isinstance(leaf, RemoteRef) and leaf.owner == rank
                for leaf in jax.tree.leaves(rec.payload, is_leaf=_is_ref))
            if not rec.idempotent or lost_refs:
                self._finish(rec, exc=LocalityLostError(
                    f"{rec.name}: locality {rank} died "
                    + ("holding its input data"
                       if lost_refs else "and the task is not idempotent")))
                continue
            with self._lock:
                self.respawned += 1
            try:
                self._send_spawn(rec)
            except BaseException as e:  # noqa: BLE001 - see _dispatch
                self._finish(rec, exc=e)

    # -- observability --------------------------------------------------------
    def stats(self) -> dict:
        """Driver-side counters: per-locality dispatch counts, re-spawns,
        outstanding tasks, and wire bytes."""
        with self._lock:
            return {"dispatched": dict(self.dispatched),
                    "respawned": self.respawned,
                    "outstanding": len(self._outstanding),
                    "alive_workers": self.group.alive_workers(),
                    "bytes_sent": self.endpoint.bytes_sent,
                    "bytes_recv": self.endpoint.bytes_recv,
                    "ckpt_leaf_wire_bytes": self.ckpt_leaf_wire_bytes,
                    "grad_wire_bytes": self.grad_wire_bytes,
                    "unhandled_posts": dict(
                        self.endpoint.unhandled_posts)}

    def remote_stats(self, rank: int, timeout: float = 30.0) -> dict:
        """A worker locality's own ``RuntimeStats`` JSON (plus directory
        size and wire bytes), fetched over the wire."""
        return self.endpoint.request(rank, "stats", timeout=timeout)

    # -- lifecycle ------------------------------------------------------------
    def barrier(self, timeout: float = 120.0):
        """Block until every distributed task has streamed back.

        Raises:
            TimeoutError: outstanding tasks remain after ``timeout``.
        """
        deadline = time.monotonic() + timeout
        with self._lock:
            ok = self._lock.wait_for(
                lambda: not self._outstanding,
                timeout=max(0.0, deadline - time.monotonic()))
        if not ok:
            raise TimeoutError(
                f"{len(self._outstanding)} distributed tasks outstanding")

    def shutdown(self, wait: bool = True, timeout: float = 120.0):
        """Drain distributed work (or poison it), stop the workers, and
        shut the local graph down if this object created it."""
        if self._closed:
            return
        self._closed = True
        if wait:
            try:
                self.barrier(timeout=timeout)
            except TimeoutError:
                pass
        with self._lock:
            stranded = list(self._outstanding.values())
            entry_pend = [(k, v) for k, v in self._spmd_entries.items()
                          if isinstance(v, PhyFuture)]
            self._spmd_entries.clear()
        for rec in stranded:
            self._finish(rec, exc=LocalityLostError(
                f"{rec.name}: distributed graph shut down"))
        for k, p in entry_pend:        # an unresolved promise would hang
            p.set_exception(LocalityLostError(  # the graph's barrier
                f"ckpt entry for step {k[0]}: distributed graph shut "
                f"down"))
        self.group.shutdown()
        if self._own_graph:
            self._graph.shutdown(wait=True)


class _LocalCall:
    """Driver-local execution of a (possibly ref-holding) task payload;
    picklable-agnostic because it never crosses the wire.  Honors the
    same ``pin`` contract as remote execution: the value stays in the
    driver's directory and the caller sees a ``RemoteRef``."""

    def __init__(self, fn: Callable, directory: ObjectDirectory, *,
                 pin: bool = False, summary: str = ""):
        self.fn = fn
        self.directory = directory
        self.pin = pin
        self.summary = summary
        self.__name__ = getattr(fn, "__name__", "task")

    def __call__(self, *args, **kwargs):
        a, kw = _deref_tree((args, kwargs), self.directory)
        value = self.fn(*a, **kw)
        if self.pin:
            value = self.directory.put(value, summary=self.summary
                                       or self.__name__)
        return value


def _checksum_vote(*results):
    """Majority vote by content checksum over replica results (HPX
    replicate); no majority means corruption we cannot arbitrate."""
    sums = [tree_checksum(r) for r in results]
    counts = collections.Counter(sums)
    best, votes = counts.most_common(1)[0]
    if votes <= len(results) // 2 and len(results) > 1:
        raise RemoteTaskError(
            f"replicate: no checksum majority across {len(results)} "
            f"localities ({counts})")
    return results[sums.index(best)]
