"""Multi-locality scheduling: ``Locality`` workers + ``DistributedGraph``.

This is the paper's HPX mapping carried across process boundaries
(DESIGN.md §9).  A *locality* is one Python process with its own
``FuturizedGraph``; the driver (rank 0) holds a ``DistributedGraph``
whose ``defer`` mirrors the local one but may place the task on any
locality:

  * **Placement = lane + data affinity.**  Explicit ``locality=`` wins;
    otherwise tasks whose arguments hold remote futures / ``RemoteRef``s
    go to the majority owner (derefs become local dictionary hits), and
    everything else round-robins over the worker localities per lane -
    so PREFETCH and CHECKPOINT streams interleave fairly instead of
    convoying on one worker.
  * **Futures span the wire.**  ``defer`` returns an ordinary
    ``PhyFuture`` (a promise node of the driver's graph); a dispatch
    node waits for the task's *local* dependency edges, then ships
    ``(fn, resolved args)`` in a ``spawn`` active message.  The worker
    defers it onto its own graph and streams the result back in a
    ``task_done`` post as soon as it resolves - fulfilling the promise,
    which releases the driver-side dependents through the normal edge
    machinery.  Errors come back as the original exception and poison
    exactly the transitive dependents; cancellation crosses the wire in
    both directions.
  * **Failure model: re-create, not migrate.**  When a worker dies, its
    in-flight idempotent tasks are re-spawned on a surviving locality
    (or run on the driver when none is left); tasks holding refs owned
    by the dead locality - state that died with it - are poisoned with
    ``LocalityLostError`` instead.  This extends the elastic-restart
    story of ``examples/elastic_restart.py`` to locality loss *without*
    a checkpoint round-trip.

Task functions must be picklable (module-level functions or bound
methods of picklable objects); closures raise a clear error at dispatch
time.  ``jax`` state never crosses the wire: workers build host values
(numpy), the driver does all ``device_put``/dispatch.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import multiprocessing as mp
import os
import pickle
import threading
import time
from concurrent.futures import CancelledError
from typing import Any, Callable, Optional

import jax

from ..analysis import sanitize as _san
from ..core.futures import FuturizedGraph, Lane, PhyFuture
from ..core.resilience import tree_checksum
from .agas import ObjectDirectory, RemoteRef
from .collectives import RingAllReduce
from .messaging import Endpoint, PeerLostError, raw_request

__all__ = ["DistributedGraph", "Locality", "LocalityGroup",
           "LocalityLostError", "RemoteTaskError", "join_locality",
           "worker_main"]


class RemoteTaskError(RuntimeError):
    """A remote task failed and its exception could not be shipped back
    verbatim (unpicklable); carries the remote repr instead."""


class LocalityLostError(RuntimeError):
    """A task (or data it needed) was lost with its locality and could
    not be re-created elsewhere."""


def _is_ref(x) -> bool:
    return isinstance(x, RemoteRef)


def _deref_tree(argskw, directory: ObjectDirectory):
    """Replace every ``RemoteRef`` leaf with its value (local hit on the
    owner, one AGAS fetch otherwise)."""
    return jax.tree.map(
        lambda x: directory.fetch(x) if _is_ref(x) else x,
        argskw, is_leaf=_is_ref)


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------
class Locality:
    """One worker process of the multi-locality runtime.

    Owns an ``Endpoint`` (active messages), a ``FuturizedGraph`` (local
    lanes + workers), and an ``ObjectDirectory`` (this locality's slice
    of the address space).  ``serve`` registers the task handlers and
    blocks until a ``shutdown`` message (or loss of the driver).

    Args:
        rank: this locality's rank (>= 1 for spawned workers).
        world: total locality count, driver included.
        max_workers: local graph worker threads.
        elastic: run the idle-thief steal loop (DESIGN.md §13) - the
            locality posts ``steal_request`` to the driver whenever its
            local graph drains.
    """

    def __init__(self, rank: int, world: int, *, max_workers: int = 2,
                 elastic: bool = False):
        self.rank = rank
        self.world = world
        self.elastic = elastic
        # membership generation (gossiped by the driver, monotone): every
        # steal carries it, so a steal planned under a stale peer table
        # is fenced instead of double-executing (PHY106)
        self.membership_gen = 0
        self.endpoint = Endpoint(rank)
        self.graph = FuturizedGraph(max_workers=max_workers,
                                    name=f"locality{rank}")
        self.directory = ObjectDirectory(rank, self.endpoint)
        self._tasks: dict[str, PhyFuture] = {}
        # tids a steal_lease may claim (the spawn said so: round-robin
        # placement, not pinned and not data-affinity)
        self._stealable: set[str] = set()
        # tids leased away mid-steal: their cancelled completion must not
        # be reported (the driver re-spawns them from its own payload)
        self._stolen: set[str] = set()
        self._steal_interval = 0.05
        self._lock = threading.Lock()
        self._stop = threading.Event()
        ep = self.endpoint
        ep.register("spawn", self._on_spawn)
        ep.register("cancel", self._on_cancel)
        ep.register("peers", self._on_peers)
        ep.register("peer_joined", self._on_peer_joined)
        ep.register("steal_lease", self._on_steal_lease)
        ep.register("agas_rebalance", self._on_rebalance)
        ep.register("shutdown", lambda src, p: self._stop.set())
        ep.register("ping", lambda src, p: p)
        ep.register("stats", self._on_stats)
        ep.register("spmd_train", self._on_spmd_train)
        # the ring registers its own "grad_ring" handler: it must exist
        # BEFORE any peer can send (posts to an unregistered action are
        # dropped - counted and warned, never delivered late), so it is
        # born with the locality
        self.grad_ring = RingAllReduce(ep, world)
        ep.register("ddp_train", self._on_ddp_train)
        ep.register("ddp_abort",
                    lambda src, reason: self.grad_ring.abort(reason))
        ep.on_peer_lost = self._on_peer_lost

    # -- handlers ------------------------------------------------------------
    def _on_spawn(self, src: int, p: dict):
        with self._lock:
            self.membership_gen = max(self.membership_gen,
                                      int(p.get("gen", 0)))
            dup = p["tid"] in self._tasks
        if dup:
            # the exactly-once handoff protocol must never land one tid
            # here twice: a second spawn means a lease raced a re-spawn
            # past the driver's fencing (PHY106) - drop it
            if _san.active():
                _san.get().record(
                    "PHY106",
                    f"locality {self.rank}: task {p['tid']} "
                    f"({p['name']}) spawned here twice - steal-lease "
                    f"violation",
                    once_key=f"spawn:{self.rank}:{p['tid']}")
            return
        node = self.graph.defer(self._run, p["fn"], p["args"], p["kwargs"],
                                lane=Lane(p["lane"]), name=p["name"])
        with self._lock:
            self._tasks[p["tid"]] = node
            if p.get("steal"):
                self._stealable.add(p["tid"])
        node.add_done_callback(
            lambda n, tid=p["tid"], pin=p["pin"], src=src:
            self._report(src, tid, pin, n))

    def _run(self, fn, args, kwargs):
        a, kw = _deref_tree((args, kwargs), self.directory)
        return fn(*a, **kw)

    def _report(self, src: int, tid: str, pin: bool, node: PhyFuture):
        with self._lock:
            stolen = tid in self._stolen
            self._stolen.discard(tid)
            self._tasks.pop(tid, None)
            self._stealable.discard(tid)
        if stolen:
            return   # leased away before it ran; the driver re-spawns it
        exc = node.exception()
        if exc is None:
            value = node.result()
            if pin:
                value = self.directory.put(value, summary=node.name)
            msg = {"tid": tid, "status": "ok", "value": value}
        elif isinstance(exc, CancelledError):
            msg = {"tid": tid, "status": "cancelled"}
        else:
            msg = {"tid": tid, "status": "error", "exc": exc}
        # serialize exactly once: post() pickles the message before any
        # bytes hit the socket, so a pickling failure here is recoverable
        # and we retry with a shippable error instead
        try:
            self.endpoint.post(src, "task_done", msg)
            return
        except PeerLostError:
            return                  # driver is gone; nobody to tell
        except Exception as e:  # noqa: BLE001 - unshippable value/exc
            msg = {"tid": tid, "status": "error",
                   "exc": RemoteTaskError(
                       f"{node.name}: result not shippable ({e}); "
                       f"pin large/custom values with pin=True")}
        try:
            self.endpoint.post(src, "task_done", msg)
        except PeerLostError:
            pass

    def _on_cancel(self, src: int, tid: str):
        with self._lock:
            node = self._tasks.get(tid)
        if node is not None:
            node.cancel()

    def _on_peers(self, src: int, p: dict):
        # payload is either a bare {rank: addr} book, or the elastic form
        # {"book": ..., "gen": ..., "world": ...}
        book = p["book"] if "book" in p else p
        self.endpoint.address_book.update(
            {int(r): tuple(a) for r, a in book.items()})
        if "gen" in p:
            with self._lock:
                self.membership_gen = max(self.membership_gen,
                                          int(p["gen"]))
                self.world = max(self.world, int(p.get("world", 0)))

    def _on_peer_joined(self, src: int, p: dict):
        """Membership gossip, generation-keyed like the PR 6 ring: a
        stale or reordered join/leave message can only move this
        locality's view forward, never regress it mid-steal."""
        gen = int(p["gen"])
        with self._lock:
            if gen <= self.membership_gen:
                return
            self.membership_gen = gen
        if p.get("event", "join") == "left":
            self.endpoint.address_book.pop(int(p["rank"]), None)
        else:
            self.endpoint.address_book[int(p["rank"])] = tuple(p["addr"])
            with self._lock:
                self.world = max(self.world, int(p["rank"]) + 1)

    def _on_steal_lease(self, src: int, p: dict) -> int:
        """Driver-brokered steal, victim side: atomically claim (cancel)
        one not-yet-running spawned task - that cancel IS the lease -
        and release it back to the driver in a ``steal_handoff``, which
        re-spawns it on the thief from its own payload.  A task whose
        cancel fails is running or done and cannot be claimed: the lease
        either moves a task that never started, or moves nothing.  Only
        tasks the spawn marked stealable (round-robin placement, neither
        pinned nor affinity-placed) are candidates."""
        with self._lock:
            candidates = [(tid, node) for tid, node in self._tasks.items()
                          if tid in self._stealable]
        for tid, node in candidates:
            with self._lock:
                self._stolen.add(tid)    # before cancel: its completion
            if not node.cancel():        # callback checks this set
                with self._lock:
                    self._stolen.discard(tid)
                continue
            with self._lock:
                self._tasks.pop(tid, None)
            try:
                self.endpoint.post(0, "steal_handoff",
                                   {"tid": tid, "thief": int(p["thief"]),
                                    "victim": self.rank,
                                    "gen": int(p.get("gen", -1))})
            except PeerLostError:
                pass          # driver gone: shutdown is imminent anyway
            return 1
        return 0

    def _on_rebalance(self, src: int, p: dict) -> int:
        """Driver-driven AGAS rebalance: refresh the peer table (the
        newcomers must be dialable before we ship values to them) and
        migrate this locality's block (``ObjectDirectory.rebalance``)."""
        self.endpoint.address_book.update(
            {int(r): tuple(a) for r, a in p.get("book", {}).items()})
        return self.directory.rebalance([int(r) for r in p["newcomers"]])

    def _steal_loop(self):
        """Idle-thief loop (elastic mode): when the local graph drains,
        ask the driver for work.  The ack gossips queue depths and the
        membership generation; a ``parked`` reply (the driver had
        nothing ready either) backs off - the driver diverts the next
        steerable dispatch here without being asked again."""
        backoff_until = 0.0
        while not self._stop.is_set():
            self._stop.wait(self._steal_interval)
            if self._stop.is_set():
                return
            if time.monotonic() < backoff_until:
                continue
            ld = self.graph.load()
            if ld["ready"] or ld["running"]:
                continue
            try:
                out = self.endpoint.request(
                    0, "steal_request",
                    {"thief": self.rank, "gen": self.membership_gen},
                    timeout=30.0)
            except (PeerLostError, TimeoutError, RuntimeError):
                backoff_until = time.monotonic() + 1.0
                continue
            with self._lock:
                self.membership_gen = max(self.membership_gen,
                                          int(out.get("gen", 0)))
            if not out.get("handed"):
                backoff_until = time.monotonic() + 0.5

    def _on_stats(self, src: int, p) -> dict:
        out = self.graph.stats().to_json()
        out["directory_objects"] = len(self.directory)
        out["directory_audit"] = self.directory.audit()
        out["bytes_sent"] = self.endpoint.bytes_sent
        out["bytes_recv"] = self.endpoint.bytes_recv
        out["unhandled_posts"] = dict(self.endpoint.unhandled_posts)
        return out

    def _on_peer_lost(self, rank: int):
        self.grad_ring.peer_lost(rank)   # abort a blocked all-reduce
        if rank == 0:               # driver died: nothing left to serve
            self._stop.set()

    def _on_spmd_train(self, src: int, spec: dict):
        """Run the SPMD shadow train loop (DESIGN.md §10) on its own
        thread: this locality mirrors the driver's device computation
        in lockstep and writes its own addressable checkpoint shards,
        posting back only the manifest entries.  Completion (or the
        failure) is reported via a ``spmd_done`` post."""
        def run():
            try:
                from ..frontend.spmd import shadow_train
                step = shadow_train(spec, endpoint=self.endpoint)
                msg = {"rank": self.rank, "ok": True, "step": step}
            except BaseException as e:  # noqa: BLE001 - shipped back
                msg = {"rank": self.rank, "ok": False,
                       "error": f"{type(e).__name__}: {e}"}
            try:
                self.endpoint.post(src, "spmd_done", msg)
            except PeerLostError:
                pass
        threading.Thread(target=run, daemon=True,
                         name=f"spmd-shadow-{self.rank}").start()

    def _on_ddp_train(self, src: int, spec: dict):
        """Run the fabric-DDP train loop (DESIGN.md §11) on its own
        thread: this locality computes gradients for its shard block,
        all-reduces them over the ring, and applies the identical
        optimizer step.  Completion - and the locality's
        ``grad_wire_bytes`` - is reported via a ``ddp_done`` post."""
        def run():
            try:
                from ..frontend.ddp import ddp_shadow_train
                out = ddp_shadow_train(spec, endpoint=self.endpoint,
                                       ring=self.grad_ring)
                msg = dict(out, rank=self.rank, ok=True)
            except BaseException as e:  # noqa: BLE001 - shipped back
                msg = {"rank": self.rank, "ok": False,
                       "grad_wire_bytes": int(self.grad_ring.wire_bytes),
                       "error": f"{type(e).__name__}: {e}"}
            try:
                self.endpoint.post(src, "ddp_done", msg)
            except PeerLostError:
                pass
        threading.Thread(target=run, daemon=True,
                         name=f"ddp-{self.rank}").start()

    # -- lifecycle -----------------------------------------------------------
    def serve(self, driver_addr: tuple[str, int]):
        """Connect to the driver, announce ourselves, and serve active
        messages until shut down (blocking)."""
        self.endpoint.address_book[0] = tuple(driver_addr)
        self.endpoint.connect(0, tuple(driver_addr))
        out = self.endpoint.request(0, "hello",
                                    {"rank": self.rank,
                                     "addr": list(self.endpoint.address)})
        if isinstance(out, dict):        # elastic driver: adopt its view
            with self._lock:
                self.membership_gen = max(self.membership_gen,
                                          int(out.get("gen", 0)))
                self.world = max(self.world, int(out.get("world", 0)))
        if self.elastic:
            threading.Thread(target=self._steal_loop, daemon=True,
                             name=f"steal{self.rank}").start()
        self._stop.wait()
        self.graph.shutdown(wait=True, cancel_pending=True)
        self.endpoint.close()


def worker_main(rank: int, world: int, driver_addr, env: Optional[dict] = None):
    """Spawned-process entry point: become locality ``rank`` and serve.

    ``env`` entries are exported before any device work so spawn-time
    configuration (e.g. ``PHYRAX_JAX_COORDINATOR``) lands in the child;
    ``launch.mesh.maybe_init_jax_distributed`` is then given a chance to
    initialize ``jax.distributed`` (a no-op unless configured).

    ``PHYRAX_LOCALITY_RANK`` is always exported, so locality-owned work
    records its executing rank (checkpoint shard entries name their
    actual writer - DESIGN.md §10); when the session forwards a
    checkpoint directory as ``PHYRAX_CKPT_DIR``, it is created here at
    spawn, so a misconfigured or unwritable checkpoint mount fails the
    worker immediately (surfacing at ``LocalityGroup`` startup) instead
    of mid-training at the first shard write.
    """
    for k, v in (env or {}).items():
        os.environ[k] = v
    os.environ["PHYRAX_LOCALITY_RANK"] = str(rank)
    ckpt_dir = os.environ.get("PHYRAX_CKPT_DIR")
    if ckpt_dir:
        os.makedirs(ckpt_dir, exist_ok=True)
    from ..launch.mesh import maybe_init_jax_distributed

    spmd = maybe_init_jax_distributed(process_id=rank, num_processes=world)
    if spmd:
        # the multi-process CPU backend exchanges local topologies over
        # the coordination service: every process must CREATE its
        # backend before any of them can.  Warm ours on a thread so
        # serve() (and the hello the driver is waiting on) is not gated
        # on the driver reaching its own first jax call.
        def _warm():
            try:
                jax.local_devices()
            except Exception:  # noqa: BLE001 - surfaces at first jax use
                pass
        threading.Thread(target=_warm, daemon=True,
                         name=f"jax-backend-warm-{rank}").start()
    elastic = os.environ.get("PHYRAX_ELASTIC", "") not in ("", "0")
    Locality(rank, world, elastic=elastic).serve(tuple(driver_addr))
    if spmd:
        # coordinated teardown: the jax.distributed shutdown barrier
        # needs every process; the driver joins it in Session.close
        try:
            jax.distributed.shutdown()
        except Exception:  # noqa: BLE001 - best-effort on the way out
            pass


def join_locality(driver_addr: tuple[str, int], *,
                  max_workers: int = 2) -> int:
    """Dial-in elastic join (the ``--join host:port`` entry point).

    Two-phase registration (DESIGN.md §13): a ``join`` request over a
    raw one-shot socket returns the assigned rank, the current peer
    table, the driver's config spec (environment to adopt), and the
    membership generation; then this process becomes that ``Locality``
    and serves - the normal ``hello`` triggers gossip and AGAS rebalance
    driver-side.  Blocks until the driver shuts the run down.

    Returns:
        The rank this process served as.
    Raises:
        RuntimeError: the driver does not accept joins (not elastic).
        ConnectionError: no driver is listening at ``driver_addr``.
    """
    driver_addr = (driver_addr[0], int(driver_addr[1]))
    grant = raw_request(driver_addr, "join", {})
    spec = grant.get("spec") or {}
    for k, v in (spec.get("env") or {}).items():
        os.environ.setdefault(k, str(v))
    rank = int(grant["rank"])
    os.environ["PHYRAX_LOCALITY_RANK"] = str(rank)
    ckpt_dir = os.environ.get("PHYRAX_CKPT_DIR")
    if ckpt_dir:
        os.makedirs(ckpt_dir, exist_ok=True)
    loc = Locality(rank, int(grant["world"]), max_workers=max_workers,
                   elastic=True)
    loc.membership_gen = int(grant.get("gen", 0))
    loc.endpoint.address_book.update(
        {int(r): tuple(a) for r, a in grant["book"].items()})
    loc.serve(driver_addr)
    return rank


# ---------------------------------------------------------------------------
# Driver side
# ---------------------------------------------------------------------------
class LocalityGroup:
    """Driver-side handle on the spawned worker localities.

    Spawns ``n_workers`` processes (ranks 1..n) via
    ``multiprocessing.spawn``, waits for each to report in, then
    broadcasts the address book so workers can reach each other (AGAS
    fetches).  ``kill`` is the failure-drill seam.

    Args:
        n_workers: worker process count (world size is ``n_workers + 1``).
        worker_env: extra environment for the children (exported before
            jax device setup in the child).
        start_timeout: seconds to wait for all workers to report in.
        port: driver listen port (0 = ephemeral); pinned by elastic runs
            so ``--join`` has a known address to dial.
    """

    def __init__(self, n_workers: int, *,
                 worker_env: Optional[dict] = None,
                 start_timeout: float = 120.0, port: int = 0):
        self.endpoint = Endpoint(0, port=port)
        self.world = n_workers + 1
        # membership generation: bumped on every join and loss, gossiped
        # with the peer table, and carried by every steal (fencing)
        self.gen = 0
        self._worker_env = worker_env
        self._start_timeout = start_timeout
        self._addrs: dict[int, tuple[str, int]] = {}
        self._alive: set[int] = set()
        self._reserved: set[int] = set()   # ranks granted, not yet hello'd
        self._started = False
        # called (rank, addr) on every post-startup hello - the elastic
        # join seam; DistributedGraph wires gossip + rebalance here
        self.on_join: Optional[Callable[[int, tuple[str, int]], None]] = None
        self._cond = threading.Condition()
        self.endpoint.register("hello", self._on_hello)
        ctx = mp.get_context("spawn")
        self.procs: dict[int, Any] = {}
        for rank in range(1, self.world):
            p = ctx.Process(
                target=worker_main, daemon=True,
                args=(rank, self.world, tuple(self.endpoint.address),
                      worker_env))
            p.start()
            self.procs[rank] = p
        with self._cond:
            ok = self._cond.wait_for(
                lambda: len(self._addrs) == n_workers, start_timeout)
        if not ok:
            missing = sorted(set(self.procs) - set(self._addrs))
            self.shutdown()
            raise TimeoutError(
                f"localities {missing} did not report in within "
                f"{start_timeout}s")
        book = {r: list(a) for r, a in self._addrs.items()}
        book[0] = list(self.endpoint.address)
        self.endpoint.address_book.update(
            {r: tuple(a) for r, a in self._addrs.items()})
        for rank in sorted(self._addrs):
            self.endpoint.post(rank, "peers",
                               {"book": book, "gen": self.gen,
                                "world": self.world})
        self._started = True

    def _on_hello(self, src: int, p: dict) -> dict:
        rank, addr = int(p["rank"]), tuple(p["addr"])
        with self._cond:
            self._addrs[rank] = addr
            self._alive.add(rank)
            self._reserved.discard(rank)
            self.world = max(self.world, rank + 1)
            started = self._started
            self._cond.notify_all()
        self.endpoint.address_book[rank] = addr
        if started and self.on_join is not None:
            # a post-startup hello is an elastic join: run gossip +
            # rebalance BEFORE acking, so the joiner's serve loop starts
            # against a settled peer table
            self.on_join(rank, addr)
        return {"world": self.world, "gen": self.gen}

    def addresses(self) -> dict[int, tuple[str, int]]:
        """The current peer table: ``rank -> (host, port)`` for every
        alive locality, driver included."""
        with self._cond:
            out = {r: self._addrs[r] for r in self._alive
                   if r in self._addrs}
        out[0] = tuple(self.endpoint.address)
        return out

    def next_rank(self) -> int:
        """Reserve and return the next unused rank (elastic join grant);
        the reservation clears when that rank's hello arrives."""
        with self._cond:
            used = set(self.procs) | set(self._addrs) | self._reserved
            rank = max(used, default=0) + 1
            self._reserved.add(rank)
            self.world = max(self.world, rank + 1)
            return rank

    def add_worker(self, timeout: Optional[float] = None) -> int:
        """Spawn one extra worker process into the running group and
        wait for it to report in (its hello fires ``on_join``).

        Returns:
            The new worker's rank.
        Raises:
            TimeoutError: it did not report in.
        """
        rank = self.next_rank()
        ctx = mp.get_context("spawn")
        p = ctx.Process(
            target=worker_main, daemon=True,
            args=(rank, self.world, tuple(self.endpoint.address),
                  self._worker_env))
        p.start()
        self.procs[rank] = p
        with self._cond:
            ok = self._cond.wait_for(
                lambda: rank in self._addrs,
                timeout if timeout is not None else self._start_timeout)
        if not ok:
            p.kill()
            with self._cond:
                self._reserved.discard(rank)
            raise TimeoutError(
                f"locality {rank} did not report in")
        return rank

    # -- liveness ------------------------------------------------------------
    def alive_workers(self) -> list[int]:
        """Worker ranks believed alive (updated on connection loss)."""
        with self._cond:
            return sorted(self._alive)

    def note_lost(self, rank: int):
        with self._cond:
            self._alive.discard(rank)

    def kill(self, rank: int):
        """SIGKILL a worker - the locality-loss drill.  The death is
        observed through its connection, same as a real crash.  A
        dial-in joiner has no process handle here; it gets a shutdown
        post instead (its process belongs to whoever ran ``--join``)."""
        proc = self.procs.get(rank)
        if proc is not None and proc.is_alive():
            proc.kill()
        elif proc is None:
            try:
                self.endpoint.post(rank, "shutdown")
            except PeerLostError:
                pass
        self.note_lost(rank)

    def shutdown(self, join_timeout: float = 10.0):
        """Ask every live worker to exit, then reap the processes and
        close the endpoint.  Idempotent."""
        for rank in self.alive_workers():
            try:
                self.endpoint.post(rank, "shutdown")
            except PeerLostError:
                pass
        for rank, proc in self.procs.items():
            proc.join(timeout=join_timeout)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=5.0)
        self.endpoint.close()


@dataclasses.dataclass
class _TaskRecord:
    tid: str
    name: str
    lane: Lane
    fn: Callable
    pin: bool
    idempotent: bool
    target: int
    promise: PhyFuture
    payload: Optional[tuple] = None     # (args, kwargs) resolved at dispatch
    sent: bool = False
    # elastic scheduling state: a steerable record (no explicit locality,
    # no data affinity) may be diverted to a parked idle thief at
    # dispatch time; local_node holds the driver-local execution node so
    # a steal can claim (cancel) it before it runs
    steerable: bool = False
    stolen: bool = False
    local_node: Optional[PhyFuture] = None
    # serializes target/sent mutation between the dispatching thread and
    # a concurrent peer-loss respawn (no double-spawn on two localities)
    lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock)


class DistributedGraph:
    """The driver's view of the multi-locality futurized graph.

    Wraps a local ``FuturizedGraph`` (usually the session runtime) and a
    ``LocalityGroup``; ``defer`` mirrors ``FuturizedGraph.defer`` but
    may place the task on any locality, returning a promise-backed
    ``PhyFuture`` that resolves when the remote result streams back.

    Args:
        localities: total process count, driver included; 1 means no
            workers are spawned and every task runs locally.
        graph: the local graph promises live on (owned by the caller);
            one is created - and shut down with this object - if None.
        worker_env: forwarded to ``LocalityGroup``.
        name: display name for an internally-created graph.
        elastic: accept dial-in joins, spawn workers with the steal loop
            armed, and route driver-local tasks through stealable
            records (DESIGN.md §13).
        elastic_port: fixed driver listen port for ``--join`` dialers
            (0 = ephemeral; only meaningful with ``elastic``).
        join_spec: shipped verbatim to dial-in joiners in the join
            grant; ``{"env": {...}}`` entries are exported by the joiner
            before it serves (checkpoint dir, sanitizer flags...).
    """

    PIN_NONE = 0

    def __init__(self, localities: int = 1, *,
                 graph: Optional[FuturizedGraph] = None,
                 worker_env: Optional[dict] = None,
                 name: str = "distrib",
                 elastic: bool = False, elastic_port: int = 0,
                 join_spec: Optional[dict] = None):
        self.localities = localities
        self.elastic = elastic
        if elastic:
            worker_env = dict(worker_env or {}, PHYRAX_ELASTIC="1")
        self._own_graph = graph is None
        self._graph = graph if graph is not None else FuturizedGraph(
            max_workers=4, name=name)
        self.group = LocalityGroup(max(0, localities - 1),
                                   worker_env=worker_env,
                                   port=elastic_port if elastic else 0)
        self.group.on_join = self._on_member_joined
        self.endpoint = self.group.endpoint
        self.directory = ObjectDirectory(0, self.endpoint)
        self.endpoint.register("task_done", self._on_task_done)
        self.endpoint.register("ckpt_entries", self._on_ckpt_entries)
        self.endpoint.register("spmd_done", self._on_spmd_done)
        self.endpoint.register("ddp_done", self._on_ddp_done)
        self.endpoint.register("join", self._on_join_request)
        self.endpoint.register("steal_request", self._on_steal_request)
        self.endpoint.register("steal_handoff", self._on_steal_handoff)
        self.endpoint.on_peer_lost = self._on_peer_lost
        self._outstanding: dict[str, _TaskRecord] = {}
        self._by_future: dict[int, _TaskRecord] = {}   # id(promise) -> rec
        self._lock = threading.Condition()
        self._tid = itertools.count()
        self._rr = {lane: itertools.count() for lane in Lane}
        self.dispatched = collections.Counter()        # per-locality sends
        self.respawned = 0
        # elastic counters (train report + acceptance drills)
        self.stolen_tasks = 0
        self.migrated_objects = 0
        self.joined = 0
        self._join_spec = dict(join_spec or {})
        self._hungry: collections.deque = collections.deque()
        self._join_done: set[int] = set()
        # checkpoint leaf bytes shipped in save payloads (host-copy
        # mode); the SPMD regression test asserts this stays 0 there
        self.ckpt_leaf_wire_bytes = 0
        # gradient payload bytes the DRIVER sent over the ring (its own
        # encodes + relays); the DDP wire test asserts the exact codec
        # formula against this
        self.grad_wire_bytes = 0
        # the driver is ring rank 0; born here for the same
        # register-before-anyone-sends reason as on the Locality side
        self.grad_ring = RingAllReduce(self.endpoint, localities,
                                       account=self.account_grad_wire_bytes)
        self._ddp_done: dict[int, dict] = {}
        # (step, rank) -> entry promise (save registered first) or the
        # buffered entry value (the worker's post arrived first)
        self._spmd_entries: dict[tuple[int, int], Any] = {}
        self._spmd_done: dict[int, dict] = {}
        self._closed = False

    @property
    def graph(self) -> FuturizedGraph:
        """The local ``FuturizedGraph`` distributed promises live on
        (the session runtime when this object was built by a
        ``Session``).  Anything that chains futures onto distributed
        results - e.g. ``CheckpointManager``'s manifest commit - must
        defer onto this graph."""
        return self._graph

    # -- placement -----------------------------------------------------------
    def alive_localities(self) -> list[int]:
        """Live locality ranks, the driver (rank 0, always alive) first.

        The serve gateway homes its model replicas over this list and
        polls it each round to detect a replica whose host locality died
        (``frontend/gateway.py``, DESIGN.md §15)."""
        return [0] + self.group.alive_workers()

    def _pick(self, lane: Lane, argskw,
              locality: Optional[int]) -> tuple[int, bool]:
        """Choose a target rank; the second element says whether the
        choice was *steerable* (round-robin, not pinned and not
        affinity-driven) - only steerable tasks may be diverted to a
        parked idle thief or claimed by a steal."""
        alive = self.group.alive_workers()
        if locality is not None:
            if locality != 0 and locality not in alive:
                raise ValueError(f"locality {locality} is not alive "
                                 f"(workers: {alive})")
            return locality, False
        homes: collections.Counter = collections.Counter()
        for leaf in jax.tree.leaves(
                argskw, is_leaf=lambda x: isinstance(x, (PhyFuture,
                                                         RemoteRef))):
            if isinstance(leaf, PhyFuture) and leaf.home is not None:
                if leaf.home == 0 or leaf.home in alive:
                    homes[leaf.home] += 1
            elif isinstance(leaf, RemoteRef):
                if leaf.owner == 0 or leaf.owner in alive:
                    homes[leaf.owner] += 1
        if homes:
            return homes.most_common(1)[0][0], False
        if not alive:
            return 0, True
        return alive[next(self._rr[lane]) % len(alive)], True

    # -- task construction ----------------------------------------------------
    def defer(self, fn: Callable, *args, lane: Lane = Lane.COMPUTE,
              name: str = "", locality: Optional[int] = None,
              pin: bool = False, idempotent: bool = True,
              **kwargs) -> PhyFuture:
        """Place ``fn(*args, **kwargs)`` on a locality and return its
        future.

        Args:
            fn: a *picklable* callable (module-level function or bound
                method of a picklable object) for remote placement.
            *args, **kwargs: arguments; local ``PhyFuture`` leaves become
                dependency edges resolved before dispatch, ``RemoteRef``
                leaves are dereferenced at the executing locality.
            lane: priority lane at the executing locality (and the
                round-robin stream the task joins here).
            name: display name; the future is ``name@L<rank>``.
            locality: pin placement to a rank (0 = the driver).
            pin: keep the result in the executing locality's directory
                and resolve the future with a ``RemoteRef`` instead of
                shipping the value back.
            idempotent: safe to re-run on another locality if the
                original dies; False poisons the future on loss instead.
        Returns:
            A ``PhyFuture`` (with ``home`` set to the chosen rank) that
            resolves with the result (or the ``RemoteRef`` when pinned).
        Raises:
            ValueError: ``locality`` names a dead worker.
        """
        if self._closed:
            raise RuntimeError("distributed graph is shut down")
        name = name or getattr(fn, "__name__", "task")
        target, steerable = self._pick(lane, (args, kwargs), locality)
        if target == 0 and not self.elastic:
            # non-elastic fast path: driver-local tasks skip the record
            # machinery entirely.  Elastic mode routes them through a
            # record so an idle joiner can claim one before it runs.
            node = self._graph.defer(
                _LocalCall(fn, self.directory, pin=pin, summary=name),
                *args, lane=lane, name=f"{name}@L0", **kwargs)
            node.home = 0
            return node
        tid = f"t{next(self._tid)}"
        promise = self._graph.promise(name=f"{name}@L{target}", lane=lane,
                                      producer=f"L{target}")
        promise.home = target
        rec = _TaskRecord(tid=tid, name=name, lane=lane, fn=fn, pin=pin,
                          idempotent=idempotent, target=target,
                          promise=promise, steerable=steerable)
        with self._lock:
            self._outstanding[tid] = rec
            self._by_future[id(promise)] = rec
        # the dispatch node carries the task's local dependency edges;
        # once they resolve it ships (fn, resolved args) to the target
        try:
            send = self._graph.defer(self._dispatch, tid, (args, kwargs),
                                     lane=lane, name=f"send:{name}")
        except BaseException as e:   # e.g. cross-graph dependency: settle
            self._finish(rec, exc=e)  # the promise or barrier hangs on it
            raise
        # a dispatch node that terminates WITHOUT sending (poisoned by an
        # upstream edge, or cancelled) must settle the promise too, or it
        # would strand forever and hang barrier/shutdown
        send.add_done_callback(lambda n: self._on_dispatch_done(rec, n))
        return promise

    def _on_dispatch_done(self, rec: _TaskRecord, node: PhyFuture):
        with rec.lock:
            if rec.sent:
                return                   # task_done will settle it
        with self._lock:
            if rec.tid not in self._outstanding:
                return
        exc = node.exception()
        if exc is not None:
            self._finish(rec, exc=exc,
                         cancelled=isinstance(exc, CancelledError))
        elif rec.promise.done():         # cancelled before dispatch ran
            self._finish(rec, exc=CancelledError(rec.name), cancelled=True)

    def fetch(self, ref: RemoteRef, **kw) -> Any:
        """Deref a ``RemoteRef`` from the driver (see
        ``ObjectDirectory.fetch``)."""
        return self.directory.fetch(ref, **kw)

    def cancel(self, fut: PhyFuture) -> bool:
        """Cancel a distributed future: locally at once (dependents are
        poisoned through the normal edges) and, if already dispatched,
        best-effort at the executing locality so queued work is shed.

        Returns:
            The local ``PhyFuture.cancel`` result (False once resolved).
        """
        with self._lock:
            rec = self._by_future.get(id(fut))
        out = fut.cancel()
        if rec is not None and rec.sent:
            try:
                self.endpoint.post(rec.target, "cancel", rec.tid)
            except PeerLostError:
                pass
        return out

    # -- resilience across localities ----------------------------------------
    def replicate(self, fn: Callable, *args, n: int = 2,
                  lane: Lane = Lane.COMPUTE, name: str = "",
                  **kwargs) -> PhyFuture:
        """HPX task replication across localities: run ``fn`` on ``n``
        *distinct* localities and vote by checksum (``core.resilience``),
        so silent corruption on one locality is outvoted by the others.

        Returns:
            A future of the majority result.
        Raises:
            ValueError: fewer than ``n`` distinct localities exist.
        """
        name = name or getattr(fn, "__name__", "task")
        domain = self.group.alive_workers() + [0]
        if len(domain) < n:
            raise ValueError(f"replicate(n={n}) needs {n} localities, "
                             f"have {len(domain)}")
        futs = [self.defer(fn, *args, lane=lane, locality=domain[i],
                           name=f"{name}!r{i}", **kwargs) for i in range(n)]
        return self._graph.defer(_checksum_vote, *futs, lane=lane,
                                 name=f"{name}!vote")

    # -- dispatch internals ---------------------------------------------------
    def _dispatch(self, tid: str, argskw):
        with self._lock:
            rec = self._outstanding.get(tid)
        if rec is None or rec.promise.done():
            return                           # cancelled before dispatch
        rec.payload = argskw                 # futures already substituted
        try:
            self._send_spawn(rec)
        except BaseException as e:  # noqa: BLE001 - a stranded promise
            self._finish(rec, exc=e)         # would hang barrier/shutdown
            raise

    def _send_spawn(self, rec: _TaskRecord):
        assert rec.payload is not None  # _dispatch resolved it before sending
        args, kwargs = rec.payload
        with rec.lock:   # one spawner at a time: dispatch vs peer-loss
            while True:
                if rec.steerable:
                    thief = self._pop_hungry()
                    if thief is not None:
                        # a parked idle locality (its steal_request found
                        # nothing to hand over) takes the next steerable
                        # dispatch - work stealing's push half
                        rec.target = thief
                        rec.stolen = True
                if rec.target != 0 \
                        and rec.target not in self.group.alive_workers():
                    rec.target = self._fallback(rec.lane)
                if rec.target == 0:
                    self._run_local(rec)
                    return
                try:
                    self.endpoint.post(rec.target, "spawn", {
                        "tid": rec.tid, "name": rec.name,
                        "lane": int(rec.lane), "pin": rec.pin,
                        "gen": self.group.gen,
                        "steal": bool(rec.steerable),
                        "fn": rec.fn, "args": args, "kwargs": kwargs})
                except PeerLostError:
                    self.group.note_lost(rec.target)
                    continue
                except (pickle.PicklingError, AttributeError, TypeError) as e:
                    self._finish(rec, exc=RemoteTaskError(
                        f"{rec.name}: not picklable for remote spawn ({e}); "
                        f"use a module-level function and picklable args"))
                    return
                rec.sent = True
                rec.promise.home = rec.target
                with self._lock:
                    self.dispatched[rec.target] += 1
                    if rec.stolen:
                        self.stolen_tasks += 1
                        rec.stolen = False
                return

    def _pop_hungry(self) -> Optional[int]:
        with self._lock:
            if not self._hungry:
                return None
        alive = set(self.group.alive_workers())
        with self._lock:
            while self._hungry:
                r = self._hungry.popleft()
                if r in alive:
                    return r
        return None

    def _fallback(self, lane: Lane) -> int:
        alive = self.group.alive_workers()
        if not alive:
            return 0
        return alive[next(self._rr[lane]) % len(alive)]

    def _run_local(self, rec: _TaskRecord):
        assert rec.payload is not None  # _dispatch resolved it before sending
        node = self._graph.defer(
            _LocalCall(rec.fn, self.directory, pin=rec.pin,
                       summary=rec.name),
            *rec.payload[0], lane=rec.lane,
            name=f"{rec.name}@L0", **rec.payload[1])
        rec.local_node = node
        rec.promise.home = 0
        with self._lock:
            self.dispatched[0] += 1
        node.add_done_callback(lambda n: self._transfer(rec, n))

    def _transfer(self, rec: _TaskRecord, node: PhyFuture):
        with rec.lock:
            if rec.local_node is not node:
                return   # claimed by a steal mid-flight: it re-spawns
        exc = node.exception()
        if exc is None:
            self._finish(rec, value=node.result())   # _LocalCall pinned
        else:
            self._finish(rec, exc=exc,
                         cancelled=isinstance(exc, CancelledError))

    def _finish(self, rec: _TaskRecord, *, value=None,
                exc: Optional[BaseException] = None,
                cancelled: bool = False):
        with self._lock:
            present = self._outstanding.pop(rec.tid, None) is not None
            self._by_future.pop(id(rec.promise), None)
            self._lock.notify_all()
        if not present:
            return   # settled concurrently (steal claim vs completion)
        if exc is None:
            rec.promise.set_result(value)
        else:
            rec.promise.set_exception(exc, cancelled=cancelled)

    # -- elastic membership + work stealing (DESIGN.md §13) -------------------
    def _on_join_request(self, src: int, p) -> dict:
        """Dial-in registration, phase one: grant the joiner a rank and
        ship the peer table + config spec + membership generation.  The
        joiner then becomes that ``Locality`` and hello-s like a spawned
        worker - gossip and rebalance happen at the hello."""
        if not self.elastic:
            raise RuntimeError(
                "this driver does not accept elastic joins; start it "
                "with Plan(elastic=True) / --elastic")
        rank = self.group.next_rank()
        book = {r: list(a) for r, a in self.group.addresses().items()}
        return {"rank": rank, "world": self.group.world,
                "gen": self.group.gen, "book": book,
                "spec": dict(self._join_spec)}

    def _on_member_joined(self, rank: int, addr: tuple[str, int]):
        """A locality reported in after startup (``add_locality`` spawn
        or ``--join`` dial-in).  Runs on the hello handler BEFORE the
        hello ack: bump the membership generation, gossip the join and
        the refreshed peer table (generation-keyed), and rebalance
        pinned objects toward the newcomer - so when the joiner's serve
        loop starts, every peer can reach it and it already owns a block
        of the address space."""
        ep = self.endpoint
        ep.address_book[rank] = tuple(addr)
        with self._lock:
            self.group.gen += 1
            gen = self.group.gen
            self.joined += 1
        book = {r: list(a) for r, a in self.group.addresses().items()}
        payload = {"book": book, "gen": gen, "world": self.group.world}
        for r in self.group.alive_workers():
            try:
                ep.post(r, "peers", payload)
                if r != rank:
                    ep.post(r, "peer_joined",
                            {"rank": rank, "addr": list(addr),
                             "gen": gen})
            except PeerLostError:
                continue
        self.rebalance([rank])
        with self._lock:
            self._join_done.add(rank)
            self._lock.notify_all()

    def add_locality(self, timeout: float = 120.0) -> int:
        """Spawn one extra worker locality into the *running* graph (the
        driver-side twin of a ``--join`` dial-in) and block until its
        membership gossip and AGAS rebalance completed.

        Returns:
            The new locality's rank.
        Raises:
            TimeoutError: the worker did not report in, or its join
                never settled.
        """
        if self._closed:
            raise RuntimeError("distributed graph is shut down")
        rank = self.group.add_worker(timeout=timeout)
        with self._lock:
            ok = self._lock.wait_for(lambda: rank in self._join_done,
                                     timeout=timeout)
        if not ok:
            raise TimeoutError(
                f"locality {rank} reported in but its membership gossip "
                f"did not complete within {timeout}s")
        return rank

    def rebalance(self, newcomers: list[int]) -> int:
        """AGAS rebalance pass: every pre-existing locality - the driver
        included - migrates a contiguous block of its pinned objects
        onto the ``newcomers``, leaving forwarding stubs so stale
        ``RemoteRef``s keep resolving one hop away.

        Returns:
            Total objects migrated across the cluster (also accumulated
            into ``stats()["migrated_objects"]``).
        """
        newcomers = [int(r) for r in newcomers]
        book = {r: list(a) for r, a in self.group.addresses().items()}
        moved = self.directory.rebalance(newcomers)
        for rank in self.group.alive_workers():
            if rank in newcomers:
                continue
            try:
                moved += int(self.endpoint.request(
                    rank, "agas_rebalance",
                    {"newcomers": newcomers, "book": book}, timeout=60.0))
            except (PeerLostError, TimeoutError):
                continue
        with self._lock:
            self.migrated_objects += moved
        return moved

    def _queue_depths(self) -> dict[int, int]:
        """Outstanding-task depth per locality (the load table gossiped
        in steal acks); driver-local counts cover unclaimed records."""
        depths: collections.Counter = collections.Counter()
        with self._lock:
            for rec in self._outstanding.values():
                if rec.sent:
                    depths[rec.target] += 1
                elif rec.local_node is not None:
                    depths[0] += 1
        return {int(r): int(n) for r, n in depths.items()}

    def _pick_victim(self, thief: int) -> Optional[int]:
        # count steerable work only: pinned/affinity tasks are not
        # claimable, so they must not make a locality look like a victim
        with self._lock:
            depths = collections.Counter(
                rec.target for rec in self._outstanding.values()
                if rec.sent and rec.steerable)
        # a depth-1 victim's only task is likely already running: a
        # lease there would find nothing claimable
        loaded = [r for r in self.group.alive_workers()
                  if r != thief and depths.get(r, 0) >= 2]
        if not loaded:
            return None
        return max(loaded, key=lambda r: depths[r])

    def _steal_local(self, thief: int) -> Optional[_TaskRecord]:
        """Claim one driver-local steerable record whose execution node
        has not started: detaching ``local_node`` then cancelling it IS
        the lease - a node already running refuses the cancel and the
        claim rolls back, so the task runs exactly once either way."""
        with self._lock:
            recs = [r for r in self._outstanding.values()
                    if r.steerable and r.local_node is not None]
        for rec in recs:
            with rec.lock:
                node = rec.local_node
                if node is None or rec.promise.done():
                    continue
                try:
                    # only ship what pickles: this payload never crossed
                    # a wire on the local path
                    pickle.dumps((rec.fn, rec.payload),
                                 protocol=pickle.HIGHEST_PROTOCOL)
                except Exception:  # noqa: BLE001 - unshippable: skip it
                    continue
                rec.local_node = None
            if node.cancel():
                with rec.lock:
                    rec.sent = False
                    rec.target = thief
                    rec.stolen = True
                return rec
            with rec.lock:       # running or done: roll the claim back
                if rec.local_node is None:
                    rec.local_node = node
            if node.done():
                # completion raced the claim and its _transfer saw the
                # node detached: settle now (idempotent)
                self._transfer(rec, node)
        return None

    def _on_steal_request(self, src: int, p: dict) -> dict:
        """Thief-side entry of the steal protocol: hand over a ready
        driver-local task, else lease one from the most-loaded worker,
        else park the thief - the next steerable dispatch is diverted to
        it.  The ack gossips queue depths and the membership generation;
        a request under a stale generation is fenced (PHY106) - the
        thief re-syncs from the ack and retries."""
        thief = int(p.get("thief", src))
        with self._lock:
            gen = self.group.gen
        depths = self._queue_depths()
        if int(p.get("gen", -1)) != gen:
            if _san.active():
                _san.get().record(
                    "PHY106",
                    f"steal_request from locality {thief} under stale "
                    f"membership generation {p.get('gen')} "
                    f"(current {gen})",
                    once_key=f"reqgen:{thief}:{p.get('gen')}")
            return {"handed": 0, "stale": True, "gen": gen,
                    "depths": depths}
        rec = self._steal_local(thief)
        if rec is not None:
            try:
                self._send_spawn(rec)
            except BaseException as e:  # noqa: BLE001 - never strand it
                self._finish(rec, exc=e)
                return {"handed": 0, "gen": gen, "depths": depths}
            return {"handed": 1, "gen": gen, "depths": depths}
        victim = self._pick_victim(thief)
        if victim is not None:
            try:
                self.endpoint.post(victim, "steal_lease",
                                   {"thief": thief, "gen": gen})
                return {"handed": 0, "leased": victim, "gen": gen,
                        "depths": depths}
            except PeerLostError:
                pass
        with self._lock:
            if thief not in self._hungry:
                self._hungry.append(thief)
        return {"handed": 0, "parked": True, "gen": gen, "depths": depths}

    def _on_steal_handoff(self, src: int, p: dict):
        """Victim released a leased task: re-own and re-spawn it - on
        the thief when the lease is current, on any live locality
        otherwise (the victim already cancelled its copy, so the task
        MUST re-spawn exactly once from the driver's payload).  The
        record lock serializes this with a concurrent peer-loss
        re-spawn; a lease for a record that already moved or finished is
        refused - the authoritative copy is elsewhere (PHY106)."""
        tid, thief = p["tid"], int(p["thief"])
        gen = int(p.get("gen", -1))
        with self._lock:
            rec = self._outstanding.get(tid)
            cur = self.group.gen
        if rec is None:
            return          # settled while the handoff was in flight
        with rec.lock:
            if rec.promise.done():
                return
            if not rec.sent or rec.target != src:
                # the record moved while the lease was in flight (a
                # peer-loss re-spawn won the race): refusing keeps
                # execution at exactly one locality
                if _san.active():
                    _san.get().record(
                        "PHY106",
                        f"steal handoff for {tid} from locality {src} "
                        f"refused: the record "
                        + ("was never dispatched" if not rec.sent else
                           f"is owned by locality {rec.target}")
                        + " (lease raced a re-spawn)",
                        once_key=f"handoff:{tid}")
                return
            rec.sent = False
            alive = set(self.group.alive_workers())
            if gen == cur and thief in alive:
                rec.target = thief
                rec.stolen = True
            else:
                # stale lease generation (membership changed mid-steal)
                # or a dead thief: fence the steal but never strand the
                # task - the victim's copy is already cancelled
                if _san.active():
                    _san.get().record(
                        "PHY106",
                        f"steal of {tid} fenced: "
                        + (f"lease generation {gen} != membership "
                           f"generation {cur}" if gen != cur
                           else f"thief locality {thief} is dead"),
                        once_key=f"fence:{tid}")
                rec.target = self._fallback(rec.lane)
        try:
            self._send_spawn(rec)
        except BaseException as e:  # noqa: BLE001 - never strand it
            self._finish(rec, exc=e)

    # -- SPMD checkpointing (addressable shards; DESIGN.md §10) ---------------
    def account_ckpt_leaf_bytes(self, n: int):
        """Record ``n`` checkpoint leaf bytes about to ship in a task
        payload (host-copy saves); SPMD saves never call this."""
        with self._lock:
            self.ckpt_leaf_wire_bytes += int(n)

    # -- fabric DDP (ring all-reduce; DESIGN.md §11) --------------------------
    def account_grad_wire_bytes(self, n: int):
        """Record ``n`` gradient payload bytes the driver's ring sent
        (own encodes + relays); wired as the driver ring's ``account``
        callback."""
        with self._lock:
            self.grad_wire_bytes += int(n)

    def ddp_train(self, spec: dict):
        """Start the fabric-DDP train loop (``frontend.ddp``) on every
        alive worker locality; the driver runs its own shard block
        in-process via ``Session._train_ddp``.

        Args:
            spec: picklable dict - ``plan``, ``steps``, ``ckpt_dir``,
                ``resume``, ``stream``, ``gen`` (the driver ring's
                generation, so all rings key segments identically).
        """
        with self._lock:
            self._ddp_done.clear()     # completions are per-run
            self.grad_wire_bytes = 0   # accounting too (re-entrant trains)
        for rank in self.group.alive_workers():
            try:
                self.endpoint.post(rank, "ddp_train", spec)
            except PeerLostError:      # died since alive_workers(): the
                pass                   # peer-loss hook aborts the ring

    def ddp_abort(self, reason: str):
        """Poison the whole ring: locally and (best-effort) on every
        alive worker.  Survivor localities with no direct connection to
        a dead rank would otherwise block until timeout."""
        self.grad_ring.abort(reason)
        for rank in self.group.alive_workers():
            try:
                self.endpoint.post(rank, "ddp_abort", reason)
            except PeerLostError:
                pass

    def _on_ddp_done(self, src: int, msg: dict):
        with self._lock:
            self._ddp_done[int(msg["rank"])] = msg
            self._lock.notify_all()

    def wait_ddp_done(self, timeout: float = 600.0) -> dict:
        """Block until every *alive* worker's DDP loop reported
        completion (a killed worker is excused - the run already
        aborted).

        Returns:
            ``{rank: done message}`` as received, each carrying ``ok``
            and ``grad_wire_bytes``.
        Raises:
            TimeoutError: an alive worker's DDP loop did not finish.
        """
        deadline = time.monotonic() + timeout

        def ready():
            alive = set(self.group.alive_workers())
            return all(r in self._ddp_done for r in alive)

        with self._lock:
            ok = self._lock.wait_for(
                ready, timeout=max(0.0, deadline - time.monotonic()))
            done = dict(self._ddp_done)
        if not ok:
            raise TimeoutError("DDP train loops still running after "
                               f"{timeout}s")
        return done

    def spmd_train(self, spec: dict):
        """Start the SPMD shadow train loop (``frontend.spmd``) on every
        alive worker locality: each mirrors the driver's device
        computation in lockstep and writes its own addressable
        checkpoint shards.

        Args:
            spec: picklable dict - ``plan``, ``steps``, ``ckpt_every``,
                ``ckpt_dir``, ``resume``, ``stream``.
        """
        with self._lock:
            self._spmd_done.clear()    # completions are per-run
        for rank in self.group.alive_workers():
            try:
                self.endpoint.post(rank, "spmd_train", spec)
            except PeerLostError:      # died since alive_workers(): its
                pass                   # entry promises poison via peer loss

    def spmd_entry_futures(self, step: int, ranks) -> list[PhyFuture]:
        """One promise per other jax process for its shard manifest
        entry of ``step`` - the metadata-only return channel of an SPMD
        save.  A promise for an already-dead locality (or one whose
        locality dies before posting) is poisoned with
        ``LocalityLostError``: its bytes exist nowhere else, so the save
        must abort, never commit.

        Args:
            step: the save's step number.
            ranks: the non-driver process ranks expected to write.
        Returns:
            List of ``PhyFuture`` resolving to the entries (or None for
            a rank that had nothing to write).
        """
        out = []
        for r in ranks:
            key = (int(step), int(r))
            p = self._graph.promise(name=f"ckpt:entry{r}:{step}",
                                    lane=Lane.CHECKPOINT,
                                    producer=f"L{r}")
            settle = None
            with self._lock:
                done = self._spmd_done.get(int(r))
                if key in self._spmd_entries and not isinstance(
                        self._spmd_entries[key], PhyFuture):
                    settle = ("value", self._spmd_entries.pop(key))
                elif r != 0 and r not in self.group.alive_workers():
                    settle = ("lost", f"locality {r} is not alive")
                elif done is not None and not done.get("ok"):
                    # the shadow ALREADY failed on a live worker: this
                    # entry will never be posted
                    settle = ("lost", f"SPMD shadow on locality {r} "
                                      f"failed: {done.get('error')}")
                else:
                    self._spmd_entries[key] = p
            if settle is None:
                pass
            elif settle[0] == "value":
                p.set_result(settle[1])
            else:
                p.set_exception(LocalityLostError(
                    f"ckpt entry for step {step}: {settle[1]}; its "
                    f"addressable shards exist nowhere else - SPMD "
                    f"save aborted"))
            out.append(p)
        return out

    def _on_ckpt_entries(self, src: int, msg: dict):
        key = (int(msg["step"]), int(msg["rank"]))
        with self._lock:
            cur = self._spmd_entries.get(key)
            if isinstance(cur, PhyFuture):
                del self._spmd_entries[key]
            else:                    # worker ahead of the driver: buffer
                self._spmd_entries[key] = msg["entry"]
                cur = None
        if cur is not None:
            cur.set_result(msg["entry"])

    def _on_spmd_done(self, src: int, msg: dict):
        with self._lock:
            self._spmd_done[int(msg["rank"])] = msg
            self._lock.notify_all()
        if not msg.get("ok"):
            # the shadow died: entries it still owes will never arrive
            self._poison_spmd_entries(
                int(msg["rank"]),
                f"SPMD shadow on locality {msg['rank']} failed: "
                f"{msg.get('error')}")

    def _poison_spmd_entries(self, rank: int, reason: str):
        with self._lock:
            pend = [(k, v) for k, v in self._spmd_entries.items()
                    if k[1] == rank and isinstance(v, PhyFuture)]
            for k, _ in pend:
                del self._spmd_entries[k]
        for _, p in pend:
            p.set_exception(LocalityLostError(reason))

    def wait_spmd_done(self, timeout: float = 600.0) -> dict:
        """Block until every *alive* worker's shadow train loop reported
        completion (a killed worker is excused - its saves aborted).

        Returns:
            ``{rank: done message}`` as received.
        Raises:
            TimeoutError: an alive worker's shadow did not finish.
        """
        deadline = time.monotonic() + timeout

        def ready():
            alive = set(self.group.alive_workers())
            return all(r in self._spmd_done for r in alive)

        with self._lock:
            ok = self._lock.wait_for(
                ready, timeout=max(0.0, deadline - time.monotonic()))
            done = dict(self._spmd_done)
        if not ok:
            raise TimeoutError("SPMD shadow train loops still running "
                               f"after {timeout}s")
        return done

    # -- wire handlers --------------------------------------------------------
    def _on_task_done(self, src: int, msg: dict):
        with self._lock:
            rec = self._outstanding.get(msg["tid"])
        if rec is None:
            return                           # cancelled/re-spawned: stale
        status = msg["status"]
        if status == "ok" and rec.sent and src != rec.target:
            # a completion from a locality that no longer owns the record
            # means the task ran somewhere the driver had moved it away
            # from - the exactly-once invariant broke (PHY106).  The
            # result is still good: settle with it (the owning copy's
            # duplicate spawn was dropped on arrival).
            if _san.active():
                _san.get().record(
                    "PHY106",
                    f"task {msg['tid']} ({rec.name}) completed on "
                    f"locality {src} but the record is owned by locality "
                    f"{rec.target} - steal-lease violation",
                    once_key=f"done:{msg['tid']}")
        if status == "ok":
            self._finish(rec, value=msg["value"])
        elif status == "cancelled":
            self._finish(rec, exc=CancelledError(rec.name), cancelled=True)
        else:
            self._finish(rec, exc=msg["exc"])

    def _on_peer_lost(self, rank: int):
        self.group.note_lost(rank)
        if self.elastic:
            # membership changed: bump the generation and gossip the
            # leave, so steals planned against the old peer table fence
            # instead of landing on (or crediting) a ghost
            with self._lock:
                self.group.gen += 1
                gen = self.group.gen
                if rank in self._hungry:
                    self._hungry = collections.deque(
                        r for r in self._hungry if r != rank)
            for r in self.group.alive_workers():
                try:
                    self.endpoint.post(r, "peer_joined",
                                       {"rank": rank, "event": "left",
                                        "gen": gen})
                except PeerLostError:
                    continue
        if self.grad_ring.active:
            # a DDP exchange is in flight: poison it everywhere - a
            # survivor with no direct connection to the dead rank never
            # observes the loss itself
            self.ddp_abort(f"locality {rank} died mid-all-reduce")
        # SPMD shard entries die with their writer: poison, never re-spawn
        self._poison_spmd_entries(
            rank, f"locality {rank} died before shipping its shard "
                  f"entry; its addressable shards exist nowhere else - "
                  f"SPMD save aborted")
        with self._lock:
            stranded = [r for r in self._outstanding.values()
                        if r.target == rank]
        for rec in stranded:
            with rec.lock:
                # re-check under the record lock: a concurrent dispatch
                # may have already moved it to a live locality
                if rec.promise.done() or rec.target != rank:
                    continue
                if not rec.sent:
                    # never reached the dead locality: just retarget
                    # (_send_spawn re-picks at send time anyway)
                    rec.target = self._fallback(rec.lane)
                    continue
                rec.sent = False
                rec.target = self._fallback(rec.lane)
            lost_refs = any(
                isinstance(leaf, RemoteRef) and leaf.owner == rank
                for leaf in jax.tree.leaves(rec.payload, is_leaf=_is_ref))
            if not rec.idempotent or lost_refs:
                self._finish(rec, exc=LocalityLostError(
                    f"{rec.name}: locality {rank} died "
                    + ("holding its input data"
                       if lost_refs else "and the task is not idempotent")))
                continue
            with self._lock:
                self.respawned += 1
            try:
                self._send_spawn(rec)
            except BaseException as e:  # noqa: BLE001 - see _dispatch
                self._finish(rec, exc=e)

    # -- observability --------------------------------------------------------
    def stats(self) -> dict:
        """Driver-side counters: per-locality dispatch counts, re-spawns,
        outstanding tasks, and wire bytes."""
        with self._lock:
            return {"dispatched": dict(self.dispatched),
                    "respawned": self.respawned,
                    "outstanding": len(self._outstanding),
                    "alive_workers": self.group.alive_workers(),
                    "bytes_sent": self.endpoint.bytes_sent,
                    "bytes_recv": self.endpoint.bytes_recv,
                    "ckpt_leaf_wire_bytes": self.ckpt_leaf_wire_bytes,
                    "grad_wire_bytes": self.grad_wire_bytes,
                    "stolen_tasks": self.stolen_tasks,
                    "migrated_objects": self.migrated_objects,
                    "joined_localities": self.joined,
                    "membership_gen": self.group.gen,
                    "unhandled_posts": dict(
                        self.endpoint.unhandled_posts)}

    def remote_stats(self, rank: int, timeout: float = 30.0) -> dict:
        """A worker locality's own ``RuntimeStats`` JSON (plus directory
        size and wire bytes), fetched over the wire."""
        return self.endpoint.request(rank, "stats", timeout=timeout)

    # -- lifecycle ------------------------------------------------------------
    def barrier(self, timeout: float = 120.0):
        """Block until every distributed task has streamed back.

        Raises:
            TimeoutError: outstanding tasks remain after ``timeout``.
        """
        deadline = time.monotonic() + timeout
        with self._lock:
            ok = self._lock.wait_for(
                lambda: not self._outstanding,
                timeout=max(0.0, deadline - time.monotonic()))
        if not ok:
            raise TimeoutError(
                f"{len(self._outstanding)} distributed tasks outstanding")

    def shutdown(self, wait: bool = True, timeout: float = 120.0):
        """Drain distributed work (or poison it), stop the workers, and
        shut the local graph down if this object created it."""
        if self._closed:
            return
        self._closed = True
        if wait:
            try:
                self.barrier(timeout=timeout)
            except TimeoutError:
                pass
        with self._lock:
            stranded = list(self._outstanding.values())
            entry_pend = [(k, v) for k, v in self._spmd_entries.items()
                          if isinstance(v, PhyFuture)]
            self._spmd_entries.clear()
        for rec in stranded:
            self._finish(rec, exc=LocalityLostError(
                f"{rec.name}: distributed graph shut down"))
        for k, p in entry_pend:        # an unresolved promise would hang
            p.set_exception(LocalityLostError(  # the graph's barrier
                f"ckpt entry for step {k[0]}: distributed graph shut "
                f"down"))
        self.group.shutdown()
        if self._own_graph:
            self._graph.shutdown(wait=True)


class _LocalCall:
    """Driver-local execution of a (possibly ref-holding) task payload;
    picklable-agnostic because it never crosses the wire.  Honors the
    same ``pin`` contract as remote execution: the value stays in the
    driver's directory and the caller sees a ``RemoteRef``."""

    def __init__(self, fn: Callable, directory: ObjectDirectory, *,
                 pin: bool = False, summary: str = ""):
        self.fn = fn
        self.directory = directory
        self.pin = pin
        self.summary = summary
        self.__name__ = getattr(fn, "__name__", "task")

    def __call__(self, *args, **kwargs):
        a, kw = _deref_tree((args, kwargs), self.directory)
        value = self.fn(*a, **kw)
        if self.pin:
            value = self.directory.put(value, summary=self.summary
                                       or self.__name__)
        return value


def _checksum_vote(*results):
    """Majority vote by content checksum over replica results (HPX
    replicate); no majority means corruption we cannot arbitrate."""
    sums = [tree_checksum(r) for r in results]
    counts = collections.Counter(sums)
    best, votes = counts.most_common(1)[0]
    if votes <= len(results) // 2 and len(results) > 1:
        raise RemoteTaskError(
            f"replicate: no checksum majority across {len(results)} "
            f"localities ({counts})")
    return results[sums.index(best)]
