"""Multi-locality runtime (DESIGN.md §9): execute the futurized graph
across processes.  ``messaging`` is the TCP active-message (parcel)
layer, ``agas`` the global object directory, ``runtime`` the
``Locality``/``DistributedGraph`` scheduler that places tasks by lane +
data affinity and streams results back as futures resolve."""
from .agas import ObjectDirectory, RemoteRef, rebalance_plan  # noqa: F401
from .collectives import (CODECS, Fp32Codec, GradCodec,  # noqa: F401
                          OneBitCodec, RingAllReduce, get_codec)
from .messaging import Endpoint, PeerLostError, raw_request  # noqa: F401
from .runtime import (DistributedGraph, Locality,  # noqa: F401
                      LocalityGroup, LocalityLostError, RemoteTaskError,
                      join_locality, worker_main)

__all__ = ["CODECS", "DistributedGraph", "Endpoint", "Fp32Codec",
           "GradCodec", "Locality", "LocalityGroup", "LocalityLostError",
           "ObjectDirectory", "OneBitCodec", "PeerLostError", "RemoteRef",
           "RemoteTaskError", "RingAllReduce", "get_codec", "join_locality",
           "raw_request", "rebalance_plan", "worker_main"]
