"""phylint — static passes over the futurized execution tree.

The linter runs over a :class:`LintGraph`, a small immutable-ish IR that
can be built three ways:

* :meth:`LintGraph.from_trace` — from a ``@futurize``/:func:`repro.frontend.futurize.tracing`
  :class:`~repro.frontend.futurize.Trace` (no execution needed beyond what
  produced the trace);
* :meth:`LintGraph.from_graph` — from a live :class:`~repro.core.futures.FuturizedGraph`
  via its ``snapshot()`` (post-mortem or mid-run inspection);
* directly via :meth:`LintGraph.add` — used by the dryrun trace builders in
  :mod:`repro.analysis.trace_builders` and by tests that seed defects.

Rule catalogue (static layer; the dynamic PHY1xx layer lives in
``analysis/sanitize.py``, full failure model in DESIGN.md §12):

===========  ==============================================================
PHY001       dependency cycle in the execution tree
PHY002       orphaned promise: created but no producer ever registered
PHY003       lane-priority inversion: a node depends on strictly
             lower-priority work (COMPUTE waiting on CHECKPOINT).  The
             PREFETCH -> COMPUTE feed edge is the sanctioned hand-off
             pattern and is exempt unless ``strict_lanes=True``.
PHY004       dead node: a sink whose result is never forced (and was not
             explicitly cancelled) — scheduled work nobody observes
PHY005       donation-after-use: a buffer donated to a jitted step is
             referenced by a later node (the DDPStep donation contract)
PHY006       fan-in hotspot: one node joins >= ``fanin_threshold`` deps
             directly (a serialization point the scheduler cannot hide)
===========  ==============================================================

Every finding carries the stable rule id, the node names involved and a
source hint, so CI output is grep-able and tests can assert exact ids.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Iterable, Sequence

if TYPE_CHECKING:  # pragma: no cover - import only for annotations
    from ..core.futures import FuturizedGraph
    from ..frontend.futurize import Trace

#: Static rule catalogue (id -> one-line summary).
STATIC_RULES: dict[str, str] = {
    "PHY001": "dependency cycle in the execution tree",
    "PHY002": "orphaned promise (no producer registered)",
    "PHY003": "lane-priority inversion",
    "PHY004": "dead node (result never forced)",
    "PHY005": "donated buffer referenced after donation",
    "PHY006": "fan-in hotspot",
}

#: Lane priorities, mirroring core.futures.Lane (lower value = higher
#: priority). Kept as a plain dict so the IR stays importable standalone.
_LANE_PRIO = {"COMPUTE": 0, "PREFETCH": 1, "CHECKPOINT": 2}

#: Default PHY006 threshold: a direct fan-in this wide is a join the
#: scheduler cannot overlap away (ckpt manifests joining every shard stay
#: far below this for any shipped topology).
DEFAULT_FANIN_THRESHOLD = 64


@dataclass(frozen=True)
class Finding:
    """One linter finding with a stable rule id."""

    rule: str
    message: str
    nodes: tuple[str, ...] = ()
    src: str = ""

    def __str__(self) -> str:
        where = f" [{', '.join(self.nodes)}]" if self.nodes else ""
        hint = f" ({self.src})" if self.src else ""
        return f"{self.rule} {self.message}{where}{hint}"


@dataclass
class LintNode:
    """IR node: one future in the execution tree.

    ``kind`` is one of ``task`` (deferred callable), ``promise``
    (externally resolved), ``immediate`` (already-done constant) or
    ``device`` (virtual node modelling a jitted device step for the
    donation contract — produced only by the step-contract builders).
    """

    index: int
    name: str
    lane: str = "COMPUTE"
    kind: str = "task"
    deps: tuple[int, ...] = ()
    forced: bool = False
    cancelled: bool = False
    producer: str = ""
    uses: tuple[str, ...] = ()
    donates: tuple[str, ...] = ()
    src: str = ""


class LintGraph:
    """The linter's IR: an ordered list of nodes with integer-index deps."""

    def __init__(self, label: str = "") -> None:
        self.label = label
        self.nodes: list[LintNode] = []
        self._by_name: dict[str, int] = {}
        # PHY004 only fires when the builder declared which results are
        # forced; raw traces carry no such information.
        self.has_forced_info = False

    # -- construction -------------------------------------------------

    def add(
        self,
        name: str,
        *,
        lane: str = "COMPUTE",
        kind: str = "task",
        deps: Sequence[int | str] = (),
        forced: bool = False,
        cancelled: bool = False,
        producer: str = "",
        uses: Sequence[str] = (),
        donates: Sequence[str] = (),
        src: str = "",
    ) -> int:
        """Append a node; ``deps`` may mix indices and (last-bound) names."""
        idx = len(self.nodes)
        dep_idx = tuple(self._resolve(d) for d in deps)
        self.nodes.append(
            LintNode(
                index=idx,
                name=name,
                lane=lane,
                kind=kind,
                deps=dep_idx,
                forced=forced,
                cancelled=cancelled,
                producer=producer,
                uses=tuple(uses),
                donates=tuple(donates),
                src=src,
            )
        )
        self._by_name[name] = idx
        if forced:
            self.has_forced_info = True
        return idx

    def _resolve(self, dep: int | str) -> int:
        if isinstance(dep, str):
            if dep not in self._by_name:
                raise KeyError(f"unknown dep name {dep!r} in lint graph {self.label!r}")
            return self._by_name[dep]
        if not 0 <= dep < len(self.nodes):
            raise IndexError(f"dep index {dep} out of range in lint graph {self.label!r}")
        return int(dep)

    def mark_forced(self, *refs: int | str) -> None:
        """Declare that these nodes' results are observed by the program."""
        for ref in refs:
            self.nodes[self._resolve(ref)].forced = True
        self.has_forced_info = True

    # -- importers ----------------------------------------------------

    @classmethod
    def from_trace(cls, trace: "Trace", *, forced: Iterable[int | str] | None = None, label: str = "") -> "LintGraph":
        """Build the IR from a recorded ``@futurize`` trace.

        ``forced`` optionally declares which node results the program
        observes; without it the PHY004 dead-node pass is skipped (a raw
        trace cannot know what the caller later forces).
        """
        g = cls(label or "trace")
        for tn in trace.nodes:
            g.add(
                tn.name,
                lane=tn.lane,
                kind=getattr(tn, "kind", "task"),
                deps=tuple(tn.deps),
                producer=getattr(tn, "producer", ""),
                src=f"trace[{tn.index}]",
            )
        if forced is not None:
            g.mark_forced(*forced)
        return g

    @classmethod
    def from_graph(cls, graph: "FuturizedGraph", *, label: str = "") -> "LintGraph":
        """Build the IR from a live graph via ``FuturizedGraph.snapshot()``.

        The snapshot knows true per-node state, so forced/cancelled flags
        are exact: ``forced`` means someone called ``result()`` /
        ``exception()``, attached a done-callback, or deferred a
        dependent onto the value (``fanout`` - the dependent itself may
        already be collected from the snapshot); resolved promises count
        as produced even without a declared producer.
        """
        g = cls(label or "graph")
        seq_to_idx: dict[int, int] = {}
        for snap in graph.snapshot():
            deps = tuple(seq_to_idx[s] for s in snap["deps"] if s in seq_to_idx)
            producer = snap["producer"]
            if snap["kind"] == "promise" and not producer and snap["state"] not in ("PENDING",):
                producer = "<resolved>"
            idx = g.add(
                snap["name"],
                lane=snap["lane"],
                kind=snap["kind"],
                deps=deps,
                forced=snap["observed"] or snap.get("fanout", 0) > 0,
                cancelled=snap["state"] == "CANCELLED",
                producer=producer,
                src=f"seq={snap['seq']} state={snap['state']}",
            )
            seq_to_idx[snap["seq"]] = idx
        g.has_forced_info = True
        return g

    # -- misc ----------------------------------------------------------

    def __len__(self) -> int:
        return len(self.nodes)

    def names(self) -> list[str]:
        return [n.name for n in self.nodes]


# ----------------------------------------------------------------------
# passes
# ----------------------------------------------------------------------


def _pass_cycles(g: LintGraph) -> list[Finding]:
    """PHY001 via Tarjan SCC: every SCC of size > 1 (or a self-loop) is a cycle."""
    n = len(g.nodes)
    index = [0] * n
    low = [0] * n
    on_stack = [False] * n
    visited = [False] * n
    stack: list[int] = []
    counter = [1]
    findings: list[Finding] = []

    def strongconnect(v0: int) -> None:
        work = [(v0, 0)]
        while work:
            v, pi = work[-1]
            if pi == 0:
                visited[v] = True
                index[v] = low[v] = counter[0]
                counter[0] += 1
                stack.append(v)
                on_stack[v] = True
            recurse = False
            deps = g.nodes[v].deps
            for i in range(pi, len(deps)):
                w = deps[i]
                if not visited[w]:
                    work[-1] = (v, i + 1)
                    work.append((w, 0))
                    recurse = True
                    break
                if on_stack[w]:
                    low[v] = min(low[v], index[w])
            if recurse:
                continue
            if low[v] == index[v]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    scc.append(w)
                    if w == v:
                        break
                if len(scc) > 1 or v in g.nodes[v].deps:
                    names = tuple(g.nodes[i].name for i in sorted(scc))
                    findings.append(
                        Finding(
                            "PHY001",
                            f"dependency cycle of {len(scc)} node(s): forcing any of them deadlocks",
                            nodes=names,
                            src=g.nodes[scc[0]].src,
                        )
                    )
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[v])

    for v in range(n):
        if not visited[v]:
            strongconnect(v)
    return findings


def _pass_orphan_promises(g: LintGraph) -> list[Finding]:
    """PHY002: a promise nobody ever committed to resolving."""
    out = []
    for node in g.nodes:
        if node.kind == "promise" and not node.producer and not node.cancelled:
            out.append(
                Finding(
                    "PHY002",
                    f"promise {node.name!r} has no registered producer; any wait on it hangs",
                    nodes=(node.name,),
                    src=node.src,
                )
            )
    return out


def _pass_lane_inversion(g: LintGraph, *, strict_lanes: bool) -> list[Finding]:
    """PHY003: higher-priority node blocked behind lower-priority work."""
    out = []
    for node in g.nodes:
        np_ = _LANE_PRIO.get(node.lane, 0)
        for d in node.deps:
            dep = g.nodes[d]
            dp = _LANE_PRIO.get(dep.lane, 0)
            if np_ >= dp:
                continue
            if not strict_lanes and dep.lane == "PREFETCH" and node.lane == "COMPUTE":
                continue  # sanctioned feed edge: compute consuming prefetched input
            out.append(
                Finding(
                    "PHY003",
                    f"{node.lane} node {node.name!r} depends on {dep.lane} node "
                    f"{dep.name!r}: the high-priority lane inherits the low one's latency",
                    nodes=(node.name, dep.name),
                    src=node.src,
                )
            )
    return out


def _pass_dead_nodes(g: LintGraph) -> list[Finding]:
    """PHY004: sinks nobody forces — scheduled work with no observer."""
    if not g.has_forced_info:
        return []
    has_dependent = [False] * len(g.nodes)
    for node in g.nodes:
        for d in node.deps:
            has_dependent[d] = True
    out = []
    for node in g.nodes:
        if has_dependent[node.index] or node.forced or node.cancelled:
            continue
        if node.kind in ("immediate", "promise", "device"):
            continue  # covered by PHY002 / not host work
        out.append(
            Finding(
                "PHY004",
                f"node {node.name!r} is never forced and has no dependents; "
                "its work (and any error it raises) is silently dropped",
                nodes=(node.name,),
                src=node.src,
            )
        )
    return out


def _pass_donation(g: LintGraph) -> list[Finding]:
    """PHY005: buffer referenced at/after the submission point that donates it.

    Submission order approximates execution order for the step sequence;
    a node submitted after the donating step that still names the donated
    buffer is reading memory XLA has already been told it may reuse.
    """
    donated_at: dict[str, int] = {}
    out = []
    for node in g.nodes:
        for buf in node.uses:
            d = donated_at.get(buf)
            if d is not None:
                out.append(
                    Finding(
                        "PHY005",
                        f"node {node.name!r} reads buffer {buf!r} already donated by "
                        f"{g.nodes[d].name!r} (donate_argnums contract)",
                        nodes=(g.nodes[d].name, node.name),
                        src=node.src,
                    )
                )
        for buf in node.donates:
            d = donated_at.get(buf)
            if d is not None:
                out.append(
                    Finding(
                        "PHY005",
                        f"node {node.name!r} re-donates buffer {buf!r} already donated by "
                        f"{g.nodes[d].name!r}",
                        nodes=(g.nodes[d].name, node.name),
                        src=node.src,
                    )
                )
            else:
                donated_at[buf] = node.index
    return out


def _pass_fanin(g: LintGraph, *, threshold: int) -> list[Finding]:
    """PHY006: direct joins wide enough to serialize the scheduler."""
    out = []
    for node in g.nodes:
        if len(node.deps) >= threshold:
            out.append(
                Finding(
                    "PHY006",
                    f"node {node.name!r} joins {len(node.deps)} dependencies directly "
                    f"(threshold {threshold}); consider a tree reduction",
                    nodes=(node.name,),
                    src=node.src,
                )
            )
    return out


def lint(
    obj: "LintGraph | Trace | FuturizedGraph",
    *,
    strict_lanes: bool = False,
    fanin_threshold: int = DEFAULT_FANIN_THRESHOLD,
) -> list[Finding]:
    """Run every static pass; returns findings ordered by rule id.

    ``obj`` may be a :class:`LintGraph`, a frontend ``Trace`` or a live
    ``FuturizedGraph`` (snapshotted without executing anything further).
    """
    g = _coerce(obj)
    findings: list[Finding] = []
    findings += _pass_cycles(g)
    findings += _pass_orphan_promises(g)
    findings += _pass_lane_inversion(g, strict_lanes=strict_lanes)
    findings += _pass_dead_nodes(g)
    findings += _pass_donation(g)
    findings += _pass_fanin(g, threshold=fanin_threshold)
    findings.sort(key=lambda f: (f.rule, f.nodes))
    return findings


def _coerce(obj: "LintGraph | Trace | FuturizedGraph") -> LintGraph:
    if isinstance(obj, LintGraph):
        return obj
    # duck-typed: a Trace has .nodes of TraceNode, a graph has .snapshot()
    if hasattr(obj, "snapshot"):
        return LintGraph.from_graph(obj)  # type: ignore[arg-type]
    if hasattr(obj, "nodes"):
        return LintGraph.from_trace(obj)  # type: ignore[arg-type]
    raise TypeError(f"cannot lint object of type {type(obj).__name__}")
