"""phylint: static execution-tree analysis + runtime concurrency sanitizer.

Two layers (DESIGN.md §12):

* **static** — :mod:`repro.analysis.lint` runs passes (PHY001–PHY006) over
  a built graph, a ``@futurize`` trace, or the dryrun mirrors in
  :mod:`repro.analysis.trace_builders`, without executing anything;
* **dynamic** — :mod:`repro.analysis.sanitize` (armed by
  ``PHYRAX_SANITIZE=1``) turns hangs and silent protocol violations into
  named diagnostics (PHY101–PHY105): a wait-for-graph deadlock watchdog,
  active-message protocol checks, and AGAS pin/deref accounting.

``sanitize`` is imported eagerly (it is stdlib-only and ``core.futures``
hooks into it at import time); the lint layer imports the core and is
loaded lazily so ``repro.core.futures -> repro.analysis`` stays acyclic.
"""

from __future__ import annotations

from . import sanitize
from .sanitize import DeadlockError, Diagnostic, Sanitizer

_LAZY = {
    # NOTE: the ``lint`` *function* is deliberately not re-exported here:
    # ``repro.analysis.lint`` must always name the submodule regardless of
    # import order (a lazy function attr would shadow it).  Call it as
    # ``lint.lint(...)`` or import it from the submodule.
    "Finding": "lint",
    "LintGraph": "lint",
    "LintNode": "lint",
    "STATIC_RULES": "lint",
    "gateway_trace": "trace_builders",
    "plan_traces": "trace_builders",
    "serve_trace": "trace_builders",
    "step_contract": "trace_builders",
    "train_trace": "trace_builders",
}

__all__ = sorted(
    ["DeadlockError", "Diagnostic", "Sanitizer", "sanitize", *_LAZY]
)


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(f".{mod}", __name__), name)
    globals()[name] = value
    return value
