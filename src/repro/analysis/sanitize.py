"""Runtime concurrency sanitizer for the futurized runtime (PHYRAX_SANITIZE=1).

This module is the *collection point* for dynamic diagnostics; the hooks
that feed it live in `core/futures.py` (wait-for-graph deadlock watchdog),
`distrib/messaging.py` (active-message protocol checks), `distrib/agas.py`
(pin/deref accounting, forwarding-stub chases), `distrib/runtime.py`
(steal-lease / membership-generation fencing) and
`distrib/collectives.py` (generation-key monotonicity).  It deliberately imports nothing from the rest of the
package so that `core.futures` can import it at module load without a
cycle.

Rule ids (dynamic layer — the static layer PHY001-PHY006 lives in
`analysis/lint.py`):

===========  ==============================================================
PHY101       deadlock: cycle in the wait-for graph, or a wait whose every
             progress path ends in an unproduced promise
PHY102       post to an unregistered active-message action
PHY103       non-monotone ring generation key (configure(gen=) regressed)
PHY104       reply/ack dropped because the peer is already dead
PHY105       unbalanced AGAS accounting (fetch-after-free, fetch or free of
             a never-registered gid)
PHY106       steal-lease violation: a task observed executing on two
             localities, or a steal under a stale membership generation
PHY107       deref chased a forwarding stub whose target is dead (freed
             value or lost locality after an elastic rebalance)
===========  ==============================================================

Activation: set ``PHYRAX_SANITIZE=1`` in the environment (inherited by
spawned localities), or use :func:`enabled` as a context manager in tests.
When inactive the hooks cost one dict lookup per wait and nothing else.
"""

from __future__ import annotations

import contextlib
import logging
import os
import threading
import time
from dataclasses import dataclass, field

log = logging.getLogger("repro.sanitize")

#: Dynamic rule catalogue (see DESIGN.md §12 for the full failure model).
DYNAMIC_RULES: dict[str, str] = {
    "PHY101": "wait-for-graph deadlock (cycle or unproduced-promise stall)",
    "PHY102": "post to unregistered active-message action",
    "PHY103": "non-monotone ring generation key",
    "PHY104": "reply to dead peer dropped",
    "PHY105": "unbalanced AGAS pin/deref accounting",
    "PHY106": "steal-lease violation (double execution or stale "
              "membership generation)",
    "PHY107": "deref through a dead forwarding stub",
}


class DeadlockError(RuntimeError):
    """Raised by sanitized waits instead of hanging forever.

    Carries the wait-for cycle (or stalled frontier) and a dump of every
    live thread's stack at detection time.
    """


@dataclass(frozen=True)
class Diagnostic:
    """One sanitizer finding: a stable rule id plus a human-readable message."""

    rule: str
    message: str
    detail: str = ""

    def __str__(self) -> str:
        head = f"{self.rule}: {self.message}"
        return f"{head}\n{self.detail}" if self.detail else head


@dataclass
class _Config:
    # seconds a single wait may stall before the watchdog scans for cycles
    deadlock_after: float = 2.0
    # seconds before a wait whose only frontier is unproduced promises raises
    orphan_after: float = 60.0
    # chunk size for sanitized condition waits (watchdog poll period)
    chunk: float = 0.25


@dataclass
class Sanitizer:
    """Thread-safe diagnostic sink shared by all sanitized components."""

    config: _Config = field(default_factory=_Config)
    _diags: list[Diagnostic] = field(default_factory=list)
    _once: set[str] = field(default_factory=set)
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def record(self, rule: str, message: str, *, detail: str = "", once_key: str | None = None) -> Diagnostic | None:
        """Record one diagnostic; with ``once_key`` repeats are coalesced."""
        with self._lock:
            if once_key is not None:
                key = f"{rule}:{once_key}"
                if key in self._once:
                    return None
                self._once.add(key)
            diag = Diagnostic(rule, message, detail)
            self._diags.append(diag)
        log.warning("%s", diag)
        return diag

    def diagnostics(self, rule: str | None = None) -> list[Diagnostic]:
        with self._lock:
            return [d for d in self._diags if rule is None or d.rule == rule]

    def clear(self) -> None:
        with self._lock:
            self._diags.clear()
            self._once.clear()


_SANITIZER = Sanitizer()
_FORCED: int | None = None  # tri-state programmatic override (tests)


def get() -> Sanitizer:
    """The process-global sanitizer instance."""
    return _SANITIZER


def active() -> bool:
    """Whether sanitized code paths should collect diagnostics.

    Re-reads the environment on every call (cheap) so localities spawned
    with ``PHYRAX_SANITIZE=1`` arm themselves without import-order games.
    """
    if _FORCED is not None:
        return bool(_FORCED)
    return os.environ.get("PHYRAX_SANITIZE", "") not in ("", "0")


@contextlib.contextmanager
def enabled(*, deadlock_after: float | None = None, orphan_after: float | None = None, chunk: float | None = None):
    """Context manager: force the sanitizer on (tests) with tuned timeouts."""
    global _FORCED
    cfg = _SANITIZER.config
    prev = (_FORCED, cfg.deadlock_after, cfg.orphan_after, cfg.chunk)
    _FORCED = 1
    if deadlock_after is not None:
        cfg.deadlock_after = deadlock_after
    if orphan_after is not None:
        cfg.orphan_after = orphan_after
    if chunk is not None:
        cfg.chunk = chunk
    try:
        yield _SANITIZER
    finally:
        _FORCED, cfg.deadlock_after, cfg.orphan_after, cfg.chunk = prev


def config() -> _Config:
    cfg = _SANITIZER.config
    if _FORCED is None:  # env-driven runs may tune timeouts via env too
        try:
            cfg.deadlock_after = float(os.environ.get("PHYRAX_SANITIZE_DEADLOCK_S", cfg.deadlock_after))
            cfg.orphan_after = float(os.environ.get("PHYRAX_SANITIZE_ORPHAN_S", cfg.orphan_after))
        except ValueError:
            pass
    return cfg


def find_cycle(edges: dict[object, tuple[object, ...]], roots: tuple[object, ...]) -> list[object] | None:
    """Find one cycle reachable from ``roots`` in a digraph, or None.

    Iterative DFS with the classic white/grey/black coloring; returns the
    cycle as a list of vertices (first == repeated vertex is *not*
    appended).  Used by the deadlock watchdog over the bipartite
    thread/node wait-for graph.
    """
    WHITE, GREY, BLACK = 0, 1, 2
    color: dict[object, int] = {}
    parent: dict[object, object] = {}
    for root in roots:
        if color.get(root, WHITE) != WHITE:
            continue
        stack: list[tuple[object, int]] = [(root, 0)]
        color[root] = GREY
        while stack:
            v, i = stack[-1]
            nbrs = edges.get(v, ())
            if i < len(nbrs):
                stack[-1] = (v, i + 1)
                w = nbrs[i]
                c = color.get(w, WHITE)
                if c == GREY:
                    # unwind the grey chain from v back to w
                    cycle = [v]
                    node = v
                    while node != w:
                        node = parent[node]
                        cycle.append(node)
                    cycle.reverse()
                    return cycle
                if c == WHITE:
                    color[w] = GREY
                    parent[w] = v
                    stack.append((w, 0))
            else:
                color[v] = BLACK
                stack.pop()
    return None


def thread_stacks(idents: tuple[int, ...] | None = None) -> str:
    """Format current stacks of (a subset of) live threads for dumps."""
    import sys
    import traceback

    frames = sys._current_frames()
    names = {t.ident: t.name for t in threading.enumerate()}
    out: list[str] = []
    for ident, frame in frames.items():
        if idents is not None and ident not in idents:
            continue
        out.append(f"--- thread {names.get(ident, '?')} (ident={ident}) ---")
        out.append("".join(traceback.format_stack(frame)).rstrip())
    return "\n".join(out)


def now() -> float:
    return time.monotonic()
