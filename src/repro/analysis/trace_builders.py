"""Dryrun trace builders: the shape of a Plan's execution tree, statically.

`tools/phylint.py` needs the futurized tree of every shipped config
*without* initializing parameters or touching devices.  These builders
construct :class:`~repro.analysis.lint.LintGraph`s that mirror — node for
node, name for name, edge for edge — the trees ``Session.train`` and
``Session.serve`` build at runtime (single-locality driver view).  A
fast-tier parity test (`tests/test_analysis.py`) traces a real session and
asserts the builder output matches, so the mirrors cannot drift silently.

Multi-locality sessions add promise/dispatch node pairs whose placement
depends on live membership; lint those from a real trace
(``LintGraph.from_trace``) or a live graph (``LintGraph.from_graph``)
instead of a static mirror.

``step_contract`` is different in kind: it models the *device-step
donation contract* (TrainStep donates ``(params, opt)`` via
``donate_argnums=(0, 1)``, DDPStep's apply via ``(1, 2)`` — DESIGN.md §11)
as virtual ``device`` nodes with ``uses``/``donates`` annotations, which
is what the PHY005 donation-after-use pass checks.  The host tree never
sees these buffers; the contract graph is where that hazard lives.
"""

from __future__ import annotations

import math

from .lint import LintGraph

#: Host-side prefetch lookahead (data/pipeline.py Prefetcher default).
PREFETCH_DEPTH = 2


def train_trace(
    plan,
    *,
    steps: int = 6,
    ckpt_every: int = 2,
    log_every: int = 2,
    ckpt: bool = True,
    depth: int = PREFETCH_DEPTH,
    start: int = 0,
) -> LintGraph:
    """The driver-side host tree of ``Session.train`` for this plan.

    Mirrors the standard, SPMD-shadow and fabric-DDP variants of the loop
    (DDP logs inline, so it has no ``log:`` nodes).  Raises for
    multi-locality standard training, whose placement-dependent
    promise/dispatch pairs cannot be mirrored statically.
    """
    ddp = bool(getattr(plan, "ddp", False))
    spmd = bool(getattr(plan, "spmd", False))
    if getattr(plan, "localities", 1) > 1 and not (ddp or spmd):
        raise ValueError(
            "train_trace mirrors the single-locality driver tree; lint a "
            "multi-locality run via LintGraph.from_trace / from_graph"
        )
    g = LintGraph(label=f"train[{getattr(plan, 'arch', '?')}]")
    scheduled: set[int] = set()

    def schedule(it: int) -> None:
        # Prefetcher.schedule: batches [it, it+depth) in flight; the final
        # iteration schedules one lookahead batch nobody consumes, which
        # prefetch.close() cancels — cancelled, not dead (PHY004 exempt).
        for s in range(it, it + depth):
            if s not in scheduled:
                scheduled.add(s)
                g.add(
                    f"prefetch:{s}",
                    lane="PREFETCH",
                    forced=s < steps,
                    cancelled=s >= steps,
                    src="data/pipeline.py Prefetcher",
                )

    pending: str | None = None  # previous save's manifest node name

    def save(step: int, retired: str | None) -> None:
        # CheckpointManager.save: gate -> shard -> manifest, chained on the
        # previous save by dependency edge (checkpoint/checkpoint.py).  The
        # chain edge is conservative: the runtime adds it only when the
        # previous save is still in flight (a finished one is consumed by
        # _raise_if_failed), so parity checks must normalize it away.
        nonlocal pending
        deps = [d for d in (retired, pending) if d is not None]
        g.add(f"ckpt:gate:{step}", lane="CHECKPOINT", deps=deps, src="checkpoint save")
        g.add(f"ckpt:shard0:{step}", lane="CHECKPOINT", deps=[f"ckpt:gate:{step}"], src="checkpoint save")
        pending = f"ckpt:manifest:{step}"
        g.add(pending, lane="CHECKPOINT", deps=[f"ckpt:shard0:{step}"], src="checkpoint save")

    for it in range(start, steps):
        schedule(it)
        if not ddp and (it + 1) % log_every == 0:
            g.add(f"log:{it}", lane="CHECKPOINT", forced=True, src="Session.train _force_and_log")
        if ckpt and (it + 1) % ckpt_every == 0:
            g.add(f"retire:{it}", lane="CHECKPOINT", src="Session.train step retirement")
            save(it + 1, f"retire:{it}")
    if ckpt and steps > start and steps % ckpt_every != 0:
        save(steps, None)  # final snapshot; gated only on the previous save
    if pending is not None:
        g.mark_forced(pending)  # ckpt.close() drains the last manifest
    g.has_forced_info = True
    return g


def serve_trace(
    plan,
    *,
    requests: int = 8,
    gen_len: int = 16,
    slots: int = 4,
) -> LintGraph:
    """The driver-side tree of ``Session.serve``: one PREFETCH wave-prep
    node per wave, a ``prefill`` joining the wave batch (plus the previous
    wave's decode tail as a dispatch-order edge), and ``gen_len`` chained
    ``decode`` nodes; only the final tail is forced."""
    if getattr(plan, "localities", 1) > 1:
        raise ValueError(
            "serve_trace mirrors the single-locality driver tree; lint a "
            "multi-locality run via LintGraph.from_trace / from_graph"
        )
    g = LintGraph(label=f"serve[{getattr(plan, 'arch', '?')}]")
    if requests <= 0:
        g.has_forced_info = True
        return g
    waiting = requests
    take = min(slots, waiting)
    waiting -= take
    batch = g.add("wave:0", lane="PREFETCH", src="Session.serve defer_wave")
    tail: int | None = None
    done, n_real, w = 0, take, 0
    while True:
        nxt: tuple[int, int] | None = None
        if waiting > 0 and done + n_real < requests:
            take = min(slots, waiting)
            waiting -= take
            nxt = (g.add(f"wave:{w + 1}", lane="PREFETCH", src="Session.serve defer_wave"), take)
        deps = [batch] if tail is None else [batch, tail]
        carry = g.add(f"prefill:w{w}", deps=deps, src="Session.serve")
        for t in range(gen_len):
            carry = g.add(f"decode:w{w}:t{t}", deps=[carry], src="Session.serve")
        tail = carry
        done += n_real
        if nxt is None:
            break
        batch, n_real = nxt
        w += 1
    g.mark_forced(tail)  # tail.result(): the whole chain retires through it
    return g


class _TraceReplica:
    """Static mirror of ``gateway._Replica``: one replica's slot/chain
    state inside ``gateway_trace`` (node ids instead of futures)."""

    def __init__(self, idx: int, slots: int, namespaced: bool):
        self.idx = idx
        self.ns = f"R{idx}:" if namespaced else ""
        self.admitted: list[int] = []
        self.residents: list[int | None] = [None] * slots
        self.carry: int | None = None
        self.prev_emit: int | None = None
        self.epoch = -1
        self.j = 0
        self.round_work: tuple[bool, list[int]] = (False, [])

    def has_residents(self) -> bool:
        return any(r is not None for r in self.residents)


def gateway_trace(
    plan,
    *,
    requests: int = 6,
    gen_len: int = 4,
    slots: int = 2,
    max_inflight: int | None = None,
    arrivals: list[int] | None = None,
    replicas: int | None = None,
) -> LintGraph:
    """The driver-side tree of ``Session.serve_stream`` (the gateway,
    DESIGN.md §14/§15) for a fault-free arrival script.

    Mirrors ``frontend/gateway.py``'s round loop exactly: per request a
    producer-backed ``request:r{i}`` promise, a PREFETCH ``stack:r{i}``
    and a ``prefill:r{i}``; per slot-membership epoch a ``refill:e{k}``
    joining the previous decode tail with the joiners' prefills; per
    round a ``decode:e{k}:t{j}`` with a chained CHECKPOINT
    ``emit:e{k}:t{j}``; and a forced ``finish:r{i}`` hanging off the emit
    that carried the request's last token.  With ``replicas > 1`` the
    *live* ``ReplicaRouter`` (purely structural: affinity, then least
    loaded, ties low) is replayed to route requests across N namespaced
    decode chains (``refill:R1:e{k}``...) - same class, same decisions,
    so the static tree matches the live one node for node.

    Args:
        arrivals: per-request arrival round (submission order); defaults
            to everyone at round 0.  Deadlines/faults are runtime-only -
            lint those via ``LintGraph.from_trace``.
        replicas: replica count (defaults to ``plan.replicas``).
    """
    if getattr(plan, "localities", 1) > 1:
        raise ValueError(
            "gateway_trace mirrors the single-locality driver tree; lint a "
            "multi-locality run via LintGraph.from_trace / from_graph"
        )
    # lazy: analysis must import without frontend (core.futures imports
    # the sanitizer, and frontend.gateway imports core.futures)
    from ..frontend.gateway import ReplicaRouter

    n_rep = replicas if replicas is not None else getattr(plan, "replicas", 1)
    g = LintGraph(label=f"gateway[{getattr(plan, 'arch', '?')}]"
                        + (f":x{n_rep}" if n_rep > 1 else ""))
    g.has_forced_info = True
    arrivals = list(arrivals) if arrivals is not None else [0] * requests
    if not arrivals:
        return g
    cap = max(1, max_inflight if max_inflight is not None
              else 2 * slots * n_rep)
    router = ReplicaRouter(n_rep)
    reps = [_TraceReplica(i, slots, namespaced=n_rep > 1)
            for i in range(n_rep)]
    queued = list(enumerate(arrivals))      # (rid index, at_round), FIFO
    pending: list[int] = []
    emitted = {i: 0 for i, _ in queued}
    prefill_of: dict[int, int] = {}
    round_ = 0

    def inflight() -> int:
        return sum(len(rep.admitted)
                   + sum(r is not None for r in rep.residents)
                   for rep in reps)

    while True:
        for i, at in [q for q in queued if q[1] <= round_]:
            queued.remove((i, at))
            g.add(f"request:r{i}", lane="CHECKPOINT", kind="promise",
                  producer="gateway", src="Gateway._register")
            pending.append(i)
        while pending and inflight() < cap:
            i = pending.pop(0)
            ridx = router.assign(f"r{i}")
            s = g.add(f"stack:r{i}", lane="PREFETCH", src="Gateway._admit")
            prefill_of[i] = g.add(f"prefill:r{i}", deps=[s],
                                  src="Gateway._admit")
            reps[ridx].admitted.append(i)
        for rep in reps:
            changed = False
            for s, i in enumerate(rep.residents):
                if i is not None and emitted[i] >= gen_len:
                    g.add(f"finish:r{i}", lane="CHECKPOINT",
                          deps=[rep.prev_emit], forced=True,
                          src="Gateway run drain")
                    rep.residents[s] = None
                    router.release(f"r{i}")
                    changed = True
            joiners: list[int] = []
            free = [s for s in range(slots) if rep.residents[s] is None]
            while free and rep.admitted:
                i = rep.admitted.pop(0)
                rep.residents[free.pop(0)] = i
                joiners.append(i)
                changed = True
            rep.round_work = (changed, joiners)
        if not any(rep.has_residents() for rep in reps):
            nxt = min((at for _, at in queued), default=None)
            if nxt is not None:
                round_ = max(round_ + 1, nxt)
                continue
            break
        for rep in reps:
            changed, joiners = rep.round_work
            if not rep.has_residents():
                continue
            if changed or rep.carry is None:
                rep.epoch += 1
                rep.j = 0
                # the live trace records dependency edges index-sorted
                deps = sorted(([] if rep.carry is None else [rep.carry])
                              + [prefill_of[i] for i in joiners])
                rep.carry = g.add(f"refill:{rep.ns}e{rep.epoch}",
                                  deps=deps, src="Gateway._refill_fn")
            rep.carry = g.add(f"decode:{rep.ns}e{rep.epoch}:t{rep.j}",
                              deps=[rep.carry], src="Gateway._decode_fn")
            emit_deps = (([] if rep.prev_emit is None else [rep.prev_emit])
                         + [rep.carry])
            rep.prev_emit = g.add(f"emit:{rep.ns}e{rep.epoch}:t{rep.j}",
                                  lane="CHECKPOINT", deps=emit_deps,
                                  src="Gateway._emit_fn")
            for i in rep.residents:
                if i is not None:
                    emitted[i] += 1
            rep.j += 1
        round_ += 1
    for rep in reps:
        if rep.prev_emit is not None:
            g.mark_forced(rep.prev_emit)   # run() drains every emit tail
    return g


def step_contract(plan, *, steps: int = 4, ckpt_every: int = 2) -> LintGraph:
    """The device-step donation contract as a lintable buffer-version graph.

    Buffers are versioned ``params@k`` / ``opt@k``: step ``k`` reads and
    donates version ``k`` and produces version ``k+1``; the synchronous
    host capture a checkpoint save performs (``np.asarray`` before the
    next dispatch) reads version ``k+1`` *before* step ``k+1`` donates it.
    A capture modelled after the donating step is exactly the PHY005
    hazard the DDPStep contract forbids.
    """
    from ..core import steps as steps_lib

    ddp = bool(getattr(plan, "ddp", False))
    donated = steps_lib.DDPStep.donated_buffers if ddp else steps_lib.TrainStep.donated_buffers
    g = LintGraph(label=f"step-contract[{getattr(plan, 'arch', '?')}]" + (":ddp" if ddp else ""))
    for it in range(steps):
        bufs = tuple(f"{b}@{it}" for b in donated)
        if ddp:
            # grad_fn reads params, the ring exchanges buckets, apply
            # donates (params, opt) — core/steps.py make_ddp_step
            g.add(f"grad:{it}", kind="device", uses=(f"params@{it}", f"batch@{it}"), src="DDPStep.grad_fn")
            g.add(f"ring:{it}", kind="device", uses=(f"buckets@{it}",), src="RingAllReduce")
            g.add(
                f"apply:{it}",
                kind="device",
                uses=bufs + (f"buckets@{it}",),
                donates=bufs,
                src="DDPStep.apply_fn donate_argnums=(1, 2)",
            )
        else:
            g.add(
                f"step:{it}",
                kind="device",
                uses=bufs + (f"batch@{it}",),
                donates=bufs,
                src="TrainStep.fn donate_argnums=(0, 1)",
            )
        if ckpt_every and (it + 1) % ckpt_every == 0:
            # synchronous host capture of the freshly produced versions
            g.add(
                f"capture:{it + 1}",
                kind="device",
                uses=tuple(f"{b}@{it + 1}" for b in donated),
                src="CheckpointManager.save host capture",
            )
    return g


def plan_traces(plan, *, steps: int = 6, requests: int = 8, gen_len: int = 4, slots: int = 4) -> dict[str, LintGraph]:
    """Every statically derivable tree for a plan, keyed by workload."""
    out = {
        "train": train_trace(plan, steps=steps),
        "step-contract": step_contract(plan, steps=steps),
    }
    if not getattr(plan, "ddp", False) and not getattr(plan, "spmd", False):
        out["serve"] = serve_trace(plan, requests=requests, gen_len=gen_len, slots=slots)
        out["gateway"] = gateway_trace(plan, requests=requests, gen_len=gen_len, slots=slots)
        out["gateway-replicas"] = gateway_trace(
            plan, requests=requests, gen_len=gen_len, slots=slots,
            replicas=max(2, getattr(plan, "replicas", 1)))
    return out


def waves_for(requests: int, slots: int) -> int:
    """Number of serve waves for a request count (helper for tests)."""
    return math.ceil(requests / slots) if requests > 0 else 0
