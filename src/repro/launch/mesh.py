"""Mesh construction.  A FUNCTION, not a module-level constant: importing
this module never touches jax device state."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over host devices for tests/examples/benchmarks."""
    shape, axes = [], []
    for n, a in ((pod, "pod"), (data, "data"), (model, "model")):
        if n > 1 or a in ("data", "model"):
            shape.append(n)
            axes.append(a)
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def mesh_devices(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
