"""Mesh construction and multi-process device bring-up.  Everything here
is a FUNCTION, not a module-level constant: importing this module never
touches jax device state.

Version note: ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
``jax.make_mesh``) only exist in newer jax releases.  All axes here are
Auto-typed, which is also the default, so on older jax we simply build the
mesh without the kwarg - same semantics either way.

Multi-process note: when the multi-locality runtime (``repro.distrib``)
spawns worker processes, each worker calls ``maybe_init_jax_distributed``
before any device work.  It is a no-op unless ``PHYRAX_JAX_COORDINATOR``
is set, because the CPU-only CI path runs each locality on its *own*
local jax (host tasks only, no cross-process device collectives) and
must not stand up a coordination service it never uses.
"""
from __future__ import annotations

import os
import socket

import jax

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType as _AxisType
except ImportError:  # jax 0.4.x
    _AxisType = None


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...], *,
              devices=None):
    if _AxisType is not None:
        try:
            return jax.make_mesh(shape, axes, devices=devices,
                                 axis_types=(_AxisType.Auto,) * len(axes))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, devices=devices)
    from jax.experimental import mesh_utils  # very old jax
    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over host devices for tests/examples/benchmarks.

    Under ``jax.distributed`` (SPMD mode) the mesh is built from THIS
    process's ``jax.local_devices()`` only: the CPU backend cannot run
    multi-process computations, so every process computes on an
    identical local mesh in lockstep and only *persistence* spans
    processes (``checkpoint.spmd``, DESIGN.md §10)."""
    shape, axes = [], []
    for n, a in ((pod, "pod"), (data, "data"), (model, "model")):
        if n > 1 or a in ("data", "model"):
            shape.append(n)
            axes.append(a)
    devices = None
    if jax.process_count() > 1:
        devices = jax.local_devices()
    return make_mesh(tuple(shape), tuple(axes), devices=devices)


def mesh_devices(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


def free_port() -> int:
    """An ephemeral loopback port for the ``jax.distributed``
    coordinator of a single-machine SPMD run (the OS-assigned port is
    released before returning; the race window is acceptable for tests
    and drills)."""
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def maybe_init_jax_distributed(*, process_id: int | None = None,
                               num_processes: int | None = None,
                               coordinator: str | None = None) -> bool:
    """Initialize ``jax.distributed`` for a spawned multi-process run.

    Reads ``PHYRAX_JAX_COORDINATOR`` (``host:port`` of process 0) plus
    optional ``PHYRAX_JAX_NUM_PROCESSES`` / ``PHYRAX_JAX_PROCESS_ID``
    overrides; explicit arguments win over the environment.  Returns
    False without touching jax unless a coordinator is configured - the
    CPU / single-process path must stay cold.

    Args:
        process_id: this process's rank (defaults to the env override).
        num_processes: world size (defaults to the env override).
        coordinator: ``host:port`` of process 0 (defaults to the env
            gate; an SPMD ``Session`` passes it explicitly so the
            driver process's environment is never mutated).
    Returns:
        True if ``jax.distributed.initialize`` was called.
    Raises:
        ValueError: a coordinator is configured but the world size is
            not (set ``PHYRAX_JAX_NUM_PROCESSES`` or pass
            ``num_processes``) - half-configured must be loud, not a
            guaranteed-wrong ``initialize(num_processes=0)``.
        RuntimeError: initialization was configured but failed (surfaced
            from jax; a misconfigured coordinator should be loud).
    """
    coordinator = coordinator or os.environ.get("PHYRAX_JAX_COORDINATOR")
    if not coordinator:
        return False
    if num_processes is None:
        num_processes = int(os.environ.get("PHYRAX_JAX_NUM_PROCESSES", "0"))
    if not num_processes:
        raise ValueError(
            "PHYRAX_JAX_COORDINATOR is set but the world size is unknown: "
            "set PHYRAX_JAX_NUM_PROCESSES (and PHYRAX_JAX_PROCESS_ID) or "
            "pass num_processes/process_id explicitly")
    if process_id is None:
        process_id = int(os.environ.get("PHYRAX_JAX_PROCESS_ID", "0"))
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    return True
