"""Mesh construction.  A FUNCTION, not a module-level constant: importing
this module never touches jax device state.

Version note: ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
``jax.make_mesh``) only exist in newer jax releases.  All axes here are
Auto-typed, which is also the default, so on older jax we simply build the
mesh without the kwarg - same semantics either way.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType as _AxisType
except ImportError:  # jax 0.4.x
    _AxisType = None


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    if _AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(_AxisType.Auto,) * len(axes))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils  # very old jax
    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over host devices for tests/examples/benchmarks."""
    shape, axes = [], []
    for n, a in ((pod, "pod"), (data, "data"), (model, "model")):
        if n > 1 or a in ("data", "model"):
            shape.append(n)
            axes.append(a)
    return make_mesh(tuple(shape), tuple(axes))


def mesh_devices(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
