"""Multi-pod dry-run sweep: lower + compile every (arch x shape x mesh)
cell.  The per-cell body - lowering, memory/cost analysis, collective
inventory, roofline terms - is ``frontend.Session.dryrun``; this module is
the sweep CLI plus the JSON artifact cache under artifacts/dryrun/, so
EXPERIMENTS.md §Dry-run and §Roofline are generated from artifacts, not
hand-typed numbers.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--strategy phylanx]
  python -m repro.launch.dryrun --list
"""
import os
# must land before the first jax import in this process
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import time
from pathlib import Path

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import steps as steps_lib
from repro.frontend import Plan, cli_args
# re-exported for benchmarks/analyze_cell.py and friends
from repro.frontend.plan import HBM_BW  # noqa: F401
from repro.frontend.plan import HBM_BYTES  # noqa: F401
from repro.frontend.plan import ICI_BW_PER_LINK  # noqa: F401
from repro.frontend.plan import ICI_LINKS  # noqa: F401
from repro.frontend.plan import PEAK_FLOPS  # noqa: F401
from repro.frontend.plan import lower_cell  # noqa: F401
from repro.frontend.plan import roofline_terms  # noqa: F401
from repro.frontend.plan import cell_is_applicable


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             strategy_name: str, out_dir: Path, *, force: bool = False,
             tag: str = "", seq_parallel: bool = False,
             moe_dispatch: str = "", overrides: dict | None = None) -> dict:
    name = f"{arch}__{shape_name}" + (f"__{tag}" if tag else "")
    out_path = out_dir / mesh_kind / f"{name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    out_path.parent.mkdir(parents=True, exist_ok=True)

    over = dict(overrides or {})
    if moe_dispatch:
        over["moe_dispatch"] = moe_dispatch
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "strategy": strategy_name, "tag": tag,
           "seq_parallel": seq_parallel, "moe_dispatch": moe_dispatch,
           "overrides": overrides or {},
           "timestamp": time.strftime("%Y-%m-%d %H:%M:%S")}
    plan = Plan(arch=arch, tiny=False, mesh=mesh_kind, shape=shape_name,
                strategy=steps_lib.Strategy(name=strategy_name,
                                            sequence_parallel=seq_parallel),
                overrides=over)
    # applicability is checked on the overridden config, before any
    # mesh/device state is touched
    ok, why = cell_is_applicable(plan.config(), shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    with plan.compile() as session:
        rec.update(session.dryrun())
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = cli_args(arch_default=None, tiny=False, mesh=False, seed=False)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multipod", "both"],
                    default="single")
    ap.add_argument("--strategy", default="phylanx",
                    choices=["phylanx", "horovod", "zero1"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--moe-dispatch", default="",
                    choices=["", "einsum", "sort"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    if args.list:
        for a in ARCH_IDS:
            cfg = get_config(a)
            for s in sorted(SHAPES):
                ok, why = cell_is_applicable(cfg, s)
                print(f"{a:26s} {s:12s} {'run' if ok else 'SKIP: ' + why}")
        return

    out_dir = Path(args.out)
    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in sorted(SHAPES):
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for mk in meshes:
        for a, s in cells:
            t0 = time.time()
            rec = run_cell(a, s, mk, args.strategy, out_dir,
                           force=args.force, tag=args.tag,
                           seq_parallel=args.seq_parallel,
                           moe_dispatch=args.moe_dispatch)
            dt = time.time() - t0
            st = rec["status"]
            n_ok += st == "ok"
            n_skip += st == "skipped"
            n_err += st == "error"
            extra = ""
            if st == "ok":
                r = rec["roofline"]
                extra = (f"dom={r['dominant']:10s} "
                         f"tc={r['t_compute_s']:.3e} tm={r['t_memory_s']:.3e} "
                         f"tx={r['t_collective_s']:.3e} "
                         f"fit={rec['fits_hbm']}")
            elif st == "error":
                extra = rec["error"][:160]
            print(f"[{mk:8s}] {a:26s} {s:12s} {st:7s} {dt:7.1f}s {extra}",
                  flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
