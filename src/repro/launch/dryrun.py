import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact under artifacts/dryrun/ with
  * compiled memory analysis     (proves the cell fits per device)
  * cost analysis                (per-device HLO FLOPs / bytes)
  * collective inventory + wire-byte model (core/hlo_analysis.py)
  * the roofline terms of DESIGN.md §6
so EXPERIMENTS.md §Dry-run and §Roofline are generated from artifacts, not
hand-typed numbers.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--strategy phylanx]
  python -m repro.launch.dryrun --list
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import hlo_analysis, hlo_costs, steps as steps_lib
from repro.core.sharding import param_structs
from repro.launch.mesh import make_production_mesh, mesh_devices

# TPU v5e model constants (per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9
ICI_LINKS = 3
HBM_BYTES = 16e9


def cell_is_applicable(cfg, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention (skip noted in DESIGN.md)"
    return True, ""


def lower_cell(cfg, mesh, shape_name: str, strategy: steps_lib.Strategy):
    shape = dict(SHAPES[shape_name])
    kind = shape["kind"]
    step = steps_lib.make_step(cfg, mesh, strategy, shape)

    if kind == "train":
        args = (step.param_structs(), step.opt_structs(),
                steps_lib.input_specs(cfg, shape))
    elif kind == "prefill":
        scfg = steps_lib._serve_cfg(cfg)
        args = (param_structs(step.specs), steps_lib.input_specs(scfg, shape))
    else:  # decode
        scfg = steps_lib._serve_cfg(cfg)
        args = (param_structs(step.specs), param_structs(step.cache_specs),
                steps_lib.input_specs(scfg, shape),
                jax.ShapeDtypeStruct((), jnp.int32))

    t0 = time.time()
    lowered = step.fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return step, lowered, compiled, t_lower, t_compile


def roofline_terms(cfg, shape_name: str, flops_dev: float, bytes_dev: float,
                   wire_bytes_dev: float, n_dev: int) -> dict:
    shape = SHAPES[shape_name]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = wire_bytes_dev / (ICI_BW_PER_LINK * ICI_LINKS)
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    # useful model flops: 6 N D (train) / 2 N D (fwd) per token
    tot, act = cfg.n_params()
    tokens = shape["global_batch"] * (shape["seq_len"]
                                      if shape["kind"] != "decode" else 1)
    mult = 6 if shape["kind"] == "train" else 2
    model_flops = mult * act * tokens / n_dev
    return {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_dev": model_flops,
        "useful_flops_ratio": model_flops / flops_dev if flops_dev else 0.0,
        "bound_step_s": max(t_compute, t_memory, t_coll),
        "roofline_fraction": (t_compute / max(t_compute, t_memory, t_coll)
                              if max(t_compute, t_memory, t_coll) > 0 else 0.0),
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             strategy_name: str, out_dir: Path, *, force: bool = False,
             tag: str = "", seq_parallel: bool = False,
             moe_dispatch: str = "", overrides: dict | None = None) -> dict:
    name = f"{arch}__{shape_name}" + (f"__{tag}" if tag else "")
    out_path = out_dir / mesh_kind / f"{name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    out_path.parent.mkdir(parents=True, exist_ok=True)

    import dataclasses as _dc
    cfg = get_config(arch)
    if moe_dispatch:
        cfg = _dc.replace(cfg, moe_dispatch=moe_dispatch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    ok, why = cell_is_applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "strategy": strategy_name, "tag": tag,
           "seq_parallel": seq_parallel, "moe_dispatch": moe_dispatch,
           "overrides": overrides or {},
           "timestamp": time.strftime("%Y-%m-%d %H:%M:%S")}
    if not ok:
        rec.update(status="skipped", reason=why)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_dev = mesh_devices(mesh)
    strategy = steps_lib.Strategy(name=strategy_name,
                                  sequence_parallel=seq_parallel)
    try:
        step, lowered, compiled, t_lower, t_compile = lower_cell(
            cfg, mesh, shape_name, strategy)
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):     # old jax: list of per-program dicts
            ca = ca[0] if ca else {}
        try:
            ma = compiled.memory_analysis()
            mem = {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "code_bytes": ma.generated_code_size_in_bytes,
            }
            mem["peak_bytes_est"] = (mem["argument_bytes"] + mem["output_bytes"]
                                     - mem["alias_bytes"] + mem["temp_bytes"])
        except Exception as e:  # pragma: no cover
            mem = {"error": str(e)}
        txt = compiled.as_text()
        # loop-aware analysis (cost_analysis counts while bodies once; see
        # core/hlo_costs.py) - this is the roofline source of truth
        costs = hlo_costs.analyze(txt, n_dev)
        flops_dev = costs.flops
        bytes_dev = costs.bytes
        terms = roofline_terms(cfg, shape_name, flops_dev, bytes_dev,
                               costs.total_wire_bytes, n_dev)
        rec.update(
            status="ok", n_devices=n_dev,
            t_lower_s=t_lower, t_compile_s=t_compile,
            flops_per_device=flops_dev, bytes_per_device=bytes_dev,
            memory=mem, collectives=costs.to_json(), roofline=terms,
            xla_cost_analysis={"flops": float(ca.get("flops", 0.0)),
                               "bytes_accessed": float(ca.get("bytes accessed", 0.0))},
            fits_hbm=bool(mem.get("peak_bytes_est", 0) < HBM_BYTES),
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multipod", "both"],
                    default="single")
    ap.add_argument("--strategy", default="phylanx",
                    choices=["phylanx", "horovod", "zero1"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--moe-dispatch", default="",
                    choices=["", "einsum", "sort"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    if args.list:
        for a in ARCH_IDS:
            cfg = get_config(a)
            for s in sorted(SHAPES):
                ok, why = cell_is_applicable(cfg, s)
                print(f"{a:26s} {s:12s} {'run' if ok else 'SKIP: ' + why}")
        return

    out_dir = Path(args.out)
    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in sorted(SHAPES):
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for mk in meshes:
        for a, s in cells:
            t0 = time.time()
            rec = run_cell(a, s, mk, args.strategy, out_dir,
                           force=args.force, tag=args.tag,
                           seq_parallel=args.seq_parallel,
                           moe_dispatch=args.moe_dispatch)
            dt = time.time() - t0
            st = rec["status"]
            n_ok += st == "ok"
            n_skip += st == "skipped"
            n_err += st == "error"
            extra = ""
            if st == "ok":
                r = rec["roofline"]
                extra = (f"dom={r['dominant']:10s} "
                         f"tc={r['t_compute_s']:.3e} tm={r['t_memory_s']:.3e} "
                         f"tx={r['t_collective_s']:.3e} "
                         f"fit={rec['fits_hbm']}")
            elif st == "error":
                extra = rec["error"][:160]
            print(f"[{mk:8s}] {a:26s} {s:12s} {st:7s} {dt:7.1f}s {extra}",
                  flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
