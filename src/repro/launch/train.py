"""Training launcher: the end-to-end driver (deliverable b).

Wires every subsystem together: config registry -> mesh -> strategy ->
shard_map train step -> synthetic pipeline w/ prefetch -> async checkpoints
-> resilience (replay / replicate / finite-validation) -> restart.

Fault tolerance drill (used by examples/elastic_restart.py and tests):
  * --fail-at-step N     raises mid-run AFTER checkpoints exist (simulated
                         node loss);
  * rerunning with --resume picks up the latest checkpoint - including onto
    a different --data/--model mesh (elastic restart via checkpoint
    resharding);
  * --resilience replay  wraps the step in HPX-style replay (retry on
    non-finite results); replicate votes across replicas by checksum.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --tiny \
      --steps 30 --batch 8 --seq 64 --strategy phylanx --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core import steps as steps_lib
from repro.core.futures import FuturizedGraph, Lane, Pipeline
from repro.core.resilience import ResilientRunner, StragglerPolicy
from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import LMStream, Prefetcher
from repro.launch.mesh import make_local_mesh


def build(args):
    cfg = get_config(args.arch, tiny=args.tiny)
    if args.tiny:
        cfg = dataclasses.replace(cfg, remat=args.remat)
    mesh = make_local_mesh(data=args.data, model=args.model)
    shape = {"seq_len": args.seq, "global_batch": args.batch, "kind": "train"}
    strategy = steps_lib.Strategy(
        name=args.strategy, grad_accum=args.grad_accum,
        sequence_parallel=args.seq_parallel)
    step = steps_lib.make_train_step(cfg, mesh, strategy, shape)
    stream = LMStream(
        vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=args.seed,
        frames_dim=cfg.d_model if cfg.family == "encdec" else 0,
        frames_len=cfg.enc_frames if cfg.family == "encdec" else 0)
    return cfg, mesh, step, stream


def run(args) -> dict:
    cfg, mesh, step, stream = build(args)
    params, opt = step.init(jax.random.PRNGKey(args.seed))
    start = 0

    # One futurized runtime for every host-side task in the loop: prefetch
    # nodes (Lane.PREFETCH), metric forcing (Lane.COMPUTE) and checkpoint
    # I/O (Lane.CHECKPOINT) share its workers; the lane order keeps saves
    # off the step-critical path.
    runtime = FuturizedGraph(max_workers=4, name="train")
    ckpt = (CheckpointManager(args.ckpt, keep=3, graph=runtime)
            if args.ckpt else None)
    if ckpt is not None and args.resume:
        latest = ckpt.latest_step()
        if latest is not None:
            start, (params, opt) = ckpt.restore(
                (params, opt),
                shardings=(step.param_shardings, step.opt_shardings))
            print(f"[train] resumed from step {start}")

    prefetch = Prefetcher(stream, step.batch_shardings, graph=runtime)
    runner = ResilientRunner(step.fn_nodonate)
    policy = StragglerPolicy(accumulate_local_steps=1)
    inflight = Pipeline(depth=2)
    log_futs: list = []
    t_log = time.time()

    def _force_and_log(it, m, t_start):
        # Runs on a runtime worker: forcing metrics never stalls dispatch.
        loss = float(m["loss"])
        dt = (time.time() - t_start) / args.log_every
        print(f"[train] step {it + 1:5d} loss {loss:8.4f} "
              f"gnorm {float(m['grad_norm']):8.3f} "
              f"{dt * 1e3:8.1f} ms/step", flush=True)
        return loss

    metrics = None
    try:
        for it in range(start, args.steps):
            batch = prefetch.get(it)
            if args.fail_at_step is not None and it == args.fail_at_step \
                    and not args.resume:
                raise RuntimeError(f"injected node failure at step {it}")
            if args.resilience == "replay":
                metrics, params, opt = runner.replay(params, opt, batch)
            elif args.resilience == "replicate":
                metrics, params, opt = runner.replicate(params, opt, batch,
                                                        n=2)
            else:
                metrics, params, opt = step.fn(params, opt, batch)
            inflight.push(it, metrics)
            if (it + 1) % args.log_every == 0:
                # CHECKPOINT lane: forcing metrics for logs must never
                # outrank the PREFETCH nodes the loop blocks on next
                log_futs.append(runtime.defer(
                    _force_and_log, it, metrics, t_log,
                    lane=Lane.CHECKPOINT, name=f"log:{it}"))
                t_log = time.time()
            if ckpt is not None and (it + 1) % args.ckpt_every == 0:
                # The write node depends on step retirement: file I/O starts
                # only after the step's outputs are resolved on device.
                retired = runtime.defer(jax.block_until_ready, metrics,
                                        lane=Lane.CHECKPOINT,
                                        name=f"retire:{it}")
                ckpt.save(it + 1, (params, opt), deps=(retired,),
                          meta={"arch": args.arch})
        inflight.drain()
        if ckpt is not None:
            ckpt.save(args.steps, (params, opt), meta={"arch": args.arch})
    finally:
        # Shutdown barrier - also on the injected-failure path, so a crash
        # never loses a save that was already requested: retire in-flight
        # steps, land every pending checkpoint node, stop the workers.
        inflight.drain()
        prefetch.close()       # cancel batches nobody will consume
        if ckpt is not None:
            ckpt.close()
        runtime.shutdown(wait=True)

    losses = [f.result() for f in log_futs]
    st = runtime.stats()
    if metrics is None:      # resumed at/after --steps: nothing left to run
        print(f"[train] nothing to do: resumed at step {start} "
              f">= --steps {args.steps}")
        return {"final_loss": float("nan"), "losses": losses,
                "params": params, "step": start,
                "runtime_stats": st.to_json()}
    final = float(metrics["loss"])
    print(f"[train] done: final loss {final:.4f} "
          f"(host tasks {st.completed}, max in-flight {st.max_in_flight})")
    return {"final_loss": final, "losses": losses,
            "params": params, "step": args.steps,
            "runtime_stats": st.to_json()}


def parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--strategy", default="phylanx",
                    choices=["phylanx", "horovod", "zero1", "onebit"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--resilience", default="none",
                    choices=["none", "replay", "replicate"])
    return ap


if __name__ == "__main__":
    run(parser().parse_args())
