"""Training launcher: a thin argparse shim over ``frontend.Plan/Session``.

The loop itself - config -> mesh -> strategy -> shard_map train step ->
synthetic pipeline w/ prefetch -> async checkpoints -> resilience ->
restart - lives in ``frontend/plan.py`` (``Session.train``); this module
only maps flags onto a ``Plan``.

Fault tolerance drill (used by examples/elastic_restart.py and tests):
  * --fail-at-step N     raises mid-run AFTER checkpoints exist (simulated
                         node loss);
  * rerunning with --resume picks up the latest checkpoint - including onto
    a different --data/--model mesh AND a different --localities count
    (elastic restart via checkpoint resharding: with --localities N each
    locality writes/reads its own shards, DESIGN.md §10);
  * --resilience replay  wraps the step in HPX-style replay (retry on
    non-finite results); replicate votes across replicas by checksum;
  * --spmd (with --localities N) runs the multi-host SPMD drill: all N
    processes join one jax.distributed world, train in lockstep, and
    each writes only the addressable shards of the global persistence
    view at every checkpoint (DESIGN.md §10) - a later --resume run with
    any process count reads them back.

Data parallelism over our own fabric (DESIGN.md §11):
  * --ddp (with --localities N) splits the batch into --ddp-shards row
    shards (default: one per locality); every process trains its own
    block and gradients are summed by a ring all-reduce of active
    messages - with --grad-codec onebit the wire carries 1-bit signs +
    error feedback (~1/31 of fp32 bytes), and the exact payload count
    is printed as the report's `grad-wire` line.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --tiny \
      --steps 30 --batch 8 --seq 64 --strategy phylanx --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse

from repro.core.steps import Strategy
from repro.frontend import cli_args, plan_from_args


def run(args) -> dict:
    strategy = Strategy(name=args.strategy, grad_accum=args.grad_accum,
                        sequence_parallel=args.seq_parallel)
    plan = plan_from_args(args, strategy=strategy, remat=args.remat)
    with plan.compile() as session:
        return session.train(
            steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
            log_every=args.log_every, resume=args.resume,
            fail_at_step=args.fail_at_step,
            kill_locality_at_step=args.kill_locality_at_step,
            resilience=args.resilience)


def parser() -> argparse.ArgumentParser:
    ap = cli_args(seq=64, batch=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--strategy", default="phylanx",
                    choices=["phylanx", "horovod", "zero1", "onebit"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--kill-locality-at-step", type=int, default=None,
                    help="drill: SIGKILL a worker locality at this step "
                         "(needs --localities > 1); training must survive")
    ap.add_argument("--resilience", default="none",
                    choices=["none", "replay", "replicate"])
    return ap


if __name__ == "__main__":
    run(parser().parse_args())
