"""Training launcher: the end-to-end driver (deliverable b).

Wires every subsystem together: config registry -> mesh -> strategy ->
shard_map train step -> synthetic pipeline w/ prefetch -> async checkpoints
-> resilience (replay / replicate / finite-validation) -> restart.

Fault tolerance drill (used by examples/elastic_restart.py and tests):
  * --fail-at-step N     raises mid-run AFTER checkpoints exist (simulated
                         node loss);
  * rerunning with --resume picks up the latest checkpoint - including onto
    a different --data/--model mesh (elastic restart via checkpoint
    resharding);
  * --resilience replay  wraps the step in HPX-style replay (retry on
    non-finite results); replicate votes across replicas by checksum.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --tiny \
      --steps 30 --batch 8 --seq 64 --strategy phylanx --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import steps as steps_lib
from repro.core.futures import Pipeline
from repro.core.resilience import ResilientRunner, StragglerPolicy, finite_check
from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import LMStream, Prefetcher
from repro.launch.mesh import make_local_mesh


def build(args):
    cfg = get_config(args.arch, tiny=args.tiny)
    if args.tiny:
        cfg = dataclasses.replace(cfg, remat=args.remat)
    mesh = make_local_mesh(data=args.data, model=args.model)
    shape = {"seq_len": args.seq, "global_batch": args.batch, "kind": "train"}
    strategy = steps_lib.Strategy(
        name=args.strategy, grad_accum=args.grad_accum,
        sequence_parallel=args.seq_parallel)
    step = steps_lib.make_train_step(cfg, mesh, strategy, shape)
    stream = LMStream(
        vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=args.seed,
        frames_dim=cfg.d_model if cfg.family == "encdec" else 0,
        frames_len=cfg.enc_frames if cfg.family == "encdec" else 0)
    return cfg, mesh, step, stream


def run(args) -> dict:
    cfg, mesh, step, stream = build(args)
    params, opt = step.init(jax.random.PRNGKey(args.seed))
    start = 0

    ckpt = CheckpointManager(args.ckpt, keep=3) if args.ckpt else None
    if ckpt is not None and args.resume:
        latest = ckpt.latest_step()
        if latest is not None:
            start, (params, opt) = ckpt.restore(
                (params, opt),
                shardings=(step.param_shardings, step.opt_shardings))
            print(f"[train] resumed from step {start}")

    prefetch = Prefetcher(stream, step.batch_shardings)
    runner = ResilientRunner(step.fn_nodonate)
    policy = StragglerPolicy(accumulate_local_steps=1)
    inflight = Pipeline(depth=2)
    losses = []
    t0 = time.time()
    for it in range(start, args.steps):
        batch = prefetch.get(it)
        if args.fail_at_step is not None and it == args.fail_at_step \
                and not args.resume:
            raise RuntimeError(f"injected node failure at step {it}")
        if args.resilience == "replay":
            metrics, params, opt = runner.replay(params, opt, batch)
        elif args.resilience == "replicate":
            metrics, params, opt = runner.replicate(params, opt, batch, n=2)
        else:
            metrics, params, opt = step.fn(params, opt, batch)
        inflight.push(it, metrics)
        if (it + 1) % args.log_every == 0:
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = (time.time() - t0) / args.log_every
            print(f"[train] step {it + 1:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"{dt * 1e3:8.1f} ms/step", flush=True)
            t0 = time.time()
        if ckpt is not None and (it + 1) % args.ckpt_every == 0:
            ckpt.save(it + 1, (params, opt),
                      meta={"arch": args.arch, "loss": float(metrics["loss"])})
    inflight.drain()
    if ckpt is not None:
        ckpt.save(args.steps, (params, opt), meta={"arch": args.arch})
        ckpt.wait()
    final = float(metrics["loss"])
    print(f"[train] done: final loss {final:.4f}")
    return {"final_loss": final, "losses": losses,
            "params": params, "step": args.steps}


def parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--strategy", default="phylanx",
                    choices=["phylanx", "horovod", "zero1", "onebit"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--resilience", default="none",
                    choices=["none", "replay", "replicate"])
    return ap


if __name__ == "__main__":
    run(parser().parse_args())
