"""Training launcher: a thin argparse shim over ``frontend.Plan/Session``.

The loop itself - config -> mesh -> strategy -> shard_map train step ->
synthetic pipeline w/ prefetch -> async checkpoints -> resilience ->
restart - lives in ``frontend/plan.py`` (``Session.train``); this module
only maps flags onto a ``Plan``.

Fault tolerance drill (used by examples/elastic_restart.py and tests):
  * --fail-at-step N     raises mid-run AFTER checkpoints exist (simulated
                         node loss);
  * rerunning with --resume picks up the latest checkpoint - including onto
    a different --data/--model mesh AND a different --localities count
    (elastic restart via checkpoint resharding: with --localities N each
    locality writes/reads its own shards, DESIGN.md §10);
  * --resilience replay  wraps the step in HPX-style replay (retry on
    non-finite results); replicate votes across replicas by checksum;
  * --spmd (with --localities N) runs the multi-host SPMD drill: all N
    processes join one jax.distributed world, train in lockstep, and
    each writes only the addressable shards of the global persistence
    view at every checkpoint (DESIGN.md §10) - a later --resume run with
    any process count reads them back.

Data parallelism over our own fabric (DESIGN.md §11):
  * --ddp (with --localities N) splits the batch into --ddp-shards row
    shards (default: one per locality); every process trains its own
    block and gradients are summed by a ring all-reduce of active
    messages - with --grad-codec onebit the wire carries 1-bit signs +
    error feedback (~1/31 of fp32 bytes), and the exact payload count
    is printed as the report's `grad-wire` line.

Elastic scale-out (DESIGN.md §13):
  * --elastic (optionally --elastic-port P) starts an elastic driver:
    it prints its join address and accepts new localities mid-run;
  * --join HOST:PORT turns THIS invocation into a dial-in locality of
    that driver instead of a training run: it registers, steals host
    tasks the moment it is idle, and exits when the driver's run ends.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --tiny \
      --steps 30 --batch 8 --seq 64 --strategy phylanx --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse
import json

from repro.core.steps import Strategy
from repro.frontend import cli_args, plan_from_args


class _StallHook:
    """Driver-side 250 ms stall at one step: the joined locality drains
    its queue, goes hungry, and the next steerable prefetch build is
    diverted to it - the deterministic steal window the churn tests and
    ``benchmarks/elastic_scaleout.py`` use (DESIGN.md §13)."""

    def __init__(self, at: int):
        self.at = at

    def on_step(self, it, metrics):
        if it == self.at:
            import time
            time.sleep(0.25)


def run(args) -> dict:
    if getattr(args, "join", None):
        # this process is a dial-in locality, not a training driver
        from repro.distrib import join_locality
        host, _, port = args.join.rpartition(":")
        rank = join_locality((host or "127.0.0.1", int(port)))
        print(f"[train] served as elastic locality {rank}; driver run "
              f"ended", flush=True)
        return {"joined_as": rank}
    strategy = Strategy(name=args.strategy, grad_accum=args.grad_accum,
                        sequence_parallel=args.seq_parallel)
    plan = plan_from_args(args, strategy=strategy, remat=args.remat)
    with plan.compile() as session:
        if session.join_address is not None:
            host, port = session.join_address
            print(f"[train] elastic: accepting --join {host}:{port}",
                  flush=True)
        if getattr(args, "expect_joins", 0):
            # drill determinism: a --join dialer pays its own Python/JAX
            # startup, so hold the train loop until it is a member -
            # otherwise a fast driver finishes before the dial lands
            import time as _time
            deadline = _time.monotonic() + 180.0
            while (session.distributed.stats()["joined_localities"]
                   < args.expect_joins):
                if _time.monotonic() > deadline:
                    raise TimeoutError(
                        f"expected {args.expect_joins} --join dial-in(s) "
                        f"within 180s")
                _time.sleep(0.1)
            print(f"[train] elastic: {args.expect_joins} dial-in(s) "
                  f"joined; training", flush=True)
        hooks = None
        if getattr(args, "stall_at_step", None) is not None:
            hooks = _StallHook(args.stall_at_step)
        out = session.train(
            steps=args.steps, hooks=hooks,
            ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
            log_every=args.log_every, resume=args.resume,
            fail_at_step=args.fail_at_step,
            kill_locality_at_step=args.kill_locality_at_step,
            resilience=args.resilience)
    if getattr(args, "stats_out", None):
        # machine-readable summary for drills/CI: loss trajectory plus
        # the distributed counters (stolen_tasks, migrated_objects...)
        with open(args.stats_out, "w") as f:
            json.dump({"final_loss": out["final_loss"],
                       "losses": out["losses"], "step": out["step"],
                       "distributed": out["runtime_stats"].get(
                           "distributed")}, f, indent=2)
    return out


def parser() -> argparse.ArgumentParser:
    ap = cli_args(seq=64, batch=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--strategy", default="phylanx",
                    choices=["phylanx", "horovod", "zero1", "onebit"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--kill-locality-at-step", type=int, default=None,
                    help="drill: SIGKILL a worker locality at this step "
                         "(needs --localities > 1); training must survive")
    ap.add_argument("--resilience", default="none",
                    choices=["none", "replay", "replicate"])
    ap.add_argument("--join", default=None, metavar="HOST:PORT",
                    help="join a running --elastic driver as an extra "
                         "locality instead of training (all other flags "
                         "are ignored; the driver ships its config)")
    ap.add_argument("--stats-out", dest="stats_out", default=None,
                    metavar="FILE",
                    help="write a JSON summary (losses + distributed "
                         "counters) here after training")
    ap.add_argument("--expect-joins", dest="expect_joins", type=int,
                    default=0, metavar="N",
                    help="drill (needs --elastic): wait for N --join "
                         "dial-ins before the first step so the joiner "
                         "is a member for the whole run")
    ap.add_argument("--stall-at-step", dest="stall_at_step", type=int,
                    default=None, metavar="K",
                    help="drill: sleep 250 ms on the driver at step K - "
                         "the deterministic work-steal window")
    return ap


if __name__ == "__main__":
    run(parser().parse_args())
