"""Serving launcher: batched prefill + decode with a slot-based scheduler.

Continuous-batching-lite: a fixed pool of decode slots; finished sequences
(hit --gen-len) are retired and refilled from the waiting queue with a fresh
prefill.  All requests in a refill wave share a prompt length (pad-align),
so the decode step stays a single compiled program - the paper's SPMD
execution model applied to inference.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tiny \
      --requests 16 --slots 4 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import steps as steps_lib
from repro.core.futures import FuturizedGraph, Lane
from repro.launch.mesh import make_local_mesh


def run(args) -> dict:
    cfg = get_config(args.arch, tiny=args.tiny)
    mesh = make_local_mesh(data=args.data, model=args.model)
    cache_len = args.prompt_len + args.gen_len
    shape = {"seq_len": cache_len, "global_batch": args.slots,
             "kind": "decode"}
    strategy = steps_lib.Strategy()
    pre = steps_lib.make_prefill_step(
        cfg, mesh, strategy,
        {"seq_len": cache_len, "global_batch": args.slots, "kind": "prefill"})
    dec = steps_lib.make_decode_step(cfg, mesh, strategy, shape)

    from repro.core.sharding import init_params
    params = init_params(pre.specs, jax.random.PRNGKey(args.seed))
    params = jax.device_put(params, pre.param_shardings)

    rng = np.random.default_rng(args.seed)
    waiting = [rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]

    # Futurized wave prep: while the current wave's prefill + decode steps
    # are in flight on device (async dispatch), a PREFETCH-lane node stacks
    # and device_puts the *next* wave's prompts, so refill never waits on
    # host work and prefill of wave k+1 can dispatch right as wave k drains.
    runtime = FuturizedGraph(max_workers=2, name="serve")

    def prepare_wave(wave: list[np.ndarray]) -> dict:
        prompts = jax.device_put(jnp.asarray(np.stack(wave)),
                                 pre.batch_shardings["tokens"])
        batch = {"tokens": prompts}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (args.slots, cfg.enc_frames, cfg.d_model), cfg.c_dtype)
        return batch

    def take_wave() -> tuple[list[np.ndarray], int]:
        wave = [waiting.pop() for _ in range(min(args.slots, len(waiting)))]
        n_real = len(wave)
        while len(wave) < args.slots:           # pad idle slots
            wave.append(np.zeros(args.prompt_len, np.int32))
        return wave, n_real

    done, t0 = 0, time.time()
    tokens_out = 0
    last_tok = None
    try:
        wave, n_real = take_wave()
        batch_fut = runtime.defer(prepare_wave, wave, lane=Lane.PREFETCH,
                                  name="wave:0")
        while done < args.requests:
            batch = batch_fut.result()
            next_wave = None
            if len(waiting) and done + n_real < args.requests:
                next_wave, next_real = take_wave()
                batch_fut = runtime.defer(prepare_wave, next_wave,
                                          lane=Lane.PREFETCH,
                                          name=f"wave:{done + n_real}")
            logits, cache = pre.fn(params, batch)
            # prefill wrote [0, prompt_len); decode continues from there.
            # Nothing below forces a transfer: prefill and every decode step
            # stay in flight back-to-back under JAX async dispatch.
            tok_sh = dec.batch_shardings["tokens"]
            tok = jax.device_put(
                jnp.argmax(logits, -1)[:, None].astype(jnp.int32), tok_sh)
            for t in range(args.gen_len):
                pos = jnp.int32(args.prompt_len + t)
                logits, cache = dec.fn(params, cache, {"tokens": tok}, pos)
                tok = jax.device_put(
                    jnp.argmax(logits, -1)[:, None].astype(jnp.int32), tok_sh)
                tokens_out += args.slots
            last_tok = tok
            done += n_real
            if next_wave is not None:
                n_real = next_real
        if last_tok is not None:      # honest timing: retire the last wave
            jax.block_until_ready(last_tok)
    finally:
        runtime.shutdown(wait=True)
    dt = time.time() - t0
    tps = tokens_out / dt
    st = runtime.stats()
    print(f"[serve] {args.requests} requests, {tokens_out} tokens in "
          f"{dt:.2f}s -> {tps:.1f} tok/s (slots={args.slots}, "
          f"host tasks {st.completed})")
    return {"tokens_per_s": tps, "requests": args.requests,
            "runtime_stats": st.to_json()}


def parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    return ap


if __name__ == "__main__":
    run(parser().parse_args())
