"""Serving launcher: a thin argparse shim over ``frontend.Plan/Session``.

Continuous-batching-lite lives in ``Session.serve`` (frontend/plan.py): a
fixed pool of decode slots; finished sequences (hit --gen-len) are retired
and refilled from the waiting queue with a fresh prefill.  Each wave runs
as a futurized tree - a prefill node plus chained, named decode nodes -
while the next wave's host prep runs as a PREFETCH node.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tiny \
      --requests 16 --slots 4 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse

from repro.frontend import cli_args, plan_from_args


def run(args) -> dict:
    plan = plan_from_args(args)
    with plan.compile() as session:
        return session.serve(
            requests=args.requests, prompt_len=args.prompt_len,
            gen_len=args.gen_len, slots=args.slots)


def parser() -> argparse.ArgumentParser:
    ap = cli_args()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    return ap


if __name__ == "__main__":
    run(parser().parse_args())
