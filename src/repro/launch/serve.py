"""Serving launcher: a thin argparse shim over ``frontend.Plan/Session``.

Two serving loops share this entry point:

* the default wave loop (``Session.serve``): a fixed pool of decode slots;
  finished sequences (hit --gen-len) are retired and refilled from the
  waiting queue with a fresh prefill, each wave a futurized tree;
* ``--serve-stream`` (``Session.serve_stream``, DESIGN.md §14): the
  continuous-batching gateway - requests arrive mid-flight through a
  ``RequestQueue``, admission control caps in-flight work
  (``--max-inflight``) and expires laggards (``--deadline-ms``), and
  prefill state parks in the paged inference cache so retire-and-refill
  loads pages instead of recomputing.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tiny \
      --requests 16 --slots 4 --prompt-len 32 --gen-len 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tiny \
      --serve-stream --requests 16 --slots 4 --max-inflight 8 \
      --deadline-ms 5000
"""
from __future__ import annotations

import argparse

from repro.frontend import cli_args, plan_from_args, serve_flags


def run(args) -> dict:
    plan = plan_from_args(args)
    with plan.compile() as session:
        if getattr(args, "serve_stream", False):
            return session.serve_stream(
                requests=args.requests, prompt_len=args.prompt_len,
                gen_len=args.gen_len, slots=args.slots,
                max_inflight=args.max_inflight,
                deadline_ms=args.deadline_ms)
        return session.serve(
            requests=args.requests, prompt_len=args.prompt_len,
            gen_len=args.gen_len, slots=args.slots)


def parser() -> argparse.ArgumentParser:
    ap = cli_args()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    serve_flags(ap)
    return ap


if __name__ == "__main__":
    run(parser().parse_args())
