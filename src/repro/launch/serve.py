"""Serving launcher: a thin argparse shim over ``frontend.Plan/Session``.

Two serving loops share this entry point:

* the default wave loop (``Session.serve``): a fixed pool of decode slots;
  finished sequences (hit --gen-len) are retired and refilled from the
  waiting queue with a fresh prefill, each wave a futurized tree;
* ``--serve-stream`` (``Session.serve_stream``, DESIGN.md §14): the
  continuous-batching gateway - requests arrive mid-flight through a
  ``RequestQueue``, admission control caps in-flight work
  (``--max-inflight``) and expires laggards (``--deadline-ms``), and
  prefill state parks in the paged inference cache so retire-and-refill
  loads pages instead of recomputing.  ``--replicas N`` fans the gateway
  out over N locality-homed model replicas (DESIGN.md §15), and
  ``--kill-replica-at IDX:ROUND`` runs the replica-death drill.

``--stats-out FILE`` writes the run summary (gateway counters including
the per-replica split, latency histograms, routing table) as JSON - the
CI serve drills assert on it.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tiny \
      --requests 16 --slots 4 --prompt-len 32 --gen-len 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tiny \
      --serve-stream --requests 16 --slots 4 --max-inflight 8 \
      --deadline-ms 5000 --stats-out serve_stats.json
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tiny \
      --serve-stream --localities 2 --replicas 2 --requests 8 --slots 2 \
      --kill-replica-at 0:2
"""
from __future__ import annotations

import argparse
import json


from repro.frontend import cli_args, plan_from_args, serve_flags

# result keys that serialize cleanly (handles hold threads and futures)
_JSON_KEYS = ("requests", "completed", "cancelled", "expired", "failed",
              "rejected", "tokens", "padded_tokens", "tokens_per_s",
              "rounds", "epochs", "replicas", "replica_assignments",
              "streams", "cache", "runtime_stats")


def _parse_kill_at(spec):
    """``IDX:ROUND`` -> ``(idx, round)`` for the replica-death drill."""
    if spec is None:
        return None
    try:
        idx, round_ = spec.split(":")
        return (int(idx), int(round_))
    except ValueError:
        raise SystemExit(f"--kill-replica-at wants IDX:ROUND, got {spec!r}")


def run(args) -> dict:
    plan = plan_from_args(args)
    with plan.compile() as session:
        if getattr(args, "serve_stream", False):
            out = session.serve_stream(
                requests=args.requests, prompt_len=args.prompt_len,
                gen_len=args.gen_len, slots=args.slots,
                max_inflight=args.max_inflight,
                deadline_ms=args.deadline_ms,
                replicas=getattr(args, "replicas", None),
                kill_replica_at_round=_parse_kill_at(
                    getattr(args, "kill_replica_at", None)))
        else:
            out = session.serve(
                requests=args.requests, prompt_len=args.prompt_len,
                gen_len=args.gen_len, slots=args.slots)
    if getattr(args, "stats_out", None):
        payload = {k: out[k] for k in _JSON_KEYS if k in out}
        with open(args.stats_out, "w") as f:
            json.dump(payload, f, indent=2, default=str)
        print(f"[serve] stats -> {args.stats_out}")
    return out


def parser() -> argparse.ArgumentParser:
    ap = cli_args()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    serve_flags(ap)
    return ap


if __name__ == "__main__":
    run(parser().parse_args())
