"""Serving launcher: batched prefill + decode with a slot-based scheduler.

Continuous-batching-lite: a fixed pool of decode slots; finished sequences
(hit --gen-len) are retired and refilled from the waiting queue with a fresh
prefill.  All requests in a refill wave share a prompt length (pad-align),
so the decode step stays a single compiled program - the paper's SPMD
execution model applied to inference.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tiny \
      --requests 16 --slots 4 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import steps as steps_lib
from repro.launch.mesh import make_local_mesh


def run(args) -> dict:
    cfg = get_config(args.arch, tiny=args.tiny)
    mesh = make_local_mesh(data=args.data, model=args.model)
    cache_len = args.prompt_len + args.gen_len
    shape = {"seq_len": cache_len, "global_batch": args.slots,
             "kind": "decode"}
    strategy = steps_lib.Strategy()
    pre = steps_lib.make_prefill_step(
        cfg, mesh, strategy,
        {"seq_len": cache_len, "global_batch": args.slots, "kind": "prefill"})
    dec = steps_lib.make_decode_step(cfg, mesh, strategy, shape)

    from repro.core.sharding import init_params
    params = init_params(pre.specs, jax.random.PRNGKey(args.seed))
    params = jax.device_put(params, pre.param_shardings)

    rng = np.random.default_rng(args.seed)
    waiting = [rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]
    done, t0 = 0, time.time()
    tokens_out = 0

    while done < args.requests:
        wave = [waiting.pop() for _ in range(min(args.slots, len(waiting)))]
        while len(wave) < args.slots:           # pad idle slots
            wave.append(np.zeros(args.prompt_len, np.int32))
        prompts = jax.device_put(jnp.asarray(np.stack(wave)),
                                 pre.batch_shardings["tokens"])
        batch = {"tokens": prompts}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (args.slots, cfg.enc_frames, cfg.d_model), cfg.c_dtype)
        logits, cache = pre.fn(params, batch)
        # prefill wrote positions [0, prompt_len); decode continues from there
        tok_sh = dec.batch_shardings["tokens"]
        tok = jax.device_put(
            jnp.argmax(logits, -1)[:, None].astype(jnp.int32), tok_sh)
        for t in range(args.gen_len):
            pos = jnp.int32(args.prompt_len + t)
            logits, cache = dec.fn(params, cache, {"tokens": tok}, pos)
            tok = jax.device_put(
                jnp.argmax(logits, -1)[:, None].astype(jnp.int32), tok_sh)
            tokens_out += args.slots
        done += len([w for w in wave if w.any() or True])
    dt = time.time() - t0
    tps = tokens_out / dt
    print(f"[serve] {args.requests} requests, {tokens_out} tokens in "
          f"{dt:.2f}s -> {tps:.1f} tok/s (slots={args.slots})")
    return {"tokens_per_s": tps, "requests": args.requests}


def parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    return ap


if __name__ == "__main__":
    run(parser().parse_args())
