"""Jitted dispatch wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this container is
CPU-only) they run under ``interpret=True`` - same kernel body, Python
evaluation - or fall back to the jnp oracle.  Model code calls these
wrappers; tests sweep shapes/dtypes against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax

from . import flash_attention as _fa
from . import mamba2_scan as _m2
from . import onebit as _ob
from . import ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "impl"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_kv: int = 128,
                    impl: str = "auto"):
    """q: [B, H, S, d]; k, v: [B, Hkv, S, d] -> [B, H, S, d]."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def mamba2_chunk_scan(xdt, a, Bm, Cm, *, chunk: int = 128,
                      impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.mamba2_scan_ref(xdt, a, Bm, Cm)
    return _m2.mamba2_chunk_scan(xdt, a, Bm, Cm, chunk=chunk,
                                 interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def onebit_quantize(g, err, *, block_rows: int = 256, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        signs, scale, new_err = ref.onebit_quantize_ref(g, err)
        return signs, scale, new_err
    return _ob.onebit_quantize(g, err, block_rows=block_rows,
                               interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def onebit_dequantize(packed_or_signs, scale, *, block_rows: int = 256,
                      impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.onebit_dequantize_ref(packed_or_signs, scale)
    return _ob.onebit_dequantize(packed_or_signs, scale,
                                 block_rows=block_rows,
                                 interpret=(impl == "interpret"))
