"""Pallas TPU kernels for the performance-critical compute layers.

  flash_attention  - causal GQA flash attention fwd (BlockSpec VMEM tiling)
  mamba2_scan      - SSD chunked scan with on-chip carried state
  onebit           - 1-bit gradient pack/unpack (error feedback)

ops.py is the jit'd dispatch layer (TPU: compiled kernel; CPU: interpret or
jnp oracle); ref.py holds the pure-jnp oracles the tests compare against.
"""
from . import ops, ref  # noqa: F401
