"""Causal GQA FlashAttention forward - Pallas TPU kernel.

Grid: (B, H, num_q_blocks, num_kv_blocks) with the kv dimension innermost,
so each (b, h, iq) row streams kv blocks sequentially while the accumulators
(o, m, l) persist in VMEM scratch.  Causal block skipping happens at the
grid level on real TPUs via masking inside ``pl.when`` (the block's work is
predicated off); the BlockSpecs keep every tile MXU-aligned (block sizes are
multiples of 128 on the lane dim) and the working set
(bq*d + 2*bk*d + bq*bk) * 4B inside VMEM.

GQA is expressed in the k/v index_maps: query head h reads kv head
h // (H // Hkv) - no materialized head expansion.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_kv: int, seq: int,
            causal: bool, window):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = iq * block_q
    k_lo = ik * block_kv
    # block-level causal/window skip (predicated off on TPU)
    run = jnp.bool_(True)
    if causal:
        run = run & (q_lo + block_q - 1 >= k_lo)
    if window is not None:
        run = run & (q_lo - (k_lo + block_kv - 1) < window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        lg = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        diff = q_pos - k_pos
        if causal:
            lg = jnp.where(diff < 0, NEG_INF, lg)
        if window is not None:
            lg = jnp.where(diff >= window, NEG_INF, lg)

        m_prev = m_scr[:, :1]                          # [bq, 1]
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(lg, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
        p = jnp.exp(lg - m_safe)                       # [bq, bk]
        corr = jnp.exp(m_prev - m_safe)                # [bq, 1]
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    scale=None, block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False):
    """q: [B, H, S, d]; k, v: [B, Hkv, S, d] -> [B, H, S, d]."""
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0
    group = H // Hkv
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0
    nq, nk = S // block_q, S // block_kv
    scale = scale or 1.0 / math.sqrt(d)

    grid = (B, H, nq, nk)
    kern = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_kv=block_kv, seq=S,
        causal=causal, window=window)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # m
            pltpu.VMEM((block_q, 128), jnp.float32),   # l
            pltpu.VMEM((block_q, d), jnp.float32),     # o accumulator
        ],
        interpret=interpret,
    )(q, k, v)
