"""Mamba-2 SSD chunked scan - Pallas TPU kernel.

Grid: (B, H, num_chunks) with the chunk dimension innermost; TPU grids
execute sequentially, so the running SSM state [P, N] lives in VMEM scratch
and carries across chunk steps (reset at chunk 0).  Each step computes, for
one (batch, head, chunk):

  intra:  y_d = (C B^T (.) exp(segsum(a))) xdt          [c, P]
  carry:  S  <- exp(sum a) * S + sum_s exp(a_cs[-1]-a_cs[s]) B_s (x) xdt_s
  inter:  y_o = C S_prev (.) exp(a_cs)

which is the same block structure as models/ssm.mamba2_chunked, but with
the chunk working set ((3c*N + 2cP + c*c + P*N) * 4B) held in VMEM and the
inter-chunk recurrence carried on-chip instead of through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(xdt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, s_scr, *,
            chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    xdt = xdt_ref[0, 0].astype(jnp.float32)        # [c, P]
    a = a_ref[0, 0].astype(jnp.float32)            # [1, c] row
    Bm = b_ref[0, 0].astype(jnp.float32)           # [c, N]
    Cm = c_ref[0, 0].astype(jnp.float32)           # [c, N]
    a = a.reshape(chunk)

    a_cs = jnp.cumsum(a)                           # [c]
    # intra-chunk: L[t,s] = exp(a_cs[t]-a_cs[s]) for t>=s
    diff = a_cs[:, None] - a_cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(tri, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [c, c]
    y = jax.lax.dot_general(CB * Lmat, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [c, P]

    # inter-chunk: contribution of carried state
    decay_from_start = jnp.exp(a_cs)[:, None]                      # [c, 1]
    y = y + jax.lax.dot_general(Cm * decay_from_start, s_scr[...].T,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update for the next chunk
    a_tot = a_cs[-1]
    decay_to_end = jnp.exp(a_tot - a_cs)[:, None]                  # [c, 1]
    s_new = jax.lax.dot_general(xdt, Bm * decay_to_end,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [P,N]
    s_scr[...] = s_scr[...] * jnp.exp(a_tot) + s_new

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        state_out_ref[0, 0] = s_scr[...].astype(state_out_ref.dtype)


def mamba2_chunk_scan(xdt, a, Bm, Cm, *, chunk: int = 128,
                      interpret: bool = False):
    """xdt: [B, H, L, P]; a: [B, H, L]; Bm, Cm: [B, H, L, N].
    Returns (y [B, H, L, P], final state [B, H, P, N])."""
    B, H, L, P = xdt.shape
    N = Bm.shape[-1]
    chunk = min(chunk, L)
    assert L % chunk == 0
    nc = L // chunk

    grid = (B, H, nc)
    kern = functools.partial(_kernel, chunk=chunk)
    y, state = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, ic: (b, h, ic)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ic: (b, h, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, L, P), xdt.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, a, Bm, Cm)
    return y, state
