"""1-bit gradient compression with error feedback - Pallas TPU kernels.

The paper (§3.7) credits CNTK's Data-Parallel SGD with the 1-bit trick:
quantize gradients to sign bits + a per-row L1 scale, add the quantization
error to the next step's gradient (error feedback).  These kernels do the
pack/unpack on-chip so the wire payload is bits, not floats - a
distributed-optimization feature of the framework (strategy
``compression="onebit"``).

quantize:  g, err [R, C] f32 -> packed u32 [R, C/32], scale [R, 1], err'
dequantize: packed, scale -> +-scale  [R, C]

Grid over row blocks; C is the lane dim (multiple of 128); the pack is a
shift-and-add over a [bm, C/32, 32] view.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(g_ref, e_ref, packed_ref, scale_ref, err_ref):
    g = g_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    q = g + e
    scale = jnp.mean(jnp.abs(q), axis=1, keepdims=True)      # [bm, 1]
    signs = (q >= 0)
    deq = jnp.where(signs, scale, -scale)
    err_ref[...] = (q - deq).astype(err_ref.dtype)
    scale_ref[...] = jnp.broadcast_to(scale, scale_ref.shape).astype(
        scale_ref.dtype)
    bm, C = q.shape
    bits = signs.reshape(bm, C // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    packed_ref[...] = jnp.sum(bits * weights[None, None, :],
                              axis=-1).astype(jnp.uint32)


def _dequant_kernel(packed_ref, scale_ref, out_ref):
    packed = packed_ref[...]                                  # [bm, C/32]
    scale = scale_ref[:, :1].astype(jnp.float32)              # [bm, 1]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    bm = packed.shape[0]
    signs = bits.reshape(bm, -1).astype(jnp.float32)
    out_ref[...] = ((2.0 * signs - 1.0) * scale).astype(out_ref.dtype)


def onebit_quantize(g, err, *, block_rows: int = 256,
                    interpret: bool = False):
    """g, err: [R, C] (C % 128 == 0). -> (packed u32 [R,C/32],
    scale [R,128] (lane-replicated), new_err [R,C])."""
    R, C = g.shape
    assert C % 128 == 0, C
    block_rows = min(block_rows, R)
    assert R % block_rows == 0
    grid = (R // block_rows,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, C // 32), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C // 32), jnp.uint32),
            jax.ShapeDtypeStruct((R, 128), jnp.float32),
            jax.ShapeDtypeStruct((R, C), g.dtype),
        ],
        interpret=interpret,
    )(g, err)


def onebit_dequantize(packed, scale, *, block_rows: int = 256,
                      interpret: bool = False):
    """packed: [R, C/32] u32; scale: [R, 128] -> [R, C] f32."""
    R, Cp = packed.shape
    C = Cp * 32
    block_rows = min(block_rows, R)
    assert R % block_rows == 0
    grid = (R // block_rows,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, Cp), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(packed, scale)
