"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Flash attention oracle: materialized softmax attention (causal / windowed,
# GQA via head mapping q_head -> kv_head * (H // Hkv)).
# ---------------------------------------------------------------------------
def flash_attention_ref(q, k, v, *, causal: bool = True, window=None,
                        scale=None):
    """q: [B, H, S, d]; k, v: [B, Hkv, S, d] -> [B, H, S, d]."""
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    scale = scale or 1.0 / math.sqrt(d)
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=1)
        v = jnp.repeat(v, H // Hkv, axis=1)
    lg = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    pos = jnp.arange(S)
    diff = pos[:, None] - pos[None, :]
    mask = jnp.zeros((S, S), jnp.float32)
    if causal:
        mask = jnp.where(diff < 0, NEG_INF, mask)
    if window is not None:
        mask = jnp.where(diff >= window, NEG_INF, mask)
    lg = lg + mask[None, None]
    p = jax.nn.softmax(lg, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)


# ---------------------------------------------------------------------------
# Mamba-2 SSD oracle: direct sequential recurrence in fp32.
#   h_t = exp(a_t) * h_{t-1} + B_t (x_t)     (outer product into [P, N])
#   y_t = C_t . h_t
# ---------------------------------------------------------------------------
def mamba2_scan_ref(xdt, a, Bm, Cm):
    """xdt: [B, H, L, P] (dt-weighted inputs); a: [B, H, L] log-decays;
    Bm, Cm: [B, H, L, N].  Returns y [B, H, L, P] and final state
    [B, H, P, N]."""
    Bsz, H, L, P = xdt.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, a_t, b_t, c_t = inp
        h = h * jnp.exp(a_t)[..., None, None] + \
            x_t[..., :, None] * b_t[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    inputs = (jnp.moveaxis(xdt.astype(jnp.float32), 2, 0),
              jnp.moveaxis(a.astype(jnp.float32), 2, 0),
              jnp.moveaxis(Bm.astype(jnp.float32), 2, 0),
              jnp.moveaxis(Cm.astype(jnp.float32), 2, 0))
    h, ys = jax.lax.scan(step, h0, inputs)
    return jnp.moveaxis(ys, 0, 2).astype(xdt.dtype), h


# ---------------------------------------------------------------------------
# 1-bit gradient compression oracle (error feedback): per-row sign + L1 scale
# ---------------------------------------------------------------------------
def onebit_quantize_ref(g, err):
    """g, err: [R, C] fp32 -> (signs bool [R, C], scale [R, 1], new_err)."""
    q = g + err
    scale = jnp.mean(jnp.abs(q), axis=1, keepdims=True)
    signs = q >= 0
    deq = jnp.where(signs, scale, -scale)
    new_err = q - deq
    return signs, scale, new_err


def onebit_dequantize_ref(signs, scale):
    return jnp.where(signs, scale, -scale)
