"""Checkpoint format layer: shard files + manifest (DESIGN.md §10).

The byte-level contract of a checkpoint, kept free of scheduling (and of
jax): everything here is plain numpy + files, so a shard task ships to a
worker locality by reference and runs anywhere.  ``checkpoint.py`` is
the I/O layer that schedules these functions as futurized tasks on
their owning localities.

Layout (one directory per step):

    <dir>/step_00000120/
        manifest.json       tree structure, shard->locality ownership
                            map, per-shard checksums; driver-written,
                            committed LAST (atomic rename)
        shard_00000.bin     the leaves owned by locality 0
        shard_00001.bin     the leaves owned by locality 1 ...

A shard file is the concatenation of raw ``.npy`` segments; the
manifest records each segment's byte offset and length, so any single
segment is loadable without parsing a container format - and a flipped
byte is caught by a checksum mismatch (``CheckpointCorruptError``
naming the shard), never by a zip CRC blowing up the parse.

A segment is usually a whole leaf, but under multi-host SPMD saves
(DESIGN.md §10) a leaf may be split into *device-shard* segments: each
process persists exactly the blocks of the global array it can address
(``jax.Array.addressable_shards``), so a segment then also records the
``slice`` of the global leaf it holds plus the leaf's ``global_shape``.
``assemble_leaf`` re-joins segments (from any number of shard files)
into the full leaf at restore, verifying exact coverage.

Invariants the I/O layer relies on:
  * ``save_shard`` is idempotent and atomic (write-ahead temp file +
    ``os.replace``): re-running it after a locality died mid-write
    converges to the same bytes, never a torn shard;
  * the manifest is assembled by the driver only after every shard
    entry resolved, written into the temp step directory, and the
    directory is then renamed - a crash at any point leaves either the
    previous checkpoint or a complete new one, never a torn manifest;
  * every leaf is checksummed (blake2b over dtype + shape + bytes) at
    save and verified at restore;
  * shard->locality ownership is recorded (the writer's actual rank,
    from ``PHYRAX_LOCALITY_RANK``), but restore never requires it:
    shards are readable by any locality count (N->M resharding,
    M=1 included).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
import time
from pathlib import Path
from typing import Iterable, Optional

import numpy as np

__all__ = ["CheckpointCorruptError", "FORMAT_VERSION", "MANIFEST_NAME",
           "assemble_leaf", "assign_shards", "build_manifest",
           "commit_manifest", "leaf_checksum", "load_manifest",
           "read_shard", "read_shard_segments", "save_shard",
           "shard_checksum", "shard_filename", "writer_rank"]

FORMAT_VERSION = "phyrax-ckpt/3"
# phyrax-ckpt/2 checkpoints (whole-leaf segments only) read unchanged
COMPAT_VERSIONS = frozenset({"phyrax-ckpt/2", FORMAT_VERSION})
MANIFEST_NAME = "manifest.json"


class CheckpointCorruptError(IOError):
    """A checkpoint failed verification at restore: a shard file is
    missing, truncated, unparseable, or a checksum does not match the
    manifest.  The message names the offending shard (and leaf)."""


def writer_rank() -> int:
    """The locality rank this process writes shards as.

    Read from ``PHYRAX_LOCALITY_RANK`` (exported by
    ``distrib.runtime.worker_main`` at spawn); 0 - the driver - when
    unset.  Recorded in every shard entry, so the manifest's ownership
    map reflects the *actual* writer even after a failure re-spawn.
    """
    return int(os.environ.get("PHYRAX_LOCALITY_RANK", "0"))


def shard_filename(shard_id: int) -> str:
    """Canonical shard file name (``shard_00003.bin``)."""
    return f"shard_{shard_id:05d}.bin"


def leaf_checksum(a: np.ndarray) -> str:
    """blake2b over one leaf's dtype + shape + raw bytes."""
    h = hashlib.blake2b(digest_size=16)
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def shard_checksum(leaf_checksums: Iterable[str]) -> str:
    """Shard-level checksum: blake2b over the ordered leaf checksums."""
    h = hashlib.blake2b(digest_size=16)
    for c in leaf_checksums:
        h.update(c.encode())
    return h.hexdigest()


def assign_shards(n_leaves: int, ranks) -> list[tuple[int, int, list[int]]]:
    """Partition ``n_leaves`` global leaf indices into one shard per
    locality rank (contiguous blocks, sized as evenly as possible).

    Args:
        n_leaves: leaf count of the flattened tree.
        ranks: locality ranks that will own a shard, in order (the
            save-time world, e.g. ``[0, 1, 2]`` - 0 is the driver).
    Returns:
        ``[(shard_id, rank, leaf_indices), ...]``; empty shards are
        dropped, so ``n_leaves < len(ranks)`` yields fewer shards than
        ranks.
    """
    ranks = list(ranks)
    n = max(1, len(ranks))
    base, extra = divmod(n_leaves, n)
    out: list[tuple[int, int, list[int]]] = []
    start = 0
    for i, rank in enumerate(ranks):
        size = base + (1 if i < extra else 0)
        if size == 0:
            continue
        out.append((len(out), rank, list(range(start, start + size))))
        start += size
    return out


def save_shard(directory: str, shard_id: int, indices, arrays,
               *_deps, slices=None) -> dict:
    """Write one shard file (idempotent, atomic) and return its manifest
    entry.

    Runs on the owning locality as a futurized CHECKPOINT task; the
    trailing ``*_deps`` swallow dependency-edge values (step retirement,
    the previous save) that exist only for ordering.

    Args:
        directory: the *temporary* step directory (created here if
            missing - concurrent writers race benignly on mkdir).
        shard_id: shard index within the checkpoint.
        indices: global leaf indices stored in this shard, in order.
            The same index may repeat when a leaf is split into
            device-shard segments.
        arrays: the segment values (numpy) matching ``indices``.
        slices: optional parallel list; entry ``i`` is None for a whole
            leaf, or ``(slice_pairs, global_shape)`` where
            ``slice_pairs`` is ``[[start, stop], ...]`` per dimension of
            the global leaf - the SPMD addressable-shard save path
            (DESIGN.md §10).
    Returns:
        The shard's manifest entry: file name, writer locality,
        per-segment byte offsets / shapes / dtypes / checksums (plus
        ``slice``/``global_shape`` for device-shard segments), and a
        shard-level checksum.
    """
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    name = shard_filename(shard_id)
    leaves, offset = [], 0
    if slices is None:
        slices = [None] * len(list(indices))
    tmp = d / f"{name}.wip-{os.getpid()}"
    # stream segment by segment: only one serialized blob is in memory
    # at a time, not the whole shard
    with open(tmp, "wb") as f:
        for idx, a, sl in zip(indices, arrays, slices):
            a = np.asarray(a)
            buf = io.BytesIO()
            np.save(buf, a)
            blob = buf.getvalue()
            entry = {"index": int(idx), "shape": list(a.shape),
                     "dtype": str(a.dtype),
                     "offset": offset, "nbytes": len(blob),
                     "checksum": leaf_checksum(a)}
            if sl is not None:
                pairs, global_shape = sl
                entry["slice"] = [[int(s), int(e)] for s, e in pairs]
                entry["global_shape"] = [int(n) for n in global_shape]
            leaves.append(entry)
            f.write(blob)
            offset += len(blob)
    os.replace(tmp, d / name)     # atomic: re-runs converge, never tear
    return {"file": name, "shard": int(shard_id),
            "locality": writer_rank(), "nbytes": offset, "leaves": leaves,
            "checksum": shard_checksum(e["checksum"] for e in leaves)}


def read_shard_segments(directory: str, entry: dict, *,
                        verify: bool = True) -> list:
    """Read one shard file back as a list of segments.

    Runs on *any* locality - a resharded restore does not need the
    writer; with ``verify`` every segment is re-checksummed against the
    manifest entry.

    Args:
        directory: the committed step directory.
        entry: this shard's manifest entry (``manifest["shards"][i]``).
        verify: verify per-segment checksums plus the shard checksum.
    Returns:
        List of ``{"index", "slice", "global_shape", "array"}`` dicts;
        ``slice``/``global_shape`` are None for whole-leaf segments.
    Raises:
        CheckpointCorruptError: the shard file is missing, truncated, or
            fails verification; the message names the shard (and leaf).
    """
    path = Path(directory) / entry["file"]
    try:
        raw = path.read_bytes()
    except OSError as e:
        raise CheckpointCorruptError(
            f"shard {entry['file']} unreadable in {directory}: {e}") from e
    out: list[dict] = []
    sums = []
    for leaf in entry["leaves"]:
        blob = raw[leaf["offset"]:leaf["offset"] + leaf["nbytes"]]
        if len(blob) != leaf["nbytes"]:
            raise CheckpointCorruptError(
                f"shard {entry['file']} truncated at leaf {leaf['index']} "
                f"({len(blob)} of {leaf['nbytes']} bytes)")
        try:
            a = np.load(io.BytesIO(blob), allow_pickle=False)
        except Exception as e:
            raise CheckpointCorruptError(
                f"shard {entry['file']} leaf {leaf['index']} does not "
                f"parse: {e}") from e
        if verify:
            got = leaf_checksum(a)
            sums.append(got)
            if got != leaf["checksum"]:
                raise CheckpointCorruptError(
                    f"checksum mismatch in shard {entry['file']} "
                    f"(leaf {leaf['index']}) - refusing to load a corrupt "
                    f"checkpoint")
        out.append({"index": int(leaf["index"]),
                    "slice": leaf.get("slice"),
                    "global_shape": leaf.get("global_shape"),
                    "array": a})
    if verify and shard_checksum(sums) != entry["checksum"]:
        raise CheckpointCorruptError(
            f"shard checksum mismatch in {entry['file']}")
    return out


def read_shard(directory: str, entry: dict, *, verify: bool = True) -> dict:
    """Read one whole-leaf shard file back into
    ``{global_leaf_index: array}``.

    Thin wrapper over ``read_shard_segments`` for shards whose segments
    are full leaves (every host-copy-mode shard).  Shards holding
    device-shard segments span leaves across files and must be
    assembled via ``read_shard_segments`` + ``assemble_leaf`` instead.

    Args:
        directory: the committed step directory.
        entry: this shard's manifest entry (``manifest["shards"][i]``).
        verify: verify per-segment checksums plus the shard checksum.
    Returns:
        Mapping of global leaf index -> numpy array.
    Raises:
        CheckpointCorruptError: corrupt shard, or a sliced (device-shard)
            segment that this whole-leaf API cannot represent.
    """
    out: dict[int, np.ndarray] = {}
    for seg in read_shard_segments(directory, entry, verify=verify):
        if seg["slice"] is not None:
            raise CheckpointCorruptError(
                f"shard {entry['file']} leaf {seg['index']} is a "
                f"device-shard segment (SPMD save); use "
                f"read_shard_segments + assemble_leaf")
        out[seg["index"]] = seg["array"]
    return out


def assemble_leaf(leaf_index: int, segments: list) -> np.ndarray:
    """Re-join one leaf from its segments (possibly from several shard
    files - the N->M restore of an SPMD checkpoint).

    Args:
        leaf_index: global leaf index (for error messages).
        segments: this leaf's ``read_shard_segments`` dicts.
    Returns:
        The full leaf as a numpy array.
    Raises:
        CheckpointCorruptError: no segments, a whole-leaf segment mixed
            with sliced ones, disagreeing global shapes, or segments
            that do not cover the leaf exactly.
    """
    if not segments:
        raise CheckpointCorruptError(f"leaf {leaf_index}: no segments")
    whole = [s for s in segments if s["slice"] is None]
    if whole:
        if len(segments) != 1:
            raise CheckpointCorruptError(
                f"leaf {leaf_index}: whole-leaf segment duplicated or "
                f"mixed with device-shard segments")
        return whole[0]["array"]
    shapes = {tuple(s["global_shape"]) for s in segments}
    if len(shapes) != 1:
        raise CheckpointCorruptError(
            f"leaf {leaf_index}: segments disagree on the global shape "
            f"({sorted(shapes)})")
    shape = shapes.pop()
    out = np.empty(shape, dtype=segments[0]["array"].dtype)
    covered = 0
    boxes = [seg["slice"] for seg in segments]
    # disjointness + total count == exact cover (overlapping segments
    # would hide an uncovered - uninitialized - region from the count)
    for i, a in enumerate(boxes):
        for b in boxes[i + 1:]:
            if all(s1 < e2 and s2 < e1
                   for (s1, e1), (s2, e2) in zip(a, b)):
                raise CheckpointCorruptError(
                    f"leaf {leaf_index}: segments {a} and {b} overlap")
    for seg in segments:
        sl = tuple(slice(s, e) for s, e in seg["slice"])
        if out[sl].shape != seg["array"].shape:
            raise CheckpointCorruptError(
                f"leaf {leaf_index}: segment slice {seg['slice']} does "
                f"not match its array shape {seg['array'].shape}")
        out[sl] = seg["array"]
        covered += seg["array"].size
    if covered != out.size:
        raise CheckpointCorruptError(
            f"leaf {leaf_index}: segments cover {covered} of {out.size} "
            f"elements - a device shard is missing from every shard file")
    return out


def build_manifest(*, step: int, treedef: str, n_leaves: int,
                   shards: list, meta: Optional[dict] = None) -> dict:
    """Assemble the manifest (driver-side, after every shard entry
    resolved).

    The ownership map is derived from the entries' recorded writer
    localities, so a shard re-written elsewhere after its owner died is
    attributed to its actual writer.

    Args:
        step: training step the snapshot belongs to.
        treedef: ``str(jax.tree.flatten(tree)[1])`` - the tree structure.
        n_leaves: global leaf count (shards must cover exactly these).
        shards: the ``save_shard`` entries, any order.
        meta: free-form user metadata.
    Returns:
        The manifest dict (see DESIGN.md §10 for the schema).
    """
    shards = sorted(shards, key=lambda e: e["shard"])
    ownership: dict[str, list[int]] = {}
    for e in shards:
        ownership.setdefault(str(e["locality"]), []).append(e["shard"])
    return {"format": FORMAT_VERSION, "step": int(step),
            "treedef": treedef, "n_leaves": int(n_leaves),
            "n_shards": len(shards), "shards": shards,
            "ownership": ownership, "meta": meta or {},
            "saved_at": time.strftime("%Y-%m-%d %H:%M:%S")}


def commit_manifest(tmp_dir, final_dir, manifest: dict) -> Path:
    """Atomic commit: write ``manifest.json`` into the temp step
    directory, then rename the directory to its final name (replacing a
    previous checkpoint of the same step).

    The manifest lands LAST: a crash before the rename leaves no
    ``step_*`` directory at all, so a reader never observes a torn
    checkpoint.

    Args:
        tmp_dir: the temp step directory holding every shard file.
        final_dir: the committed ``step_XXXXXXXX`` path.
        manifest: the ``build_manifest`` result.
    Returns:
        ``final_dir`` as a ``Path``.
    """
    tmp_dir, final_dir = Path(tmp_dir), Path(final_dir)
    # every shard entry has resolved by now, so anything the manifest
    # does not reference is a dead writer's orphan - a .wip-* write-ahead
    # file, or a stale shard from an aborted attempt with a different
    # world size - and must not be committed
    referenced = {e["file"] for e in manifest.get("shards", [])}
    for p in tmp_dir.iterdir():
        if p.name != MANIFEST_NAME and p.name not in referenced:
            p.unlink()
    (tmp_dir / MANIFEST_NAME).write_text(json.dumps(manifest, indent=1))
    if final_dir.exists():
        shutil.rmtree(final_dir)
    os.rename(tmp_dir, final_dir)
    return final_dir


def load_manifest(step_dir) -> dict:
    """Read and minimally validate a committed step's manifest.

    Args:
        step_dir: a committed ``step_XXXXXXXX`` directory.
    Returns:
        The manifest dict.
    Raises:
        CheckpointCorruptError: missing or unparseable manifest, or a
            format version this layer does not understand.
    """
    path = Path(step_dir) / MANIFEST_NAME
    try:
        manifest = json.loads(path.read_text())
    except OSError as e:
        raise CheckpointCorruptError(
            f"no manifest in {step_dir}: {e}") from e
    except json.JSONDecodeError as e:
        raise CheckpointCorruptError(
            f"manifest in {step_dir} does not parse: {e}") from e
    if manifest.get("format") not in COMPAT_VERSIONS:
        raise CheckpointCorruptError(
            f"{step_dir}: unsupported checkpoint format "
            f"{manifest.get('format')!r} (want one of "
            f"{sorted(COMPAT_VERSIONS)})")
    return manifest
