"""Locality-owned sharded checkpoints (DESIGN.md §10).

``format`` is the byte-level contract - shard files, the driver-written
manifest (tree structure, shard->locality ownership map, per-shard
checksums), atomic rename commit; ``checkpoint`` is the futurized I/O
layer that schedules save/load shard tasks on their owning localities
and reshards on restore (N writers -> M readers, M=1 included)."""
from .checkpoint import CheckpointManager  # noqa: F401
from .format import (CheckpointCorruptError, assign_shards,  # noqa: F401
                     build_manifest, commit_manifest, load_manifest,
                     read_shard, save_shard)

__all__ = ["CheckpointCorruptError", "CheckpointManager", "assign_shards",
           "build_manifest", "commit_manifest", "load_manifest",
           "read_shard", "save_shard"]
