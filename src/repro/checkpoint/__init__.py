"""Locality-owned sharded checkpoints (DESIGN.md §10).

``format`` is the byte-level contract - shard files of (possibly
device-shard) segments, the driver-written manifest (tree structure,
shard->locality ownership map, per-shard checksums), atomic rename
commit; ``checkpoint`` is the futurized I/O layer that schedules
save/load shard tasks on their owning localities and reshards on
restore (N writers -> M readers, M=1 included); ``spmd`` is the
multi-host save path, where every ``jax.distributed`` process
serializes only the addressable shards of its global arrays."""
from .checkpoint import CheckpointManager  # noqa: F401
from .format import (CheckpointCorruptError, assemble_leaf,  # noqa: F401
                     assign_shards, build_manifest, commit_manifest,
                     load_manifest, read_shard, read_shard_segments,
                     save_shard)
from .spmd import write_spmd_shard  # noqa: F401

__all__ = ["CheckpointCorruptError", "CheckpointManager", "assemble_leaf",
           "assign_shards", "build_manifest", "commit_manifest",
           "load_manifest", "read_shard", "read_shard_segments",
           "save_shard", "write_spmd_shard"]
