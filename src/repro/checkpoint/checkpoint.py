"""Distributed checkpoint I/O: locality-owned shards as futurized tasks.

The byte-level format lives in ``format.py`` (DESIGN.md §10); this
module is the scheduling half.  ``CheckpointManager`` turns each save
into per-shard ``save_shard`` tasks placed on the locality that OWNS
the shard (``DistributedGraph.defer``; the driver is rank 0 and owns a
shard too), chained on the CHECKPOINT lane behind step retirement and
the previous save, so saves overlap training.  The manifest is built by
the driver only after every shard entry resolved and committed
atomically by rename - the driver no longer serializes or writes the
whole snapshot.

Properties the launcher relies on:
  * distributed save: each locality checksums, serializes, and writes
    the shards it owns; with one locality everything runs locally
    through the same format layer;
  * async save: the device->host transfer happens on the caller, every
    shard write is a ``Lane.CHECKPOINT`` graph node, so training
    continues while bytes hit disk;
  * failure model: a killed locality's shard tasks are idempotent and
    re-spawn on a survivor (or the driver), with the actual writer
    recorded in the manifest; if a save cannot complete, the manifest
    is never committed - the previous checkpoint stays latest, no torn
    state (paper R9);
  * resharded restore: shards are read by the CURRENT localities, which
    need not be the writers - a checkpoint written by N localities
    restores into M (M=1 included), with checksum verification
    (``CheckpointCorruptError`` names the bad shard);
  * elastic restore: leaves are ``device_put`` against the *current*
    mesh's shardings - a snapshot written on one mesh restores onto any
    other topology.

Multi-host SPMD mode (``jax.distributed`` active, DESIGN.md §10): the
save path switches from host copies to *addressable shards*.  This
process (the driver, jax process 0) writes only the blocks it
addresses; every other process writes its own blocks from inside its
shadow train loop (``frontend.spmd``) and ships back just the manifest
ENTRY - metadata - as an active message, which resolves a
``DistributedGraph.spmd_entry_futures`` promise here.  No leaf bytes
cross the messaging layer in either direction
(``stats()["ckpt_leaf_wire_bytes"]`` stays 0); the driver still
assembles and atomically commits the manifest.  A writer lost mid-save
is unrecoverable in SPMD mode - nobody else holds its bytes - so the
save ABORTS (never commits, counted in ``aborted_saves``) instead of
re-spawning, and the previous checkpoint stays latest.
"""
from __future__ import annotations

import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from ..core.futures import FuturizedGraph, Lane, PhyFuture
from ..distrib.runtime import LocalityLostError
from . import format as fmt
from . import spmd
from .format import CheckpointCorruptError

__all__ = ["CheckpointCorruptError", "CheckpointManager"]


def _prepare_tmp(tmp: str, *_deps):
    """Dependency gate + clean slate.  Collapses the (step retirement,
    previous save) edges into one local node, so shard tasks ship no
    device values - and wipes a stale temp dir left by an aborted
    earlier attempt of the same step, so its files can never leak into
    this save's commit.  Runs strictly after the previous save's commit
    (saves chain), strictly before this save's shard writes (they
    depend on it)."""
    p = Path(tmp)
    if p.exists():
        shutil.rmtree(p)
    p.mkdir(parents=True)
    return None


def _prepare_tmp_spmd(tmp: str, *_deps):
    """The SPMD save gate: same edge collapse, but NO wipe - the other
    processes' shadow loops may already have streamed their shard files
    into the temp dir before the driver's gate runs (they pace
    themselves, not the driver).  Stale files from an aborted earlier
    attempt are instead pruned at commit: ``format.commit_manifest``
    deletes everything the manifest does not reference."""
    Path(tmp).mkdir(parents=True, exist_ok=True)
    return None


class CheckpointManager:
    """Schedules checkpoint saves/restores over the futurized runtime.

    When ``graph`` is supplied (the Session-owned path: ``Session.train``
    passes its runtime), save nodes ride that graph and ``close()`` only
    drains pending writes - the graph's lifetime belongs to its owner.
    Standalone use spins up a private graph, shut down on ``close()``.
    Usable as a context manager either way.

    Args:
        directory: checkpoint root; one ``step_XXXXXXXX`` dir per save.
            Shared by every locality (same filesystem / shared mount).
        keep: committed checkpoints retained (older ones are GC'd).
        async_save: schedule writes as graph nodes (False runs saves
            inline on the caller, single-locality, for tests).
        graph: the ``FuturizedGraph`` save/commit nodes ride; private
            one created (and owned) when None.
        dgraph: a ``repro.distrib.DistributedGraph``; when given, shard
            tasks are placed on their owning localities and restores
            spread shard reads over the current localities.  Its local
            graph must be ``graph`` (futures cannot span graphs).
    Raises:
        ValueError: ``graph`` and ``dgraph.graph`` differ.
    """

    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = True,
                 graph: Optional[FuturizedGraph] = None,
                 dgraph: Optional[Any] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._dgraph = dgraph
        if dgraph is not None:
            if graph is not None and graph is not dgraph.graph:
                raise ValueError(
                    "graph and dgraph.graph must be the same "
                    "FuturizedGraph - distributed shard futures cannot "
                    "span graphs")
            self._own_graph = False
            self._graph = dgraph.graph
        else:
            self._own_graph = graph is None
            self._graph = graph if graph is not None else FuturizedGraph(
                max_workers=2, name="checkpoint")
        self._pending: Optional[PhyFuture] = None
        self._pending_step: Optional[int] = None
        self.aborted_saves = 0          # SPMD saves lost with a writer

    # -- placement ------------------------------------------------------------
    def ranks(self) -> list[int]:
        """Locality ranks owning a shard of the next save: the driver
        plus every alive worker (``[0]`` without a distributed graph)."""
        if self._dgraph is None:
            return [0]
        return [0] + self._dgraph.group.alive_workers()

    def _defer_on(self, rank: int, fn, *args, name: str, **kwargs):
        """One CHECKPOINT-lane task on ``rank`` (driver-local without a
        distributed graph); falls back to the driver if ``rank`` died
        between ``ranks()`` and this call."""
        if self._dgraph is None:
            return self._graph.defer(fn, *args, lane=Lane.CHECKPOINT,
                                     name=name, **kwargs)
        try:
            return self._dgraph.defer(fn, *args, lane=Lane.CHECKPOINT,
                                      name=name, locality=rank,
                                      idempotent=True, **kwargs)
        except ValueError:            # rank died since ranks(): retarget
            return self._dgraph.defer(fn, *args, lane=Lane.CHECKPOINT,
                                      name=name, locality=0,
                                      idempotent=True, **kwargs)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, *, meta: Optional[dict] = None,
             deps: tuple = ()):
        """Snapshot a pytree as locality-owned shards.

        Returns immediately when async: the tree is split into one shard
        per locality (``format.assign_shards``), each written by its
        owning locality as a ``Lane.CHECKPOINT`` task gated on ``deps``
        (e.g. the step-retirement future) and on the previous save
        (writes chain by dependency edge, never by blocking the caller);
        the driver commits the manifest only after every shard resolved.
        The device->host transfer stays synchronous: leaf buffers may be
        donated to the next dispatched step, so values are captured now.

        Fail fast: if the previous async save already finished with an
        error, raise it here rather than silently poisoning every later
        write in the dependency chain until close().  Exception: an SPMD
        save aborted because its writer died (``LocalityLostError``) is
        *expected* under host loss - it never committed, the previous
        checkpoint stays latest - so it is counted (``aborted_saves``)
        and warned about, not raised.

        In SPMD mode (``jax.distributed`` with more than one process)
        the snapshot is written as addressable shards: see the module
        docstring.  ``async_save=False`` is unsupported there (a sync
        save cannot await the other processes' entries).

        Args:
            step: step number the snapshot belongs to.
            tree: the pytree to snapshot.
            meta: free-form metadata stored in the manifest.
            deps: futures the shard writes must wait for.
        Returns:
            The manifest-commit ``PhyFuture`` (resolving to the committed
            directory) when async; the committed ``Path`` when sync.
        """
        self._raise_if_failed()
        if spmd.is_multiprocess():
            return self._save_spmd(step, tree, meta=meta, deps=deps)
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]
        treedef_str = str(treedef)
        shards = fmt.assign_shards(len(host), self.ranks())
        tmp = self.dir / f".tmp_step_{step:08d}"
        final = self.dir / f"step_{step:08d}"

        if not self.async_save:
            for d in deps:
                d.result()
            _prepare_tmp(str(tmp))
            entries = [fmt.save_shard(str(tmp), sid, idx,
                                      [host[i] for i in idx])
                       for sid, _rank, idx in shards]
            return self._commit(step, treedef_str, len(host), meta,
                                str(tmp), str(final), *entries)

        order = deps if self._pending is None else (*deps, self._pending)
        gate = self._graph.defer(_prepare_tmp, str(tmp), *order,
                                 lane=Lane.CHECKPOINT,
                                 name=f"ckpt:gate:{step}")
        entry_futs = []
        for sid, rank, idx in shards:
            if rank != 0 and self._dgraph is not None:
                # host-copy mode ships the owner its leaf bytes in the
                # spawn payload; the counter is what the SPMD-mode
                # regression test asserts stays 0
                self._dgraph.account_ckpt_leaf_bytes(
                    sum(host[i].nbytes for i in idx))
            entry_futs.append(
                self._defer_on(rank, fmt.save_shard, str(tmp), sid,
                               list(idx), [host[i] for i in idx], gate,
                               name=f"ckpt:shard{sid}:{step}"))
        self._pending = self._graph.defer(
            self._commit, step, treedef_str, len(host), meta,
            str(tmp), str(final), *entry_futs,
            lane=Lane.CHECKPOINT, name=f"ckpt:manifest:{step}")
        self._pending_step = step
        return self._pending

    # -- SPMD save (addressable shards; DESIGN.md §10) -------------------------
    def _save_spmd(self, step: int, tree: Any, *, meta, deps) -> PhyFuture:
        if not self.async_save:
            raise RuntimeError(
                "async_save=False is unsupported in SPMD mode: a "
                "synchronous save cannot await the other processes' "
                "shard entries")
        rank, world = jax.process_index(), jax.process_count()
        if rank != 0:
            raise RuntimeError(
                "CheckpointManager.save drives SPMD saves from jax "
                "process 0 (the driver); other processes write their "
                "shards via checkpoint.spmd.write_spmd_shard "
                "(frontend.spmd shadow loop)")
        leaves, treedef = jax.tree.flatten(tree)
        # capture THIS process's addressable blocks now (host copies),
        # before the caller's next step can donate the buffers
        indices, slices, arrays = spmd.collect_segments(tree)
        tmp = self.dir / f".tmp_step_{step:08d}"
        final = self.dir / f"step_{step:08d}"
        order = deps if self._pending is None else (*deps, self._pending)
        gate = self._graph.defer(_prepare_tmp_spmd, str(tmp), *order,
                                 lane=Lane.CHECKPOINT,
                                 name=f"ckpt:gate:{step}")
        # the driver's own shard: a local node - nothing ships anywhere
        mine = self._graph.defer(fmt.save_shard, str(tmp), rank,
                                 indices, arrays, gate, slices=slices,
                                 lane=Lane.CHECKPOINT,
                                 name=f"ckpt:shard{rank}:{step}")
        others = []
        if world > 1:
            if self._dgraph is None:
                raise RuntimeError(
                    "SPMD save needs a DistributedGraph to receive the "
                    "other processes' shard entries (Session passes it; "
                    "pass dgraph= for standalone use)")
            others = self._dgraph.spmd_entry_futures(
                step, [r for r in range(world) if r != rank])
        self._pending = self._graph.defer(
            self._commit, step, str(treedef), len(leaves), meta,
            str(tmp), str(final), mine, *others,
            lane=Lane.CHECKPOINT, name=f"ckpt:manifest:{step}")
        self._pending_step = step
        return self._pending

    def _commit(self, step, treedef_str, n_leaves, meta, tmp, final,
                *entries) -> Path:
        # a rank that addressed no replica-0 block contributes no shard
        entries = [e for e in entries if e is not None]
        manifest = fmt.build_manifest(step=step, treedef=treedef_str,
                                      n_leaves=n_leaves,
                                      shards=list(entries), meta=meta)
        out = fmt.commit_manifest(Path(tmp), Path(final), manifest)
        self._gc()
        return out

    def _raise_if_failed(self):
        """Surface a finished-failed pending save.  A LocalityLostError
        in SPMD mode means a writer died holding bytes nobody else has:
        the save aborted atomically (no manifest), which is survivable -
        warn and count it instead of killing the run."""
        if self._pending is None or not self._pending.done():
            return
        failed, self._pending = self._pending, None
        step, self._pending_step = self._pending_step, None
        exc = failed.exception()
        if exc is None:
            return
        if isinstance(exc, LocalityLostError) and spmd.is_multiprocess():
            self.aborted_saves += 1
            if step is not None:
                # reclaim the aborted attempt's temp dir now: _gc only
                # prunes temp dirs a LATER commit supersedes, and with a
                # writer permanently gone there may never be one - the
                # driver's full shard per abort would pile up
                shutil.rmtree(self.dir / f".tmp_step_{step:08d}",
                              ignore_errors=True)
            print(f"[ckpt] WARNING: SPMD save aborted, previous "
                  f"checkpoint stays latest: {exc}", flush=True)
            return
        raise exc

    def wait(self):
        """Barrier: block until every pending save has committed (or, in
        SPMD mode, aborted with its lost writer - see ``save``)."""
        if self._pending is not None:
            try:
                self._pending.result()
                self._pending = None
            except LocalityLostError:
                self._raise_if_failed()

    def close(self):
        """Shutdown barrier: drain pending saves; stop our workers if we
        own the graph (shared runtimes are shut down by their owner)."""
        self.wait()
        if self._own_graph:
            self._graph.shutdown(wait=True)

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc):
        self.close()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
        latest = steps[-1] if steps else None
        # temp dirs of aborted or superseded saves are garbage once a
        # same-or-later step has committed
        for p in self.dir.glob(".tmp_step_*"):
            try:
                s = int(p.name.rsplit("_", 1)[1])
            except ValueError:
                continue
            if latest is not None and s <= latest:
                shutil.rmtree(p, ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Any = None, strict_checksums: bool = True):
        """Load a pytree with the structure of ``like``.

        Shards are read by the CURRENT localities (spread round-robin
        over the driver + alive workers), which need not be the writers:
        a checkpoint written by N localities restores into M, including
        M=1.  An SPMD checkpoint's device-shard segments are re-joined
        per leaf (``format.assemble_leaf``) - the process count may have
        changed arbitrarily.  Leaves are placed against ``shardings``
        (same structure) for elastic mesh restore; a sharding spanning
        processes is honored without a single-host round-trip
        (``spmd.device_put_maybe_global``).

        Args:
            like: pytree giving the structure (and leaf count) expected.
            step: step to load; latest when None.
            shardings: optional shardings pytree for placement.
            strict_checksums: verify per-leaf + per-shard checksums.
        Returns:
            ``(step, tree)``.
        Raises:
            FileNotFoundError: no checkpoint under the directory.
            ValueError: leaf count does not match ``like``.
            CheckpointCorruptError: a shard is missing, truncated, or
                fails checksum verification (the message names it).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = fmt.load_manifest(d)
        leaves_like, treedef = jax.tree.flatten(like)
        if manifest["n_leaves"] != len(leaves_like):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"expected {len(leaves_like)}")
        parts: dict[int, list] = {}
        for segs in self._read_shards(d, manifest["shards"],
                                      strict_checksums):
            for seg in segs:
                parts.setdefault(seg["index"], []).append(seg)
        missing = [i for i in range(len(leaves_like)) if i not in parts]
        if missing:
            raise CheckpointCorruptError(
                f"{d}: leaves {missing} missing from every shard")
        by_index = {i: fmt.assemble_leaf(i, segs)
                    for i, segs in parts.items()}
        sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                     else [None] * len(leaves_like))
        out = [spmd.device_put_maybe_global(by_index[i], sh)
               for i, sh in enumerate(sh_leaves)]
        return step, jax.tree.unflatten(treedef, out)

    def _read_shards(self, d: Path, entries: list, verify: bool) -> list:
        ranks = self.ranks()
        # SPMD mode reads locally: worker localities run shadow loops
        # (each restores its own copy), and shipping segment bytes back
        # over the wire is exactly what this mode exists to avoid
        if self._dgraph is None or len(ranks) == 1 \
                or spmd.is_multiprocess():
            return [fmt.read_shard_segments(str(d), e, verify=verify)
                    for e in entries]
        futs = [self._defer_on(ranks[i % len(ranks)],
                               fmt.read_shard_segments,
                               str(d), e, verify=verify,
                               name=f"ckpt:load:{e['file']}")
                for i, e in enumerate(entries)]
        return [f.result() for f in futs]

    @property
    def meta(self) -> dict:
        step = self.latest_step()
        if step is None:
            return {}
        return fmt.load_manifest(self.dir / f"step_{step:08d}").get(
            "meta", {})
