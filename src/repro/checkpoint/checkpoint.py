"""Distributed checkpoint I/O: locality-owned shards as futurized tasks.

The byte-level format lives in ``format.py`` (DESIGN.md §10); this
module is the scheduling half.  ``CheckpointManager`` turns each save
into per-shard ``save_shard`` tasks placed on the locality that OWNS
the shard (``DistributedGraph.defer``; the driver is rank 0 and owns a
shard too), chained on the CHECKPOINT lane behind step retirement and
the previous save, so saves overlap training.  The manifest is built by
the driver only after every shard entry resolved and committed
atomically by rename - the driver no longer serializes or writes the
whole snapshot.

Properties the launcher relies on:
  * distributed save: each locality checksums, serializes, and writes
    the shards it owns; with one locality everything runs locally
    through the same format layer;
  * async save: the device->host transfer happens on the caller, every
    shard write is a ``Lane.CHECKPOINT`` graph node, so training
    continues while bytes hit disk;
  * failure model: a killed locality's shard tasks are idempotent and
    re-spawn on a survivor (or the driver), with the actual writer
    recorded in the manifest; if a save cannot complete, the manifest
    is never committed - the previous checkpoint stays latest, no torn
    state (paper R9);
  * resharded restore: shards are read by the CURRENT localities, which
    need not be the writers - a checkpoint written by N localities
    restores into M (M=1 included), with checksum verification
    (``CheckpointCorruptError`` names the bad shard);
  * elastic restore: leaves are ``device_put`` against the *current*
    mesh's shardings - a snapshot written on one mesh restores onto any
    other topology.
"""
from __future__ import annotations

import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from ..core.futures import FuturizedGraph, Lane, PhyFuture
from . import format as fmt
from .format import CheckpointCorruptError

__all__ = ["CheckpointCorruptError", "CheckpointManager"]


def _prepare_tmp(tmp: str, *_deps):
    """Dependency gate + clean slate.  Collapses the (step retirement,
    previous save) edges into one local node, so shard tasks ship no
    device values - and wipes a stale temp dir left by an aborted
    earlier attempt of the same step, so its files can never leak into
    this save's commit.  Runs strictly after the previous save's commit
    (saves chain), strictly before this save's shard writes (they
    depend on it)."""
    p = Path(tmp)
    if p.exists():
        shutil.rmtree(p)
    p.mkdir(parents=True)
    return None


class CheckpointManager:
    """Schedules checkpoint saves/restores over the futurized runtime.

    When ``graph`` is supplied (the Session-owned path: ``Session.train``
    passes its runtime), save nodes ride that graph and ``close()`` only
    drains pending writes - the graph's lifetime belongs to its owner.
    Standalone use spins up a private graph, shut down on ``close()``.
    Usable as a context manager either way.

    Args:
        directory: checkpoint root; one ``step_XXXXXXXX`` dir per save.
            Shared by every locality (same filesystem / shared mount).
        keep: committed checkpoints retained (older ones are GC'd).
        async_save: schedule writes as graph nodes (False runs saves
            inline on the caller, single-locality, for tests).
        graph: the ``FuturizedGraph`` save/commit nodes ride; private
            one created (and owned) when None.
        dgraph: a ``repro.distrib.DistributedGraph``; when given, shard
            tasks are placed on their owning localities and restores
            spread shard reads over the current localities.  Its local
            graph must be ``graph`` (futures cannot span graphs).
    Raises:
        ValueError: ``graph`` and ``dgraph.graph`` differ.
    """

    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = True,
                 graph: Optional[FuturizedGraph] = None,
                 dgraph: Optional[Any] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._dgraph = dgraph
        if dgraph is not None:
            if graph is not None and graph is not dgraph.graph:
                raise ValueError(
                    "graph and dgraph.graph must be the same "
                    "FuturizedGraph - distributed shard futures cannot "
                    "span graphs")
            self._own_graph = False
            self._graph = dgraph.graph
        else:
            self._own_graph = graph is None
            self._graph = graph if graph is not None else FuturizedGraph(
                max_workers=2, name="checkpoint")
        self._pending: Optional[PhyFuture] = None

    # -- placement ------------------------------------------------------------
    def ranks(self) -> list[int]:
        """Locality ranks owning a shard of the next save: the driver
        plus every alive worker (``[0]`` without a distributed graph)."""
        if self._dgraph is None:
            return [0]
        return [0] + self._dgraph.group.alive_workers()

    def _defer_on(self, rank: int, fn, *args, name: str, **kwargs):
        """One CHECKPOINT-lane task on ``rank`` (driver-local without a
        distributed graph); falls back to the driver if ``rank`` died
        between ``ranks()`` and this call."""
        if self._dgraph is None:
            return self._graph.defer(fn, *args, lane=Lane.CHECKPOINT,
                                     name=name, **kwargs)
        try:
            return self._dgraph.defer(fn, *args, lane=Lane.CHECKPOINT,
                                      name=name, locality=rank,
                                      idempotent=True, **kwargs)
        except ValueError:            # rank died since ranks(): retarget
            return self._dgraph.defer(fn, *args, lane=Lane.CHECKPOINT,
                                      name=name, locality=0,
                                      idempotent=True, **kwargs)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, *, meta: Optional[dict] = None,
             deps: tuple = ()):
        """Snapshot a pytree as locality-owned shards.

        Returns immediately when async: the tree is split into one shard
        per locality (``format.assign_shards``), each written by its
        owning locality as a ``Lane.CHECKPOINT`` task gated on ``deps``
        (e.g. the step-retirement future) and on the previous save
        (writes chain by dependency edge, never by blocking the caller);
        the driver commits the manifest only after every shard resolved.
        The device->host transfer stays synchronous: leaf buffers may be
        donated to the next dispatched step, so values are captured now.

        Fail fast: if the previous async save already finished with an
        error, raise it here rather than silently poisoning every later
        write in the dependency chain until close().

        Args:
            step: step number the snapshot belongs to.
            tree: the pytree to snapshot.
            meta: free-form metadata stored in the manifest.
            deps: futures the shard writes must wait for.
        Returns:
            The manifest-commit ``PhyFuture`` (resolving to the committed
            directory) when async; the committed ``Path`` when sync.
        """
        if self._pending is not None and self._pending.done():
            failed, self._pending = self._pending, None
            exc = failed.exception()
            if exc is not None:
                raise exc
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]
        treedef_str = str(treedef)
        shards = fmt.assign_shards(len(host), self.ranks())
        tmp = self.dir / f".tmp_step_{step:08d}"
        final = self.dir / f"step_{step:08d}"

        if not self.async_save:
            for d in deps:
                d.result()
            _prepare_tmp(str(tmp))
            entries = [fmt.save_shard(str(tmp), sid, idx,
                                      [host[i] for i in idx])
                       for sid, _rank, idx in shards]
            return self._commit(step, treedef_str, len(host), meta,
                                str(tmp), str(final), *entries)

        order = deps if self._pending is None else (*deps, self._pending)
        gate = self._graph.defer(_prepare_tmp, str(tmp), *order,
                                 lane=Lane.CHECKPOINT,
                                 name=f"ckpt:gate:{step}")
        entry_futs = [
            self._defer_on(rank, fmt.save_shard, str(tmp), sid,
                           list(idx), [host[i] for i in idx], gate,
                           name=f"ckpt:shard{sid}:{step}")
            for sid, rank, idx in shards]
        self._pending = self._graph.defer(
            self._commit, step, treedef_str, len(host), meta,
            str(tmp), str(final), *entry_futs,
            lane=Lane.CHECKPOINT, name=f"ckpt:manifest:{step}")
        return self._pending

    def _commit(self, step, treedef_str, n_leaves, meta, tmp, final,
                *entries) -> Path:
        manifest = fmt.build_manifest(step=step, treedef=treedef_str,
                                      n_leaves=n_leaves,
                                      shards=list(entries), meta=meta)
        out = fmt.commit_manifest(Path(tmp), Path(final), manifest)
        self._gc()
        return out

    def wait(self):
        """Barrier: block until every pending save has committed."""
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def close(self):
        """Shutdown barrier: drain pending saves; stop our workers if we
        own the graph (shared runtimes are shut down by their owner)."""
        self.wait()
        if self._own_graph:
            self._graph.shutdown(wait=True)

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc):
        self.close()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
        latest = steps[-1] if steps else None
        # temp dirs of aborted or superseded saves are garbage once a
        # same-or-later step has committed
        for p in self.dir.glob(".tmp_step_*"):
            try:
                s = int(p.name.rsplit("_", 1)[1])
            except ValueError:
                continue
            if latest is not None and s <= latest:
                shutil.rmtree(p, ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Any = None, strict_checksums: bool = True):
        """Load a pytree with the structure of ``like``.

        Shards are read by the CURRENT localities (spread round-robin
        over the driver + alive workers), which need not be the writers:
        a checkpoint written by N localities restores into M, including
        M=1.  Leaves are ``device_put`` against ``shardings`` (same
        structure) for elastic mesh restore.

        Args:
            like: pytree giving the structure (and leaf count) expected.
            step: step to load; latest when None.
            shardings: optional shardings pytree for ``device_put``.
            strict_checksums: verify per-leaf + per-shard checksums.
        Returns:
            ``(step, tree)``.
        Raises:
            FileNotFoundError: no checkpoint under the directory.
            ValueError: leaf count does not match ``like``.
            CheckpointCorruptError: a shard is missing, truncated, or
                fails checksum verification (the message names it).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = fmt.load_manifest(d)
        leaves_like, treedef = jax.tree.flatten(like)
        if manifest["n_leaves"] != len(leaves_like):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"expected {len(leaves_like)}")
        by_index: dict[int, np.ndarray] = {}
        for part in self._read_shards(d, manifest["shards"],
                                      strict_checksums):
            by_index.update(part)
        missing = [i for i in range(len(leaves_like)) if i not in by_index]
        if missing:
            raise CheckpointCorruptError(
                f"{d}: leaves {missing} missing from every shard")
        sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                     else [None] * len(leaves_like))
        out = [jax.device_put(by_index[i], sh) if sh is not None
               else jax.numpy.asarray(by_index[i])
               for i, sh in enumerate(sh_leaves)]
        return step, jax.tree.unflatten(treedef, out)

    def _read_shards(self, d: Path, entries: list, verify: bool) -> list:
        ranks = self.ranks()
        if self._dgraph is None or len(ranks) == 1:
            return [fmt.read_shard(str(d), e, verify=verify)
                    for e in entries]
        futs = [self._defer_on(ranks[i % len(ranks)], fmt.read_shard,
                               str(d), e, verify=verify,
                               name=f"ckpt:load:{e['file']}")
                for i, e in enumerate(entries)]
        return [f.result() for f in futs]

    @property
    def meta(self) -> dict:
        step = self.latest_step()
        if step is None:
            return {}
        return fmt.load_manifest(self.dir / f"step_{step:08d}").get(
            "meta", {})
