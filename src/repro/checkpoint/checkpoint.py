"""Checkpointing: sharded, checksummed, asynchronous, mesh-elastic.

Layout (one directory per step):
    <dir>/step_000120/
        manifest.json      tree structure, shapes/dtypes, blake2b checksums
        arr_00000.npy ...  one file per leaf

Properties the launcher relies on:
  * checksums: every leaf is hashed at save and verified at restore -
    silent-corruption of a checkpoint is detected, not loaded (paper R9);
  * async save: the device->host transfer happens on the caller, the file
    I/O in a background thread (core.futures), so training continues while
    bytes hit disk;
  * elastic restore: leaves are ``device_put`` against the *current* mesh's
    shardings - a checkpoint written on one mesh restores onto any other
    (different device count / topology), which is the restart path for both
    node failure and elastic rescaling;
  * atomicity: writes go to ``<dir>/.tmp_step_X`` and are renamed only when
    complete, so a crash mid-save never corrupts the latest checkpoint.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from ..core.futures import FuturizedGraph, Lane, PhyFuture


def _checksum(a: np.ndarray) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


class CheckpointManager:
    """When ``graph`` is supplied (the Session-owned path: ``Session.train``
    passes its runtime), save nodes ride that graph and ``close()`` only
    drains pending writes - the graph's lifetime belongs to its owner.
    Standalone use spins up a private graph, shut down on ``close()``.
    Usable as a context manager either way."""

    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = True,
                 graph: Optional[FuturizedGraph] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._own_graph = graph is None
        self._graph = graph if graph is not None else FuturizedGraph(
            max_workers=2, name="checkpoint")
        self._pending: Optional[PhyFuture] = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, *, meta: Optional[dict] = None,
             deps: tuple = ()):
        """Snapshot a pytree.  Returns immediately when async: the file I/O
        becomes a ``Lane.CHECKPOINT`` graph node that runs after ``deps``
        (e.g. the step-retirement future) and after the previous save (writes
        chain by dependency edge, never by blocking the caller).  The
        device->host transfer stays synchronous: leaf buffers may be donated
        to the next dispatched step, so values must be captured now.

        Fail fast: if the previous async save already finished with an
        error, raise it here rather than silently poisoning every later
        write in the dependency chain until close()."""
        if self._pending is not None and self._pending.done():
            failed, self._pending = self._pending, None
            exc = failed.exception()
            if exc is not None:
                raise exc
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]
        treedef_str = str(treedef)

        def _write(*_deps):
            tmp = self.dir / f".tmp_step_{step:08d}"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            entries = []
            for i, a in enumerate(host):
                name = f"arr_{i:05d}.npy"
                np.save(tmp / name, a)
                entries.append({"file": name, "shape": list(a.shape),
                                "dtype": str(a.dtype),
                                "checksum": _checksum(a)})
            manifest = {"step": step, "treedef": treedef_str,
                        "n_leaves": len(host), "entries": entries,
                        "meta": meta or {},
                        "saved_at": time.strftime("%Y-%m-%d %H:%M:%S")}
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()
            return final

        if self.async_save:
            order = deps if self._pending is None else (*deps, self._pending)
            self._pending = self._graph.defer(
                _write, *order, lane=Lane.CHECKPOINT, name=f"ckpt:{step}")
            return self._pending
        for d in deps:
            d.result()
        return _write()

    def wait(self):
        """Barrier: block until every pending save has hit disk."""
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def close(self):
        """Shutdown barrier: drain pending saves; stop our workers if we
        own the graph (shared runtimes are shut down by their owner)."""
        self.wait()
        if self._own_graph:
            self._graph.shutdown(wait=True)

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc):
        self.close()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Any = None, strict_checksums: bool = True):
        """Load a pytree with the structure of ``like``; device_put against
        ``shardings`` (same structure) for elastic mesh restore.
        Returns (step, tree)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves_like, treedef = jax.tree.flatten(like)
        if manifest["n_leaves"] != len(leaves_like):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"expected {len(leaves_like)}")
        sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                     else [None] * len(leaves_like))
        out = []
        for i, (entry, sh) in enumerate(zip(manifest["entries"], sh_leaves)):
            a = np.load(d / entry["file"])
            if strict_checksums and _checksum(a) != entry["checksum"]:
                raise IOError(
                    f"checksum mismatch in {d / entry['file']} - refusing "
                    f"to load a corrupt checkpoint (leaf {i})")
            out.append(jax.device_put(a, sh) if sh is not None
                       else jax.numpy.asarray(a))
        return step, jax.tree.unflatten(treedef, out)

    @property
    def meta(self) -> dict:
        step = self.latest_step()
        if step is None:
            return {}
        d = self.dir / f"step_{step:08d}"
        return json.loads((d / "manifest.json").read_text()).get("meta", {})
