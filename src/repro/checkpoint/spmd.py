"""SPMD checkpointing: addressable-shard serialization (DESIGN.md §10).

Under true multi-host SPMD (``jax.distributed`` active, one process per
host) a global ``jax.Array`` spans processes and no single host can -
or should - materialize it: each host persists exactly the blocks it
can address.  This module is that save path:

  * ``global_view`` lifts a train-state pytree into *global* arrays on
    a persistence mesh spanning every process's devices.  Leaves that
    already are global arrays pass through untouched (the real
    multi-chip case); host-local leaves - the CPU CI case, where each
    process holds an identical full copy from lockstep compute - are
    wrapped via ``jax.make_array_from_callback``, which materializes
    only this process's addressable shards.
  * ``collect_segments`` enumerates ``addressable_shards`` of every
    leaf and keeps exactly the blocks this process must write: one
    segment per distinct device shard with ``replica_id == 0``, so a
    replicated leaf is written once (by the process holding replica 0)
    and a sharded leaf is partitioned bit-exactly across hosts with no
    overlap.
  * ``write_spmd_shard`` streams those segments into this process's
    shard file through the ordinary format layer (``format.save_shard``
    with ``slices``); only the returned manifest *entry* - offsets,
    shapes, checksums: metadata - ever crosses the messaging layer.
    The leaf bytes themselves never do, which
    ``DistributedGraph.stats()["ckpt_leaf_wire_bytes"]`` proves.

Restore needs no new machinery: segments carry the global leaf slice
they hold, ``format.assemble_leaf`` re-joins them on any process count
(N->M, M=1 included), and ``device_put_maybe_global`` places a leaf
against a cross-process sharding without round-tripping through a
single host.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from . import format as fmt

__all__ = ["CKPT_AXIS", "addressable_segments", "collect_segments",
           "device_put_maybe_global", "global_view", "is_multiprocess",
           "persistence_mesh", "persistence_sharding", "write_spmd_shard"]

CKPT_AXIS = "ckpt"


def is_multiprocess() -> bool:
    """True when this process is part of a ``jax.distributed`` world
    (``jax.process_count() > 1``) - the gate for the SPMD save path."""
    try:
        return jax.process_count() > 1
    except RuntimeError:  # pragma: no cover - backend not initializable
        return False


def persistence_mesh() -> Mesh:
    """A 1-D mesh with ONE device per process, used only to define the
    persistence shardings of ``global_view`` - no computation ever runs
    on it (multi-process computations need a real multi-host target).

    One device per process, not all devices: what SPMD persistence
    distributes is the per-HOST byte load, and a leading axis divides
    the (small) process count far more often than the full device
    count, so more leaves split and the shard files balance.  Leaves
    that already are global arrays keep their own (per-device)
    shardings - this mesh never sees them.
    """
    by_proc: dict[int, Any] = {}
    for d in jax.devices():
        by_proc.setdefault(d.process_index, d)
    return Mesh(np.array([by_proc[k] for k in sorted(by_proc)]),
                (CKPT_AXIS,))


def persistence_sharding(mesh: Mesh, shape) -> NamedSharding:
    """The sharding a leaf is persisted under: split the leading axis
    over every device when it divides evenly, replicate otherwise.

    Replicated leaves cost nothing extra: only the process holding
    replica 0 writes them (``collect_segments``).
    """
    n = mesh.shape[CKPT_AXIS]
    if len(shape) >= 1 and shape[0] >= n and shape[0] % n == 0:
        return NamedSharding(mesh, PartitionSpec(CKPT_AXIS))
    return NamedSharding(mesh, PartitionSpec())


def global_view(tree: Any, mesh: Optional[Mesh] = None) -> Any:
    """The persistence view of a train-state pytree: every leaf as a
    global array whose ``addressable_shards`` name exactly what this
    process must write.

    Leaves that are already global (not fully addressable) pass through
    - their run-time sharding IS the persistence layout.  Host-local
    leaves are wrapped against ``persistence_sharding``; the callback
    slices this process's full local copy, so only addressable blocks
    are materialized.

    Args:
        tree: pytree of jax arrays / numpy arrays / scalars.
        mesh: persistence mesh (defaults to ``persistence_mesh()``).
    Returns:
        A pytree of global ``jax.Array`` leaves (same structure).
    """
    mesh = mesh if mesh is not None else persistence_mesh()

    def wrap(leaf):
        if isinstance(leaf, jax.Array) and not leaf.is_fully_addressable:
            return leaf
        host = np.asarray(leaf)
        sh = persistence_sharding(mesh, host.shape)
        return jax.make_array_from_callback(host.shape, sh,
                                            lambda idx: host[idx])

    return jax.tree.map(wrap, tree)


def _normalize_index(index, shape):
    """A ``Shard.index`` (tuple of slices) -> ``[[start, stop], ...]``,
    or None when it covers the whole leaf (stored as a plain whole-leaf
    segment)."""
    pairs, full = [], True
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = int(dim) if sl.stop is None else int(sl.stop)
        pairs.append((start, stop))
        full = full and start == 0 and stop == int(dim)
    return None if full else pairs


def addressable_segments(garr: jax.Array) -> list:
    """The blocks of one global array THIS process must persist.

    One entry per addressable device shard with ``replica_id == 0`` -
    the canonical copy of each distinct block - so the union over all
    processes covers the array exactly once.

    Args:
        garr: a (possibly global) ``jax.Array``.
    Returns:
        List of ``(slice_pairs_or_None, global_shape, host_array)``.
    """
    shape = garr.shape
    out = []
    for s in garr.addressable_shards:
        if s.replica_id != 0:
            continue
        out.append((_normalize_index(s.index, shape), list(shape),
                    np.asarray(s.data)))
    return out


def collect_segments(tree: Any, mesh: Optional[Mesh] = None) -> tuple:
    """Flatten ``tree`` into this process's segment lists, ready for
    ``format.save_shard``.

    Synchronous on purpose: the host copies are captured NOW, before
    the caller's next step can donate the buffers.

    Args:
        tree: train-state pytree (lifted via ``global_view`` first).
        mesh: persistence mesh override.
    Returns:
        ``(indices, slices, arrays)`` - parallel lists; ``slices[i]``
        is None for a whole leaf or ``(slice_pairs, global_shape)``.
    """
    leaves = jax.tree.leaves(global_view(tree, mesh))
    indices, slices, arrays = [], [], []
    for i, leaf in enumerate(leaves):
        for pairs, gshape, arr in addressable_segments(leaf):
            indices.append(i)
            slices.append(None if pairs is None else (pairs, gshape))
            arrays.append(arr)
    return indices, slices, arrays


def write_spmd_shard(directory: str, shard_id: int, tree: Any) -> Optional[dict]:
    """Persist this process's addressable shards of ``tree`` as one
    shard file (``shard_id`` = the process rank) and return its manifest
    entry - the only thing that ships to the driver.

    Args:
        directory: the temporary step directory (shared filesystem).
        shard_id: this process's rank (shard ids mirror ranks in SPMD
            mode).
        tree: the train-state pytree.
    Returns:
        The ``format.save_shard`` entry, or None when this process
        addresses no replica-0 block of any leaf (nothing to write).
    """
    indices, slices, arrays = collect_segments(tree)
    if not indices:
        return None
    return fmt.save_shard(directory, shard_id, indices, arrays,
                          slices=slices)


def device_put_maybe_global(host: np.ndarray, sharding) -> jax.Array:
    """Place a restored host leaf against a sharding that may span
    processes: a plain ``device_put`` when fully addressable, a
    ``make_array_from_callback`` (each process materializes only its
    blocks) otherwise.
    """
    if sharding is None:
        return jax.numpy.asarray(host)
    if getattr(sharding, "is_fully_addressable", True):
        return jax.device_put(host, sharding)
    return jax.make_array_from_callback(host.shape, sharding,
                                        lambda idx: host[idx])
