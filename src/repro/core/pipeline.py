"""GPipe-style pipeline parallelism over a mesh axis (paper R2).

The model's layer stack is split into S contiguous stages, one per rank of
the pipeline mesh axis.  Microbatches flow through a static schedule of
S + M - 1 ticks; at each tick every stage computes its resident microbatch
and hands the activation to the next stage with ``collective_permute``
(core.collectives.pipeline_shift).  The schedule is expressed as a
``lax.scan`` over ticks inside ``shard_map``, so reverse-mode autodiff
derives the backward pipeline automatically (ppermute transposes to the
reverse shift) - 1F1B-ish interleaving falls out of XLA's scheduler rather
than being hand-written, which is the paper's "constraint-based
synchronization" idea applied to pipelining.

Bubble fraction: (S - 1) / (M + S - 1) - reported by ``bubble_fraction`` and
validated in tests.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from . import collectives, compat


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe_apply(stage_fn: Callable, stage_params, x_micro, *,
                axis: str = "stage"):
    """Run microbatches through the pipeline (call inside shard_map).

    stage_fn(params_for_stage, x) -> y     applied by every stage
    stage_params: this rank's stage parameters (already sharded by caller)
    x_micro: [M, mb, ...] microbatched inputs (replicated across stages;
             only stage 0 injects them)
    returns [M, mb, ...] outputs as produced by the last stage (replicated
    via the closing broadcast from the last stage).
    """
    S = compat.axis_size(axis)
    sid = lax.axis_index(axis)
    M = x_micro.shape[0]
    T = M + S - 1
    mb_shape = x_micro.shape[1:]

    def tick(carry, t):
        state, outs = carry           # state: activation resident here
        # stage 0 injects microbatch t (if any left)
        inject = jnp.where(t < M, t, 0)
        x_in = x_micro[inject]
        state = jnp.where(sid == 0, x_in, state)
        valid = (t - sid >= 0) & (t - sid < M)
        y = stage_fn(stage_params, state)
        y = jnp.where(valid, y, state)
        # last stage records its finished microbatch
        out_idx = jnp.where(t - (S - 1) >= 0, t - (S - 1), 0)
        done = (sid == S - 1) & (t - (S - 1) >= 0) & (t - (S - 1) < M)
        outs = lax.dynamic_update_index_in_dim(
            outs, jnp.where(done, y, outs[out_idx]), out_idx, 0)
        # hand activations downstream
        state = collectives.pipeline_shift(y, axis)
        return (state, outs), None

    state0 = jnp.zeros(mb_shape, x_micro.dtype)
    outs0 = jnp.zeros((M,) + mb_shape, x_micro.dtype)
    (_, outs), _ = lax.scan(tick, (state0, outs0), jnp.arange(T))
    # broadcast the last stage's outputs to every rank (psum of one-hot)
    outs = lax.psum(jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)),
                    axis)
    return outs


def make_pipeline_fn(stage_fn: Callable, mesh, *, axis: str = "stage",
                     param_spec=None, out_replicated: bool = True):
    """Wrap gpipe_apply in shard_map. stage params enter sharded on dim 0
    (one slice per stage)."""
    from jax.sharding import PartitionSpec as P

    def body(stacked_params, x_micro):
        my = jax.tree.map(lambda p: p[0], stacked_params)  # local slice
        return gpipe_apply(stage_fn, my, x_micro, axis=axis)

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(param_spec if param_spec is not None else P(axis), P()),
        out_specs=P(),
        axis_names={axis}, check_vma=False)
