"""HLO-text analysis: collective inventory + wire-byte model for §Roofline.

``cost_analysis()`` has no collective-byte entry, so we parse the compiled
module text, find every collective instruction, take its payload bytes from
the printed result shape, and convert to *wire bytes per device* with ring-
algorithm factors over the parsed replica-group size g:

  all-reduce         2 * s * (g-1) / g      (s = payload bytes)
  all-gather         s * (g-1) / g          (s = gathered/output bytes)
  reduce-scatter     s * (g-1) / g          (s = input bytes = out*g)
  all-to-all         s * (g-1) / g          (s = payload bytes)
  collective-permute s
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DT_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict                 # HLO ops (post XLA combining)
    operands: dict               # logical launches (variadic operands)
    payload_bytes: dict          # sum of result-shape bytes per op kind
    wire_bytes: dict             # ring-model wire bytes per device per kind

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.counts.values()))

    def to_json(self) -> dict:
        return {"counts": dict(self.counts),
                "operands": dict(self.operands),
                "payload_bytes": {k: float(v) for k, v in self.payload_bytes.items()},
                "wire_bytes": {k: float(v) for k, v in self.wire_bytes.items()},
                "total_wire_bytes": self.total_wire_bytes}


def analyze_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    counts: dict = defaultdict(int)
    operands: dict = defaultdict(int)
    payload: dict = defaultdict(float)
    wire: dict = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        s = _shape_bytes(shape_str)
        if s == 0:
            continue
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        counts[op] += 1
        operands[op] += max(shape_str.count("["), 1)
        payload[op] += s
        if op == "all-reduce":
            w = 2 * s * (g - 1) / g
        elif op == "all-gather":
            w = s * (g - 1) / g
        elif op == "reduce-scatter":
            w = s * (g - 1)          # printed shape is the scattered output
        elif op == "all-to-all":
            w = s * (g - 1) / g
        else:                         # collective-permute
            w = s
        wire[op] += w
    return CollectiveStats(dict(counts), dict(operands), dict(payload), dict(wire))


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
