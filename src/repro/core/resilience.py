"""Software resilience: replay / replicate+consensus / checksums (paper R9).

The paper (§4.1) describes HPX's resilience model for silent data corruption
(SDC): after a suspect computation the user may (1) *replay* it and keep the
result if the corruption vanished, or (2) run *replicates* compared by
(a) checksums, (b) a consensus function, or (c) a validate function.  We
implement exactly that API over JAX step functions, plus the checkpoint
checksums used by restart-based fault tolerance (node loss).

SDC cannot be produced on demand, so tests inject faults through the
``fault_hook`` seam - the detection/recovery logic is identical either way.

Across process boundaries the same primitives back the multi-locality
runtime (DESIGN.md §9): ``repro.distrib.DistributedGraph.replicate`` runs
replicas on *distinct localities* and votes with ``tree_checksum``, and a
killed locality's idempotent tasks are re-spawned on survivors - replay,
at the placement layer.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Checksums
# ---------------------------------------------------------------------------
def tree_checksum(tree) -> str:
    """Deterministic content hash of a pytree of arrays (bitwise)."""
    h = hashlib.blake2b(digest_size=16)
    for leaf in jax.tree.leaves(tree):
        a = np.asarray(leaf)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def finite_check(tree) -> bool:
    """Cheap on-device validity predicate: every leaf is finite."""
    leaves = jax.tree.leaves(tree)
    ok = jnp.array(True)
    for leaf in leaves:
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            ok = ok & jnp.isfinite(leaf).all()
    return bool(ok)


# ---------------------------------------------------------------------------
# Replay & replicate
# ---------------------------------------------------------------------------
class ResilienceError(RuntimeError):
    pass


@dataclasses.dataclass
class ResilientRunner:
    """Wrap a (pure) step function with HPX-style resilience semantics.

    validate: result -> bool         (reject corrupt results, default finite)
    consensus: [results] -> result   (pick among replicates; default checksum
                                      majority, ties broken by validate)
    fault_hook: result -> result     (test seam to inject corruption)
    """

    fn: Callable
    validate: Callable[[Any], bool] = finite_check
    consensus: Optional[Callable[[Sequence[Any]], Any]] = None
    fault_hook: Optional[Callable[[Any], Any]] = None
    stats: dict = dataclasses.field(
        default_factory=lambda: {"replays": 0, "replicas": 0, "rejected": 0})

    def _run_once(self, *args, **kwargs):
        out = self.fn(*args, **kwargs)
        if self.fault_hook is not None:
            out = self.fault_hook(out)
        return out

    def replay(self, *args, max_retries: int = 3, **kwargs):
        """HPX task replay: rerun until the result validates."""
        for attempt in range(max_retries + 1):
            out = self._run_once(*args, **kwargs)
            if self.validate(out):
                return out
            self.stats["replays"] += 1
            self.stats["rejected"] += 1
        raise ResilienceError(
            f"replay failed after {max_retries + 1} attempts")

    def replicate(self, *args, n: int = 3, **kwargs):
        """HPX task replication with checksum/consensus/validate selection."""
        results = [self._run_once(*args, **kwargs) for _ in range(n)]
        self.stats["replicas"] += n
        if self.consensus is not None:
            return self.consensus(results)
        # default: checksum majority vote
        sums = [tree_checksum(r) for r in results]
        counts: dict[str, int] = {}
        for s in sums:
            counts[s] = counts.get(s, 0) + 1
        best, votes = max(counts.items(), key=lambda kv: kv[1])
        if votes > 1:
            return results[sums.index(best)]
        # no agreement: fall back to the validate function (HPX case (c))
        for r in results:
            if self.validate(r):
                return r
        raise ResilienceError("no replicate passed validation")


# ---------------------------------------------------------------------------
# Straggler mitigation policy (advisory; realized by the launcher)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    """Synchronous updates + asynchronous collectives (the paper's position,
    R7) plus bounded local accumulation as an explicit escape hatch.

    accumulate_local_steps > 1 behaves like PyTorch-DDP ``no_sync``: workers
    skip the gradient collective for k-1 steps and reduce the accumulated
    gradient on step k, trading gradient freshness for straggler tolerance
    without an asynchronous solver (which the paper rejects - low statistical
    efficiency of ASGD).
    """
    accumulate_local_steps: int = 1
    backup_worker_fraction: float = 0.0   # drop slowest f of DP groups (doc'd)

    def sync_this_step(self, step: int) -> bool:
        return (step + 1) % self.accumulate_local_steps == 0
