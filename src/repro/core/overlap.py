"""Communication/computation overlap strategies for data parallelism (R3).

The gradient exchange + solver update, expressed *explicitly* inside the
framework (unified, R6) as code over manual data-parallel mesh axes:

  * ``horovod`` - the paper's Fig.-1 baseline: one all-reduce per gradient
    tensor, dense solver states.  No fusion; collective launch count equals
    the tensor count.
  * ``phylanx`` - the paper-faithful strategy: gradients coalesced into
    runtime-adaptively capped fusion buckets (R5), one asynchronous
    all-reduce per bucket; XLA's latency-hiding scheduler can start each
    bucket's collective as soon as its last gradient is produced.
  * ``zero1``   - beyond-paper: the same fusion buckets, but reduce-scattered
    so each rank owns (and keeps solver state for) 1/N of every bucket;
    updated shards are all-gathered back.  Wire bytes per step match
    all-reduce, solver memory drops by the DP degree.

All three run inside ``jax.shard_map(..., axis_names=dp_axes)`` bodies, so
the collectives here are real lax collectives the scheduler can overlap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import compat, fusion
from ..optim import optimizers as optim


def dp_axis_size(dp_axes) -> jax.Array:
    n = 1
    for a in dp_axes:
        n = n * compat.axis_size(a)
    return n


def exchange_horovod(grads, dp_axes):
    """Per-tensor blocking-style all-reduce mean (Fig. 1 baseline)."""
    return jax.tree.map(lambda g: lax.pmean(g, dp_axes), grads)


def exchange_phylanx(grads, dp_axes, bucket_bytes: int,
                     fuse_mask=None):
    """Fused-bucket asynchronous all-reduce mean (paper-faithful).

    fuse_mask: per-leaf bool tree - True for tensors safe to coalesce.
    Tensor-parallel-sharded gradients must NOT be flattened into shared
    buckets (ravel+concat of differently-sharded arrays forces the SPMD
    partitioner to all-gather them to replicated - measured at 253 GB/step
    wire on chameleon-34b, §Perf iteration A2).  Those go through per-tensor
    all-reduce, which partitions cleanly; the paper's tensor-fusion win is
    for the many SMALL (replicated) tensors anyway.
    """
    leaves, treedef = jax.tree.flatten(grads)
    if fuse_mask is None:
        mask = [True] * len(leaves)
    else:
        mask = jax.tree.leaves(fuse_mask)
    fusable = [g for g, m in zip(leaves, mask) if m]
    out = list(leaves)
    if fusable:
        plan = fusion.make_plan(fusable, bucket_bytes)
        bufs = [lax.pmean(b, dp_axes) for b in fusion.pack(fusable, plan)]
        fused_out = jax.tree.leaves(fusion.unpack(bufs, plan))
        it = iter(fused_out)
        out = [next(it) if m else g for g, m in zip(leaves, mask)]
    out = [g if m else lax.pmean(g, dp_axes)
           for g, m in zip(out, mask)]
    return jax.tree.unflatten(treedef, out)


def dense_update(grads, opt_state, params, oc, dp_axes, *,
                 strategy: str, bucket_bytes: int):
    if strategy == "horovod":
        grads = exchange_horovod(grads, dp_axes)
    else:
        grads = exchange_phylanx(grads, dp_axes, bucket_bytes)
    return optim.update(grads, opt_state, params, oc)


# ---------------------------------------------------------------------------
# ZeRO-1 (per-tensor): reduce-scatter grads along dim0 -> sharded solver ->
# all-gather updated params.  Per-tensor rather than flat-bucket, because
# flattening TP-sharded tensors into shared buckets de-shards them (§Perf
# iteration A2).  A leaf is scattered when its dim0 divides the dp degree
# and is not already claimed by the model axis; small/ragged leaves keep a
# dense (replicated) solver state - they are a tiny fraction of memory.
# ---------------------------------------------------------------------------
def zero1_scatter_mask(param_specs, mesh, rules, ndp: int,
                       min_size: int = 1 << 14):
    """Per-leaf bool tree: True -> solver state sharded over dp on dim0."""
    from .sharding import ParamSpec, spec_for

    def decide(s: ParamSpec) -> bool:
        if compat.NEEDS_DP_OPERAND_REPLICATION:
            # old jax: the scatter path's collectives hit partial-manual
            # partitioner bugs; fall back to dense (identical math, no
            # solver-memory sharding)
            return False
        if not s.shape or s.shape[0] % max(ndp, 1) or s.size < min_size:
            return False
        pspec = spec_for(mesh, rules, s.shape, s.dims)
        dim0_free = len(pspec) == 0 or pspec[0] is None
        return bool(dim0_free and ndp > 1)

    return jax.tree.map(decide, param_specs,
                        is_leaf=lambda x: hasattr(x, "dims"))


def zero1_init_state(param_specs, scatter_mask, ndp: int):
    """GLOBAL shapes (the step's in_specs shard dim0 over dp)."""
    from .sharding import ParamSpec

    def mk(s, sc):
        return jnp.zeros(s.shape, jnp.float32)

    zeros = jax.tree.map(mk, param_specs, scatter_mask,
                         is_leaf=lambda x: hasattr(x, "dims"))
    return {"count": jnp.zeros((), jnp.int32), "m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros)}


def zero1_state_shard_specs(scatter_mask, dp_axes):
    """shard_map in_specs for the zero1 state (dim0 over dp when scattered)."""
    from jax.sharding import PartitionSpec as P
    axes = tuple(dp_axes)
    leaf = lambda sc: P(axes) if sc else P()
    per = jax.tree.map(leaf, scatter_mask)
    return {"count": P(), "m": per, "v": jax.tree.map(leaf, scatter_mask)}


def zero1_update(grads, opt_state, params, oc, dp_axes, scatter_mask):
    """Inside shard_map: grads/params replicated over dp; scattered m/v
    enter as local dim0 shards."""
    axes = tuple(dp_axes)
    ndp = dp_axis_size(dp_axes)
    count = opt_state["count"] + 1

    g_leaves, treedef = jax.tree.flatten(grads)
    p_leaves = jax.tree.leaves(params)
    m_leaves = jax.tree.leaves(opt_state["m"])
    v_leaves = jax.tree.leaves(opt_state["v"])
    mask = jax.tree.leaves(scatter_mask)

    # phase 1: reduce (scatter when possible) + exact global grad norm
    reduced = []
    sq_scattered = jnp.zeros((), jnp.float32)
    sq_dense = jnp.zeros((), jnp.float32)
    for g, sc in zip(g_leaves, mask):
        if sc:
            g_sh = lax.psum_scatter(g.astype(jnp.float32), axes,
                                    scatter_dimension=0, tiled=True) / ndp
            sq_scattered += jnp.sum(jnp.square(g_sh))
            reduced.append(g_sh)
        else:
            g_r = lax.pmean(g.astype(jnp.float32), axes)
            sq_dense += jnp.sum(jnp.square(g_r))
            reduced.append(g_r)
    gn = jnp.sqrt(lax.psum(sq_scattered, axes) + sq_dense)
    clip = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gn, 1e-9))

    # phase 2: solver on shards; all-gather updated params
    new_p, new_m, new_v = [], [], []
    for g_r, p, m, v, sc in zip(reduced, p_leaves, m_leaves, v_leaves, mask):
        if sc:
            # never reached on old jax (zero1_scatter_mask gates the
            # scatter path off there), so axis_index only traces where
            # the partitioner supports it
            shard = m.shape[0]
            rank = lax.axis_index(axes)
            p_sh = lax.dynamic_slice_in_dim(
                p.astype(jnp.float32), rank * shard, shard, axis=0)
            p2, m2, v2 = optim.zero1_shard_update(g_r, p_sh, m, v, count, oc,
                                                  clip)
            p2 = compat.all_gather(p2, axes, axis=0, tiled=True)
        else:
            p2, m2, v2 = optim.zero1_shard_update(
                g_r, p.astype(jnp.float32), m, v, count, oc, clip)
        new_p.append(p2.astype(p.dtype))
        new_m.append(m2)
        new_v.append(v2)
    params = jax.tree.unflatten(treedef, new_p)
    state = {"count": count, "m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v)}
    return params, state, {"grad_norm": gn}
