"""Paged inference cache: a slot-free page-pool over per-request decode
state.

``Session.serve`` (the fixed-wave loop) rebuilds a request's KV/conv/SSM
decode state from scratch whenever a slot is refilled: the wave barrier
throws the state away and the next prefill recomputes it.  The serving
gateway (``frontend/gateway.py``, DESIGN.md §14) instead prefills a
request *once*, at admission, and parks the resulting per-request state
here until a batch slot frees up - retire-and-refill then *loads* pages
instead of recomputing prefill.

Two layers, both host-side and framework-free (NumPy only):

  * ``PagePool`` - a fixed-page-size byte allocator.  Pages are uniform
    ``np.uint8`` blocks, the free list is LIFO so freed pages are reused
    before the pool grows, every live page has exactly one owner, and
    pages are zero-scrubbed on allocation so a recycled page can never
    leak a previous request's state.
  * ``InferenceCache`` - maps a request id to the pages holding its
    serialized decode-state pytree (the ``InferenceCache(conv_state,
    ssm_state)`` shape from the Mamba serving stacks, generalized to any
    state pytree: KV caches, mamba conv+ssm, xLSTM recurrent state).
    ``put`` flattens the pytree and spills the leaf bytes across pages;
    ``get`` reassembles a bit-identical pytree; ``drop`` reclaims.

Page accounting invariants (property-tested in tests/test_property.py):
no page is ever owned by two live requests, freed pages are reused before
the pool grows, and a put→drop→put cycle never leaks stale bytes into the
new request.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Iterator, Optional

import numpy as np

__all__ = ["InferenceCache", "PagePool", "PageError"]


class PageError(RuntimeError):
    """Page-accounting violation: double free, foreign page, unknown id."""


@dataclasses.dataclass
class _Entry:
    """One cached request: its pages plus the template to rebuild the
    pytree (leaf shapes/dtypes in flatten order and the treedef)."""
    pages: list[int]
    nbytes: int
    shapes: list[tuple]
    dtypes: list[Any]
    treedef: Any


class PagePool:
    """Fixed-size byte pages with single-owner accounting.

    The pool starts empty and grows on demand; it never shrinks (pages are
    cheap host memory and reuse is the point).  All methods are
    thread-safe - gateway node bodies allocate/free from worker threads.

    Args:
        page_bytes: size of every page in bytes (>= 1).
    """

    def __init__(self, page_bytes: int = 1 << 16):
        if page_bytes < 1:
            raise ValueError(f"page_bytes must be >= 1, got {page_bytes}")
        self.page_bytes = int(page_bytes)
        self._lock = threading.Lock()
        self._pages: list[np.ndarray] = []      # page id -> buffer
        self._free: list[int] = []              # LIFO: reuse before grow
        self._owner: dict[int, str] = {}        # live page id -> owner
        self.allocs = 0      # pages handed out
        self.frees = 0       # pages returned
        self.grown = 0       # pages created (pool size)
        self.reused = 0      # allocations served from the free list
        self.peak_live = 0   # high-water mark of live pages

    # -- allocation ---------------------------------------------------------
    def alloc(self, owner: str, n: int = 1) -> list[int]:
        """Allocate ``n`` zero-scrubbed pages owned by ``owner``.

        Args:
            owner: non-empty tag recorded as the pages' single owner.
            n: page count (>= 0; 0 returns ``[]``).
        Returns:
            The allocated page ids, free-list pages first.
        """
        if not owner:
            raise ValueError("pages must have a non-empty owner")
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        out: list[int] = []
        with self._lock:
            for _ in range(n):
                if self._free:
                    pid = self._free.pop()      # LIFO reuse
                    self._pages[pid][:] = 0     # scrub: no stale bytes
                    self.reused += 1
                else:
                    pid = len(self._pages)
                    self._pages.append(np.zeros(self.page_bytes, np.uint8))
                    self.grown += 1
                self._owner[pid] = owner
                out.append(pid)
            self.allocs += n
            self.peak_live = max(self.peak_live, len(self._owner))
        return out

    def free(self, pages: list[int], owner: str):
        """Return ``pages`` (all owned by ``owner``) to the free list.

        Raises:
            PageError: a page is unknown, already free, or owned by
                someone else - the accounting bugs this class exists to
                catch are never silently absorbed.
        """
        with self._lock:
            for pid in pages:
                got = self._owner.get(pid)
                if got is None:
                    raise PageError(f"free of non-live page {pid} "
                                    f"by {owner!r}")
                if got != owner:
                    raise PageError(f"page {pid} owned by {got!r}, "
                                    f"freed by {owner!r}")
            for pid in pages:
                del self._owner[pid]
                self._free.append(pid)
            self.frees += len(pages)

    # -- page I/O -----------------------------------------------------------
    def write(self, pid: int, owner: str, data: np.ndarray):
        """Copy ``data`` (uint8, <= page_bytes) into page ``pid``."""
        with self._lock:
            self._check_owned(pid, owner)
            buf = self._pages[pid]
        if data.nbytes > self.page_bytes:
            raise ValueError(f"{data.nbytes} bytes > page size "
                             f"{self.page_bytes}")
        buf[:data.size] = data

    def read(self, pid: int, owner: str, nbytes: Optional[int] = None
             ) -> np.ndarray:
        """The first ``nbytes`` (default: all) of page ``pid`` as uint8."""
        with self._lock:
            self._check_owned(pid, owner)
            buf = self._pages[pid]
        return buf[:self.page_bytes if nbytes is None else nbytes].copy()

    def _check_owned(self, pid: int, owner: str):
        got = self._owner.get(pid)
        if got != owner:
            raise PageError(f"page {pid} owned by {got!r}, "
                            f"accessed by {owner!r}")

    # -- inspection ---------------------------------------------------------
    @property
    def live(self) -> int:
        """Pages currently owned (allocated and not yet freed)."""
        with self._lock:
            return len(self._owner)

    @property
    def size(self) -> int:
        """Total pages ever created (live + free)."""
        with self._lock:
            return len(self._pages)

    def owners(self) -> dict[int, str]:
        """Snapshot of the live page -> owner map."""
        with self._lock:
            return dict(self._owner)

    def counters(self) -> dict[str, int]:
        """Accounting snapshot for stats/benchmarks."""
        with self._lock:
            return {"page_allocs": self.allocs, "page_frees": self.frees,
                    "pages_grown": self.grown, "pages_reused": self.reused,
                    "pages_live": len(self._owner),
                    "pages_peak": self.peak_live}


class InferenceCache:
    """Per-request decode state parked in ``PagePool`` pages.

    ``put`` serializes a state pytree (any nest of numpy arrays - KV
    caches, mamba ``(conv_state, ssm_state)``, xLSTM recurrences) into
    freshly allocated pages; ``get`` reassembles a bit-identical pytree;
    ``drop`` frees the pages.  One entry per request id; a request's
    pages are owned by ``"req:{rid}"`` so cross-request aliasing is a
    ``PageError``, not a corruption.

    A multi-replica gateway gives every replica its own *named* cache
    over one shared pool: ``name="R0"`` prefixes the owner tag
    (``"R0:req:{rid}"``), so one replica freeing - or reading - another
    replica's pages is a ``PageError``, and the only sanctioned
    cross-replica path is ``transfer`` (which re-owns the pages under
    the destination cache, the replica-death migration edge).

    jax.tree flatten/unflatten is imported lazily so the pool itself
    stays importable without JAX (property tests exercise it raw).
    """

    def __init__(self, pool: Optional[PagePool] = None, *,
                 page_bytes: int = 1 << 16, name: str = ""):
        self.pool = pool if pool is not None else PagePool(page_bytes)
        self.name = name
        self._lock = threading.Lock()
        self._entries: dict[str, _Entry] = {}
        self.puts = 0
        self.hits = 0        # successful get()s
        self.misses = 0      # get()/drop() of an absent rid
        self.drops = 0
        self.transfers_in = 0    # entries adopted from a sibling cache
        self.transfers_out = 0   # entries handed to a sibling cache

    def _owner(self, rid: str) -> str:
        return f"{self.name}:req:{rid}" if self.name else f"req:{rid}"

    def put(self, rid: str, state: Any) -> int:
        """Park ``state`` (pytree of arrays) for request ``rid``.

        Returns the page count used.  Raises ``PageError`` if ``rid``
        already has an entry - callers drop before re-putting.
        """
        import jax
        leaves, treedef = jax.tree.flatten(state)
        arrs = [np.asarray(leaf) for leaf in leaves]
        blob = (np.concatenate([a.reshape(-1).view(np.uint8) for a in arrs])
                if arrs else np.zeros(0, np.uint8))
        with self._lock:
            if rid in self._entries:
                raise PageError(f"request {rid!r} already cached")
        npages = -(-blob.nbytes // self.pool.page_bytes) if blob.nbytes else 0
        pages = self.pool.alloc(self._owner(rid), npages)
        for i, pid in enumerate(pages):
            lo = i * self.pool.page_bytes
            self.pool.write(pid, self._owner(rid),
                            blob[lo:lo + self.pool.page_bytes])
        entry = _Entry(pages=pages, nbytes=blob.nbytes,
                       shapes=[a.shape for a in arrs],
                       dtypes=[a.dtype for a in arrs], treedef=treedef)
        with self._lock:
            if rid in self._entries:    # lost a put/put race: roll back
                self.pool.free(pages, self._owner(rid))
                raise PageError(f"request {rid!r} already cached")
            self._entries[rid] = entry
            self.puts += 1
        return npages

    def get(self, rid: str) -> Any:
        """The bit-identical state pytree parked by ``put``; None (a
        recorded miss) if ``rid`` has no entry."""
        with self._lock:
            entry = self._entries.get(rid)
            if entry is None:
                self.misses += 1
                return None
            self.hits += 1
        import jax
        chunks = []
        left = entry.nbytes
        for pid in entry.pages:
            take = min(left, self.pool.page_bytes)
            chunks.append(self.pool.read(pid, self._owner(rid), take))
            left -= take
        blob = (np.concatenate(chunks) if chunks else np.zeros(0, np.uint8))
        leaves, off = [], 0
        for shape, dtype in zip(entry.shapes, entry.dtypes):
            n = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
            leaves.append(blob[off:off + n].view(dtype).reshape(shape))
            off += n
        return jax.tree.unflatten(entry.treedef, leaves)

    def drop(self, rid: str) -> bool:
        """Free ``rid``'s pages; True if an entry existed."""
        with self._lock:
            entry = self._entries.pop(rid, None)
            if entry is None:
                self.misses += 1
                return False
            self.drops += 1
        self.pool.free(entry.pages, self._owner(rid))
        return True

    def transfer(self, rid: str, dst: "InferenceCache") -> bool:
        """Move ``rid``'s parked state into ``dst`` (bit-identical).

        The only sanctioned cross-cache page path: the state is read
        under this cache's ownership, the pages are freed, and ``dst``
        re-parks it under its own owner tag - so the single-owner
        invariant holds at every instant.  Used by the gateway when a
        surviving replica adopts a dead replica's requests.

        Returns True if an entry existed (False is a recorded miss, as
        for ``get``/``drop``).
        """
        state = self.get(rid)
        if state is None:
            return False
        self.drop(rid)
        dst.put(rid, state)
        with self._lock:
            self.transfers_out += 1
        with dst._lock:
            dst.transfers_in += 1
        return True

    def __contains__(self, rid: str) -> bool:
        with self._lock:
            return rid in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __iter__(self) -> Iterator[str]:
        with self._lock:
            return iter(list(self._entries))

    def counters(self) -> dict[str, int]:
        """Cache + pool accounting, merged (stats/benchmark payload)."""
        with self._lock:
            out = {"cache_puts": self.puts, "cache_hits": self.hits,
                   "cache_misses": self.misses, "cache_drops": self.drops,
                   "cache_transfers_in": self.transfers_in,
                   "cache_transfers_out": self.transfers_out,
                   "cache_entries": len(self._entries)}
        out.update(self.pool.counters())
        return out
