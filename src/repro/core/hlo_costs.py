"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each ``while`` body ONCE - for scan-based
models (layers scan, chunked attention, SSM chunk scans) that undercounts
FLOPs, bytes and collectives by the trip count (verified empirically; see
EXPERIMENTS.md §Dry-run "methodology").  This module re-derives the three
roofline inputs from ``compiled.as_text()`` with loop multipliers:

  * computations are parsed into instruction lists;
  * every ``while`` carries ``backend_config={"known_trip_count":{"n":K}}``;
    multipliers propagate through the call graph (while bodies x K,
    fusions/calls/conditionals x 1);
  * FLOPs   = sum over dot/convolution ops of 2 * |out| * contracted-size,
    times the computation's multiplier (transcendentals/elementwise are
    ignored: MXU work dominates - documented);
  * bytes   = sum over control-flow computations' top-level instructions of
    (result + operand bytes), skipping bookkeeping ops (parameter, constant,
    tuple plumbing, bitcast) and fusion internals - i.e. fused producers
    count once, which is closer to real HBM traffic than per-op sums;
  * collectives = payload/wire bytes as in hlo_analysis, times multiplier.

All quantities are *per device* (the compiled module is the per-partition
SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from .hlo_analysis import _shape_bytes, _group_size

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*?)\s+([\w\-]+)\(")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_COMP = re.compile(r"(?:true_computation|false_computation)=%?([\w.\-]+)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_DIMS = re.compile(r"\w+\[([\d,]*)\]")
_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id", "iota"}

_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    operands: list
    attrs: str
    inner: str = ""              # raw text inside the opcode parens
    is_root: bool = False

    def result_bytes(self) -> int:
        return _shape_bytes(self.shape_str)


def _parse_operands_and_attrs(line: str, start: int):
    depth = 1
    i = start
    while i < len(line) and depth:
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
        i += 1
    inner = line[start:i - 1]
    attrs = line[i:]
    ops = [m.group(1) for m in _OPERAND.finditer(inner)]
    return ops, attrs, inner


def parse_module(text: str) -> dict:
    """-> {comp_name: [Instr]}, entry name."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        h = _COMP_HDR.match(line)
        if h:
            cur = h.group(2)
            comps[cur] = []
            if h.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        ops, attrs, inner = _parse_operands_and_attrs(line, m.end())
        comps[cur].append(Instr(m.group(1), m.group(2), m.group(3), ops,
                                attrs, inner,
                                is_root=line.lstrip().startswith("ROOT ")))
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _multipliers(comps: dict, entry: str) -> tuple[dict, set]:
    """multiplier per computation; set of fusion-called computations."""
    mult: dict[str, float] = defaultdict(float)
    fusion_comps: set[str] = set()
    mult[entry] = 1.0
    # iterate to fixpoint over the call DAG (small graphs; few passes)
    for _ in range(64):
        changed = False
        new = defaultdict(float)
        new[entry] = 1.0
        for cname, instrs in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in instrs:
                if ins.opcode == "while":
                    t = _TRIP.search(ins.attrs)
                    trip = float(t.group(1)) if t else 1.0
                    b = _BODY.search(ins.attrs)
                    c = _COND.search(ins.attrs)
                    if b:
                        new[b.group(1)] += m * trip
                    if c:
                        new[c.group(1)] += m * (trip + 1)
                elif ins.opcode in ("fusion", "call", "custom-call",
                                    "conditional", "map", "reduce",
                                    "reduce-window", "sort", "scatter",
                                    "select-and-scatter", "all-reduce",
                                    "reduce-scatter"):
                    for cm in _CALLS.finditer(ins.attrs):
                        new[cm.group(1)] += m
                        if ins.opcode == "fusion":
                            fusion_comps.add(cm.group(1))
                    bm = _BRANCHES.search(ins.attrs)
                    if bm:
                        for br in _OPERAND.finditer(bm.group(1)):
                            new[br.group(1)] += m
                    for tf in _TF_COMP.finditer(ins.attrs):
                        new[tf.group(1)] += m
        if dict(new) != dict(mult):
            mult = new
            changed = True
        if not changed:
            break
    # transitively mark fusion-called comps (their callees too)
    frontier = set(fusion_comps)
    while frontier:
        nxt = set()
        for cname in frontier:
            for ins in comps.get(cname, []):
                for cm in _CALLS.finditer(ins.attrs):
                    if cm.group(1) not in fusion_comps:
                        nxt.add(cm.group(1))
        fusion_comps |= nxt
        frontier = nxt
    return dict(mult), fusion_comps


def _dims(shape_str: str) -> list[int]:
    m = _DIMS.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",") if d]


def _dot_flops(ins: Instr, table: dict) -> float:
    out = _dims(ins.shape_str)
    out_n = 1
    for d in out:
        out_n *= d
    lhs = table.get(ins.operands[0]) if ins.operands else None
    cd = _CDIMS.search(ins.attrs)
    k = 1
    if lhs and cd:
        ldims = _dims(lhs)
        for idx in (int(x) for x in cd.group(1).split(",") if x):
            if idx < len(ldims):
                k *= ldims[idx]
    return 2.0 * out_n * k


def _conv_flops(ins: Instr, table: dict) -> float:
    out = _dims(ins.shape_str)
    out_n = 1
    for d in out:
        out_n *= d
    rhs = table.get(ins.operands[1]) if len(ins.operands) > 1 else None
    k = 1
    if rhs:
        for d in _dims(rhs)[:-1]:   # kernel spatial dims x in_features
            k *= d
    return 2.0 * out_n * k


def _ordered_params(callee: list) -> list:
    """Parameter instructions ordered by their parameter index."""
    ps = []
    for i in callee:
        if i.opcode == "parameter":
            try:
                idx = int(i.inner.strip())
            except (ValueError, AttributeError):
                idx = len(ps)
            ps.append((idx, i))
    return [i for _, i in sorted(ps, key=lambda t: t[0])]


def _instr_bytes(ins: Instr, table: dict, comps: dict) -> float:
    """HBM-traffic model for one top-level instruction.

    Slice-aware: dynamic-slice reads only its window; dynamic-update-slice
    writes only the updated region (the rest aliases in place).  Fusions
    whose operands are only dynamically sliced inside (the scan-over-layers
    parameter slicing pattern) count the slice, not the stacked buffer.
    """
    op = ins.opcode
    if op in ("dynamic-slice", "slice", "gather"):
        # reads only the window/rows it extracts, not the whole operand
        return 2.0 * ins.result_bytes()
    if op == "dynamic-update-slice":
        upd = (_shape_bytes(table[ins.operands[1]])
               if len(ins.operands) > 1 and ins.operands[1] in table else
               ins.result_bytes())
        return 3.0 * upd  # read update + read/write window
    b = float(ins.result_bytes())
    callee = None
    if op == "fusion":
        cm = _CALLS.search(ins.attrs)
        if cm:
            callee = comps.get(cm.group(1))
    if callee:
        inner_table = {i.name: i.shape_str for i in callee}
        params = _ordered_params(callee)
        root = next((i for i in callee if i.is_root),
                    callee[-1] if callee else None)
        skip_pos = -1
        # DUS-rooted fusion: result aliases in place; count the update only
        # and skip the aliased target operand entirely
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = (_shape_bytes(inner_table[root.operands[1]])
                   if len(root.operands) > 1 and root.operands[1] in inner_table
                   else 0)
            b = 3.0 * upd
            target = root.operands[0] if root.operands else None
            for pos, pr in enumerate(params):
                if pr.name == target:
                    skip_pos = pos
                    break
        for pos, o in enumerate(ins.operands):
            if pos == skip_pos or o not in table:
                continue
            full = _shape_bytes(table[o])
            pname = params[pos].name if pos < len(params) else None
            if pname is not None:
                uses = [i for i in callee if pname in i.operands]
                if uses and all(u.opcode in ("dynamic-slice", "gather",
                                             "slice")
                                for u in uses):
                    b += sum(u.result_bytes() for u in uses)
                    continue
            b += full
        return b
    for o in ins.operands:
        if o in table:
            b += _shape_bytes(table[o])
    return b


@dataclasses.dataclass
class HloCosts:
    flops: float
    bytes: float
    coll_counts: dict
    coll_payload: dict
    coll_wire: dict
    dot_count: float
    coll_operands: dict = dataclasses.field(default_factory=dict)

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.coll_wire.values()))

    def to_json(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "dot_count": self.dot_count,
                "coll_operands": dict(self.coll_operands),
                "coll_counts": dict(self.coll_counts),
                "coll_payload": {k: float(v) for k, v in self.coll_payload.items()},
                "coll_wire": {k: float(v) for k, v in self.coll_wire.items()},
                "total_wire_bytes": self.total_wire_bytes}


def analyze(text: str, n_devices: int) -> HloCosts:
    comps, entry = parse_module(text)
    mult, fusion_comps = _multipliers(comps, entry)

    flops = 0.0
    nbytes = 0.0
    dot_count = 0.0
    coll_counts: dict = defaultdict(float)
    coll_operands: dict = defaultdict(float)
    coll_payload: dict = defaultdict(float)
    coll_wire: dict = defaultdict(float)

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        table = {ins.name: ins.shape_str for ins in instrs}
        in_fusion = cname in fusion_comps
        for ins in instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, table)
                dot_count += m
            elif ins.opcode == "convolution":
                flops += m * _conv_flops(ins, table)
            op = ins.opcode.removesuffix("-start")
            if op in ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute"):
                s = ins.result_bytes()
                g = _group_size(ins.attrs, n_devices)
                if s and g > 1:
                    coll_counts[op] += m
                    coll_operands[op] += m * max(len(ins.operands), 1)
                    coll_payload[op] += m * s
                    if op == "all-reduce":
                        w = 2 * s * (g - 1) / g
                    elif op == "all-gather":
                        w = s * (g - 1) / g
                    elif op == "reduce-scatter":
                        w = s * (g - 1)
                    elif op == "all-to-all":
                        w = s * (g - 1) / g
                    else:
                        w = s
                    coll_wire[op] += m * w
            if not in_fusion and ins.opcode not in _SKIP_BYTES \
                    and not ins.opcode.endswith("-done"):
                nbytes += m * _instr_bytes(ins, table, comps)
    return HloCosts(flops=flops, bytes=nbytes, coll_counts=dict(coll_counts),
                    coll_payload=dict(coll_payload),
                    coll_wire=dict(coll_wire), dot_count=dot_count,
                    coll_operands=dict(coll_operands))
