"""Step builders: train / prefill / decode, with distribution wired in.

The train step is a ``jax.shard_map`` whose *manual* axes are the
data-parallel mesh axes ("pod","data") - so the gradient exchange and solver
are explicit framework code (core/overlap.py: horovod | phylanx | zero1) -
while the "model" axis stays *auto*: tensor/expert parallelism inside the
model is delegated to the SPMD partitioner driven by the tiling plans
(core/sharding.py).  This is DESIGN.md §2's mapping of Phylanx's
active-messaging collectives onto TPU-native constructs.

Serve steps (prefill/decode) are pure pjit programs; their KV-cache tiling
plan adapts per architecture (GQA heads sharded when divisible, otherwise
the cache's sequence dim goes on the model axis) and per shape (long-context
caches spread over "data" too).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from . import compat, fusion, overlap
from .granularity import GrainPolicy
from .sharding import (ShardingRules, default_rules, init_params,
                       param_shardings, param_structs, set_act_hook,
                       spec_for)
from ..models.model import build_model
from ..optim.optimizers import OptConfig
from ..optim import optimizers as optim


# ---------------------------------------------------------------------------
# Strategy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str = "phylanx"            # phylanx | horovod | zero1 | onebit
    bucket_bytes: int = 0            # 0 -> runtime-adaptive (GrainPolicy)
    sequence_parallel: bool = False  # shard residual seq dim on "model"
    grad_accum: int = 1
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)

    def resolve_bucket_bytes(self, cfg, mesh, n_tensors: int,
                             shape: dict) -> int:
        if self.bucket_bytes:
            return self.bucket_bytes
        tot, _ = cfg.n_params()
        dec = GrainPolicy.derive(
            n_params=tot, n_tensors=n_tensors,
            global_batch=shape.get("global_batch", 8),
            seq=shape.get("seq_len", 1024), d_model=cfg.d_model,
            n_layers=cfg.n_layers, head_dim=max(cfg.head_dim, 1),
            dp_degree=dp_degree(mesh))
        return dec.bucket_bytes


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def dp_degree(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _serve_cfg(cfg):
    return dataclasses.replace(cfg, param_dtype="bf16", remat=False)


def _batch_spec(mesh, name: str) -> P:
    axes = dp_axes(mesh)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs - never allocated; spec step 2)
# ---------------------------------------------------------------------------
def input_specs(cfg, shape: dict) -> dict:
    """Stand-ins for every model input of a (arch x shape) cell."""
    B, S = shape["global_batch"], shape["seq_len"]
    kind = shape["kind"]
    i32 = jnp.int32
    if kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
               "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_frames, cfg.d_model), cfg.c_dtype)
        return out
    if kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_frames, cfg.d_model), cfg.c_dtype)
        return out
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    raise ValueError(kind)


def batch_shardings(cfg, mesh, shape: dict):
    spec = _batch_spec(mesh, "batch")
    sh = {}
    for k, v in input_specs(cfg, shape).items():
        # shard dim0 (batch) over dp axes when divisible
        n = dp_degree(mesh)
        use = spec if (v.shape and v.shape[0] % max(n, 1) == 0 and n > 1) else P()
        sh[k] = NamedSharding(mesh, use)
    return sh


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TrainStep:
    # buffers fn donates per call (donate_argnums=(0, 1) below); the
    # phylint step-contract builder lints against this declaration
    donated_buffers = ("params", "opt")
    fn: Any                      # jitted (params, opt, batch) -> (metrics, params, opt)
    fn_nodonate: Any = None      # for resilience replay/replicate (inputs kept)
    model: Any = None
    specs: Any = None            # ParamSpec tree
    param_shardings: Any = None
    opt_shardings: Any = None
    batch_shardings: Any = None
    rules: Any = None
    plan: Any = None
    strategy: Any = None
    mesh: Any = None
    scatter_mask: Any = None

    def _ndp(self):
        return dp_degree(self.mesh) if self.mesh is not None else 1

    def init(self, key):
        params = init_params(self.specs, key)
        params = jax.device_put(params, self.param_shardings)
        if self.strategy.name == "zero1":
            opt = overlap.zero1_init_state(self.specs, self.scatter_mask,
                                           self._ndp())
        else:
            opt = optim.init(params, self.strategy.opt)
            if self.strategy.name == "onebit":
                from ..optim.compression import ROW
                ndp = self._ndp()
                opt["ef"] = [jnp.zeros((ndp * b.size // ROW, ROW), jnp.float32)
                             for b in self.plan.buckets]
        opt = jax.device_put(opt, self.opt_shardings)
        return params, opt

    def param_structs(self):
        return param_structs(self.specs)

    def opt_structs(self):
        if self.strategy.name == "zero1":
            z = lambda: jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                param_structs(self.specs))
            return {"count": jax.ShapeDtypeStruct((), jnp.int32),
                    "m": z(), "v": z()}
        zeros = lambda: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
            param_structs(self.specs))
        out = {"count": jax.ShapeDtypeStruct((), jnp.int32)}
        if self.strategy.opt.kind == "adamw":
            out["m"], out["v"] = zeros(), zeros()
        elif self.strategy.opt.kind == "momentum":
            out["m"] = zeros()
        if self.strategy.name == "onebit":
            from ..optim.compression import ROW
            ndp = self._ndp()
            out["ef"] = [jax.ShapeDtypeStruct((ndp * b.size // ROW, ROW),
                                              jnp.float32)
                         for b in self.plan.buckets]
        return out


def make_train_step(cfg=None, mesh=None, strategy: Optional[Strategy] = None,
                    shape: Optional[dict] = None, *, plan=None) -> TrainStep:
    if plan is not None:
        cfg, mesh, strategy, shape = plan.resolve(
            "train", cfg=cfg, mesh=mesh, strategy=strategy, shape=shape)
    model = build_model(cfg)
    specs = model.specs()
    rules = default_rules(sequence_parallel=strategy.sequence_parallel)
    p_shard = param_shardings(specs, mesh, rules)
    # manual axes of size 1 make every dp collective a no-op; drop them so
    # the dp=1 case is a plain pjit program (old jax also cannot represent
    # manual subgroups over size-1 axes)
    axes = tuple(a for a in dp_axes(mesh) if mesh.shape[a] > 1)
    ndp = dp_degree(mesh)
    structs = param_structs(specs)
    n_tensors = len(jax.tree.leaves(structs))
    bucket_bytes = strategy.resolve_bucket_bytes(cfg, mesh, n_tensors, shape)
    oc = strategy.opt

    plan = None
    f32_structs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), structs)
    scatter_mask = None
    if strategy.name == "zero1":
        scatter_mask = overlap.zero1_scatter_mask(specs, mesh, rules, ndp)
    elif strategy.name == "onebit":
        from ..optim import compression
        plan = compression.make_plan(f32_structs, ndp)

    # tensors safe to coalesce into fused buckets: not sharded on "model"
    # (flattening TP-sharded grads de-shards them; see overlap.py)
    def _fusable(sp):
        pspec = spec_for(mesh, rules, sp.shape, sp.dims)
        return not any("model" in ((p,) if isinstance(p, str) else tuple(p or ()))
                       for p in pspec)
    fuse_mask = jax.tree.map(_fusable, specs,
                             is_leaf=lambda x: hasattr(x, "dims"))

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if strategy.grad_accum <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        k = strategy.grad_accum
        micro = jax.tree.map(
            lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)

        def acc(carry, mb):
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (carry[0] + l / k,
                    jax.tree.map(lambda a, b: a + b / k, carry[1], g)), None
        zero_g = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                              structs)
        (l, g), _ = compat.layer_scan(acc,
                                      (jnp.zeros((), jnp.float32), zero_g),
                                      micro)
        return l, g

    def body(params, opt_state, batch):
        # inside shard_map the batch dim is already local: constrain only
        # auto-axis (model) placements; seq joins under sequence parallelism
        set_act_hook(mesh, rules.with_overrides(batch=None))
        loss, grads = grads_of(params, batch)
        loss = jax.lax.pmean(loss, axes) if axes else loss
        if axes and compat.NEEDS_DP_OPERAND_REPLICATION:
            # old-jax partial-manual workaround: the dp exchange below may
            # psum tensors still sharded over the auto "model" axis
            grads = compat.replicate_dp_operands(grads, mesh)
            if strategy.name == "zero1":
                params = compat.replicate_dp_operands(params, mesh)
        if strategy.name == "zero1":
            params, opt_state, m = overlap.zero1_update(
                grads, opt_state, params, oc, axes, scatter_mask)
        elif strategy.name == "onebit" and axes:
            from ..optim import compression
            grads_r, new_ef = compression.exchange_onebit(
                grads, opt_state["ef"], axes, plan)
            inner = {k: v for k, v in opt_state.items() if k != "ef"}
            params, inner, m = optim.update(grads_r, inner, params, oc)
            opt_state = dict(inner, ef=new_ef)
        else:
            if axes:
                grads_r = (overlap.exchange_horovod(grads, axes)
                           if strategy.name == "horovod" else
                           overlap.exchange_phylanx(grads, axes, bucket_bytes,
                                                    fuse_mask=fuse_mask))
            else:
                grads_r = grads
            params, opt_state, m = optim.update(grads_r, opt_state, params, oc)
        metrics = {"loss": loss, "grad_norm": m["grad_norm"]}
        return metrics, params, opt_state

    if axes:
        if strategy.name == "zero1":
            opt_specs = overlap.zero1_state_shard_specs(scatter_mask, axes)
        elif strategy.name == "onebit":
            opt_specs = _opt_skeleton(oc)
            opt_specs["ef"] = [P(tuple(axes)) for _ in plan.buckets]
        else:
            opt_specs = _opt_skeleton(oc)  # prefix tree of P()
        bspec = _batch_spec(mesh, "batch")
        fn = compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(), opt_specs, bspec),
            out_specs=(P(), P(), opt_specs),
            axis_names=set(axes), check_vma=False)
    else:
        fn = body

    # shardings for init/IO
    if strategy.name == "onebit":
        f32_specs = optim.init_specs(specs, oc)
        opt_sh = param_shardings(f32_specs, mesh, rules)
        dp_spec = P(axes if len(axes) > 1 else (axes[0] if axes else None))
        opt_sh["ef"] = [NamedSharding(mesh, dp_spec) for _ in plan.buckets]
    elif strategy.name == "zero1":
        def _state_sh(sp, sc):
            pspec = spec_for(mesh, rules, sp.shape, sp.dims)
            parts = list(pspec) + [None] * (len(sp.shape) - len(pspec))
            if sc and axes:
                parts[0] = axes if len(axes) > 1 else axes[0]
            return NamedSharding(mesh, P(*parts))
        per = jax.tree.map(_state_sh, specs, scatter_mask,
                           is_leaf=lambda x: hasattr(x, "dims"))
        opt_sh = {"count": NamedSharding(mesh, P()), "m": per,
                  "v": jax.tree.map(_state_sh, specs, scatter_mask,
                                    is_leaf=lambda x: hasattr(x, "dims"))}
    else:
        f32_specs = optim.init_specs(specs, oc)
        opt_sh = param_shardings(f32_specs, mesh, rules)

    b_shard = batch_shardings(cfg, mesh, shape)
    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "grad_norm": NamedSharding(mesh, P())}
    jitted = jax.jit(fn, donate_argnums=(0, 1),
                     in_shardings=(p_shard, opt_sh, b_shard),
                     out_shardings=(metrics_sh, p_shard, opt_sh))
    nodonate = jax.jit(fn, in_shardings=(p_shard, opt_sh, b_shard),
                       out_shardings=(metrics_sh, p_shard, opt_sh))
    return TrainStep(fn=jitted, fn_nodonate=nodonate, model=model, specs=specs,
                     param_shardings=p_shard, opt_shardings=opt_sh,
                     batch_shardings=b_shard,
                     rules=rules, plan=plan, strategy=strategy, mesh=mesh,
                     scatter_mask=scatter_mask)


# ---------------------------------------------------------------------------
# DDP step (multi-process data parallelism over the active-message fabric)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DDPStep:
    """Split train step for fabric DDP (DESIGN.md §11).

    Unlike :class:`TrainStep` - one jit that exchanges gradients with
    XLA collectives inside ``shard_map`` - DDP over the active-message
    wire needs the exchange OUTSIDE jax: ``grad_fn`` produces the local
    loss plus fused f32 gradient buckets (``grad_plan``), the ring
    all-reduce sums them across localities, and ``apply_fn`` applies the
    identical optimizer update to the summed-and-averaged buckets.  Both
    halves are deterministic pure functions of their inputs, which is
    what makes every locality's post-step params bitwise equal.
    """

    # buffers apply_fn donates per call (donate_argnums=(1, 2) below);
    # the phylint step-contract builder lints against this declaration
    donated_buffers = ("params", "opt")
    grad_fn: Any                 # jitted (params, batch) -> (loss, [bufs])
    apply_fn: Any                # jitted ([bufs], params, opt) -> (gnorm, params, opt)
    model: Any = None
    specs: Any = None            # ParamSpec tree
    param_shardings: Any = None
    opt_shardings: Any = None
    batch_shardings: Any = None
    grad_plan: Any = None        # FusionPlan for the wire buckets
    strategy: Any = None
    mesh: Any = None

    def init(self, key):
        """Deterministic (params, opt) - identical on every locality fed
        the same key."""
        params = init_params(self.specs, key)
        params = jax.device_put(params, self.param_shardings)
        opt = jax.device_put(optim.init(params, self.strategy.opt),
                             self.opt_shardings)
        return params, opt


def make_ddp_step(cfg=None, mesh=None, strategy: Optional[Strategy] = None,
                  shape: Optional[dict] = None, *, plan=None) -> DDPStep:
    """Build the split grad/apply step pair for fabric DDP.

    ``shape['global_batch']`` here is the PER-SHARD batch (the frontend
    divides ``Plan.batch`` by the shard count).  Gradient buckets come
    from ``optim.compression.make_plan`` with ``dp=1`` - the wire codec,
    not XLA, owns the data-parallel exchange.

    Raises:
        ValueError: strategy is zero1 (sharded optimizer state cannot
            ride a replicated-bucket wire), uses grad accumulation, or
            the mesh has an in-process dp axis (> 1) - fabric DDP IS the
            data parallelism; combine with model-axis sharding only.
    """
    if plan is not None:
        cfg, mesh, strategy, shape = plan.resolve(
            "train", cfg=cfg, mesh=mesh, strategy=strategy, shape=shape)
    if strategy.name == "zero1":
        raise ValueError("ddp=True cannot use the zero1 strategy: its "
                         "optimizer state is dp-sharded inside one process, "
                         "but fabric DDP replicates state per locality")
    if strategy.grad_accum > 1:
        raise ValueError("ddp=True with grad_accum > 1 is not supported "
                         "yet; raise Plan.ddp_shards instead")
    if dp_degree(mesh) > 1:
        raise ValueError("ddp=True replaces the in-process dp axes: use a "
                         "mesh with data=pod=1 (model-axis sharding is fine)")
    model = build_model(cfg)
    specs = model.specs()
    rules = default_rules(sequence_parallel=strategy.sequence_parallel)
    p_shard = param_shardings(specs, mesh, rules)
    structs = param_structs(specs)
    f32_structs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), structs)
    from ..optim import compression
    gplan = compression.make_plan(f32_structs, 1)
    oc = strategy.opt

    def loss_and_bufs(params, batch):
        set_act_hook(mesh, rules)
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        return loss.astype(jnp.float32), fusion.pack(grads, gplan)

    b_shard = batch_shardings(cfg, mesh, shape)
    repl = NamedSharding(mesh, P())
    bufs_sh = [repl for _ in gplan.buckets]
    grad_fn = jax.jit(loss_and_bufs,
                      in_shardings=(p_shard, b_shard),
                      out_shardings=(repl, bufs_sh))

    def apply(bufs, params, opt_state):
        set_act_hook(mesh, rules)
        grads = fusion.unpack(bufs, gplan)
        params, opt_state, m = optim.update(grads, opt_state, params, oc)
        return m["grad_norm"], params, opt_state

    f32_specs = optim.init_specs(specs, oc)
    opt_sh = param_shardings(f32_specs, mesh, rules)
    apply_fn = jax.jit(apply, donate_argnums=(1, 2),
                       in_shardings=(bufs_sh, p_shard, opt_sh),
                       out_shardings=(repl, p_shard, opt_sh))
    return DDPStep(grad_fn=grad_fn, apply_fn=apply_fn, model=model,
                   specs=specs, param_shardings=p_shard, opt_shardings=opt_sh,
                   batch_shardings=b_shard, grad_plan=gplan,
                   strategy=strategy, mesh=mesh)


def _opt_skeleton(oc: OptConfig):
    """PartitionSpec prefix-tree for dense optimizer state (all replicated
    over manual dp axes; 'model' sharding is auto)."""
    out = {"count": P()}
    if oc.kind == "adamw":
        out["m"], out["v"] = P(), P()
    elif oc.kind == "momentum":
        out["m"] = P()
    return out


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------
def decode_rules(cfg, mesh, shape: dict) -> ShardingRules:
    """Tiling plan for KV caches / recurrent state, adapted per cell."""
    r = default_rules()
    model_n = mesh.shape.get("model", 1)
    over = {}
    if model_n > 1 and cfg.n_kv_heads % model_n != 0:
        # GQA cache can't shard by head: tile the sequence dim instead
        over["kv_seq"] = "model"
        over["kv_heads"] = None
    if shape["global_batch"] == 1:
        # long-context single stream: spread the cache over "data" too
        if over.get("kv_seq") == "model":
            over["kv_seq"] = ("data", "model")
        else:
            over["kv_seq"] = "data"
    return r.with_overrides(**over)


@dataclasses.dataclass
class ServeStep:
    # decode donates the KV cache in place (donate_argnums=(1,) below);
    # the phylint step-contract builder lints against this declaration
    donated_buffers = ("cache",)
    fn: Any
    model: Any
    specs: Any
    param_shardings: Any
    cache_specs: Any            # None for prefill
    cache_shardings: Any
    batch_shardings: Any
    rules: ShardingRules


def make_prefill_step(cfg=None, mesh=None, strategy: Optional[Strategy] = None,
                      shape: Optional[dict] = None, *, plan=None) -> ServeStep:
    if plan is not None:
        cfg, mesh, strategy, shape = plan.resolve(
            "prefill", cfg=cfg, mesh=mesh, strategy=strategy, shape=shape)
    scfg = _serve_cfg(cfg)
    model = build_model(scfg)
    specs = model.specs()
    rules = decode_rules(scfg, mesh, shape)
    p_shard = param_shardings(specs, mesh, rules)
    S = shape["seq_len"]

    def fn(params, batch):
        set_act_hook(mesh, rules)
        return model.prefill(params, batch, S)

    cache_sp = model.cache_specs(shape["global_batch"], S)
    cache_sh = param_shardings(cache_sp, mesh, rules)
    jitted = jax.jit(fn, in_shardings=(p_shard, batch_shardings(scfg, mesh, shape)),
                     out_shardings=(NamedSharding(mesh, P()), cache_sh))
    return ServeStep(fn=jitted, model=model, specs=specs,
                     param_shardings=p_shard, cache_specs=cache_sp,
                     cache_shardings=cache_sh,
                     batch_shardings=batch_shardings(scfg, mesh, shape),
                     rules=rules)


def make_decode_step(cfg=None, mesh=None, strategy: Optional[Strategy] = None,
                     shape: Optional[dict] = None, *, plan=None) -> ServeStep:
    if plan is not None:
        cfg, mesh, strategy, shape = plan.resolve(
            "decode", cfg=cfg, mesh=mesh, strategy=strategy, shape=shape)
    scfg = _serve_cfg(cfg)
    model = build_model(scfg)
    specs = model.specs()
    rules = decode_rules(scfg, mesh, shape)
    p_shard = param_shardings(specs, mesh, rules)
    B, S = shape["global_batch"], shape["seq_len"]
    cache_sp = model.cache_specs(B, S)
    cache_sh = param_shardings(cache_sp, mesh, rules)

    def fn(params, cache, batch, pos):
        set_act_hook(mesh, rules)
        return model.decode_step(params, cache, batch, pos)

    jitted = jax.jit(
        fn,
        in_shardings=(p_shard, cache_sh, batch_shardings(scfg, mesh, shape),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P()), cache_sh),
        donate_argnums=(1,))
    return ServeStep(fn=jitted, model=model, specs=specs,
                     param_shardings=p_shard, cache_specs=cache_sp,
                     cache_shardings=cache_sh,
                     batch_shardings=batch_shardings(scfg, mesh, shape),
                     rules=rules)


def make_step(cfg=None, mesh=None, strategy: Optional[Strategy] = None,
              shape: Optional[dict] = None, *, plan=None):
    if shape is None and plan is not None:
        if plan.shape is None:
            raise ValueError(
                "make_step(plan=...) dispatches on shape['kind']: give the "
                "Plan a named shape or pass shape= explicitly (or call "
                "make_train_step/make_prefill_step/make_decode_step)")
        shape = plan.shape_of("train")   # named Plan shapes carry their kind
    kind = shape["kind"]
    if kind == "train":
        return make_train_step(cfg, mesh, strategy, shape, plan=plan)
    if kind == "prefill":
        return make_prefill_step(cfg, mesh, strategy, shape, plan=plan)
    if kind == "decode":
        return make_decode_step(cfg, mesh, strategy, shape, plan=plan)
    raise ValueError(kind)
