"""Version shims for jax APIs that moved between releases.

The repo targets current jax but must run (and be tested) on older
installs:

  * ``jax.shard_map`` graduated from ``jax.experimental.shard_map`` (and
    ``jax.sharding.AxisType`` appeared alongside it — handled in
    launch/mesh.py).  Import ``shard_map`` from here, never from jax.
  * ``lax.axis_size`` does not exist on jax 0.4.x; ``axis_size`` here
    falls back to the statically-evaluated ``psum(1, axis)`` idiom.
  * on jax 0.4.x, a ``lax.all_gather`` inside a *partially-manual*
    shard_map (auto axes present) crashes XLA's SPMD partitioner
    (``Check failed: IsManualSubgroup``); ``all_gather`` here emulates it
    with dynamic_update_slice + psum on old jax — 2x the wire bytes of a
    ring all-gather, but correct, and only on the fallback path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

_NEW_SHARD_MAP = hasattr(jax, "shard_map")

if _NEW_SHARD_MAP:
    shard_map = jax.shard_map
else:  # jax <= 0.4.x: adapt the new kwargs onto the experimental API
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=True):
        # new axis_names= (manual axes) is the complement of old auto=
        auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
                if axis_names is not None else frozenset())
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma,
                          auto=auto)


# On jax 0.4.x, sharding propagation loses the manual-subgroup annotation
# through `while` ops (lax.scan) inside a partially-manual shard_map, so
# any later collective over the manual axes fails the partitioner's
# RET_CHECK.  layer_scan unrolls small scans into a python loop on old jax
# (identical math, bigger HLO); above the cap it falls back to real scan -
# full-scale shapes would pay an unacceptable compile blow-up, and they are
# not run on old jax.
_UNROLL_CAP = 64


def layer_scan(f, init, xs, length=None):
    """``lax.scan`` with the old-jax partial-manual workaround above."""
    n = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    if _NEW_SHARD_MAP or n > _UNROLL_CAP:
        return jax.lax.scan(f, init, xs, length=length)
    carry, ys = init, []
    for i in range(n):
        xi = None if xs is None else jax.tree.map(lambda a, i=i: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if not ys or all(y is None for y in ys):
        return carry, None
    return carry, jax.tree.map(lambda *vs: jnp.stack(vs), *ys)


# On jax 0.4.x, a collective over *manual* mesh axes whose operand still
# carries an *auto*-axis sharding (e.g. pmean over "data" of a
# tensor-parallel-sharded gradient) hits XLA RET_CHECK failures in the SPMD
# partitioner ("Cross-partition allreduce must be in (partial) manual
# partitioning mode").  The workaround is to replicate such operands across
# the auto axes just before the collective; the pjit-level output shardings
# re-shard afterwards.  Costs extra wire on the fallback path only.
NEEDS_DP_OPERAND_REPLICATION = not _NEW_SHARD_MAP


def replicate_dp_operands(tree, mesh):
    """Constrain every leaf replicated across auto axes (old jax only)."""
    if not NEEDS_DP_OPERAND_REPLICATION:
        return tree
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = NamedSharding(mesh, P())
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(x, sh), tree)


def axis_size(axis_name) -> int:
    """Static size of a mapped axis (or product over a tuple of axes)."""
    if not isinstance(axis_name, str):
        n = 1
        for a in axis_name:
            n *= axis_size(a)
        return n
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)   # statically evaluated on concrete input


def all_gather(x: jax.Array, axis_name, *, axis: int = 0,
               tiled: bool = True) -> jax.Array:
    """``lax.all_gather`` where the partitioner supports it; emulated via
    dynamic_update_slice + psum inside old-jax partial-manual bodies."""
    if _NEW_SHARD_MAP:
        return lax.all_gather(x, axis_name, axis=axis, tiled=tiled)
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    n = axis_size(names)
    # ``anchor`` ties compiler-generated constants to the input so sharding
    # propagation keeps them inside the manual subgroup (free-floating
    # constants get auto shardings and abort the old partitioner).
    anchor = x.ravel()[0] * 0
    # rank without lax.axis_index (it lowers to a PartitionId op the old
    # partitioner rejects inside partial-manual regions): psum_scatter of a
    # replicated iota hands rank r the block [r] of the cross-rank sum,
    # i.e. the scalar n * r
    r = lax.psum_scatter(
        jnp.arange(n, dtype=jnp.float32) + anchor.astype(jnp.float32),
        axis_name, scatter_dimension=0, tiled=True)
    idx = jnp.round(r[0] / n).astype(jnp.int32)
    if tiled:
        shape = list(x.shape)
        shape[axis] *= n
        start = [0] * x.ndim
        start[axis] = idx * x.shape[axis]
        buf = jnp.zeros(shape, x.dtype) + anchor
        buf = lax.dynamic_update_slice(buf, x, tuple(start))
    else:
        buf = jnp.zeros((n,) + x.shape, x.dtype) + anchor
        buf = lax.dynamic_update_slice(buf, x[None], (idx,) + (0,) * x.ndim)
    return lax.psum(buf, axis_name)
