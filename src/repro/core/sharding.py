"""Divisibility-aware sharding-rules engine ("tiling plans").

Phylanx represents a distributed array as local tiles plus meta-information
describing the whole array. The JAX-native equivalent is a
``NamedSharding(mesh, PartitionSpec)``; what JAX does *not* give us is a
declarative mapping from *logical dimension names* (``"batch"``, ``"heads"``,
``"d_ff"``, ...) to mesh axes with graceful fallback when a dimension does not
divide the axis.  This module provides that: models annotate every parameter
and activation with logical dim names and the engine turns them into concrete
``PartitionSpec``s, replicating any dimension that cannot be tiled evenly
(e.g. 2 KV heads under 16-way tensor parallelism).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical dimension vocabulary (shared by models / steps / dryrun)
# ---------------------------------------------------------------------------
#   batch      -> data-parallel axes ("pod","data")
#   seq        -> sequence; sharded only under sequence parallelism
#   model-ish  -> "model" axis: heads, kv_heads, d_ff, vocab, experts, inner
#   replicated -> None
DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),
    "seq": None,              # flipped to "model" under sequence parallelism
    "kv_seq": None,           # long-context KV sharding -> "data"
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "inner": "model",         # mamba2 / mlstm inner channels
    "state": None,            # SSM state dim
    "conv": None,
    "layers": None,           # scan-over-layers stacking dim
    "stage": None,            # pipeline stage dim (PP experiments)
    "channels": "model",      # CNN channels
    "spatial": None,
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """A tiling plan: logical dim name -> mesh axis (or tuple of axes)."""

    rules: Mapping[str, tuple[str, ...] | str | None]

    def with_overrides(self, **ov) -> "ShardingRules":
        new = dict(self.rules)
        new.update(ov)
        return ShardingRules(new)

    def axis_for(self, dim: str) -> tuple[str, ...] | str | None:
        return self.rules.get(dim, None)


def default_rules(*, sequence_parallel: bool = False,
                  long_context_kv: bool = False) -> ShardingRules:
    r = dict(DEFAULT_RULES)
    if sequence_parallel:
        r["seq"] = "model"
    if long_context_kv:
        r["kv_seq"] = "data"
    return ShardingRules(r)


def _axis_size(mesh: Mesh, axis: tuple[str, ...] | str | None) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        axis = (axis,)
    size = 1
    for a in axis:
        if a in mesh.shape:
            size *= mesh.shape[a]
    return size


def _present(mesh: Mesh, axis: tuple[str, ...] | str | None):
    """Filter an axis assignment down to axes present in this mesh."""
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in mesh.shape else None
    axes = tuple(a for a in axis if a in mesh.shape)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def spec_for(mesh: Mesh, rules: ShardingRules, shape: Sequence[int],
             dims: Sequence[str | None]) -> P:
    """PartitionSpec for a concrete shape with divisibility fallback.

    A dim is sharded on its mapped mesh axes only when evenly divisible;
    otherwise it is replicated.  Axes may be consumed at most once per spec
    (XLA requirement) - first dim wins.
    """
    assert len(shape) == len(dims), (shape, dims)
    used: set[str] = set()
    parts: list[Any] = []
    for size, dim in zip(shape, dims):
        axis = _present(mesh, rules.axis_for(dim)) if dim is not None else None
        if axis is None:
            parts.append(None)
            continue
        ax_tuple = (axis,) if isinstance(axis, str) else tuple(axis)
        if any(a in used for a in ax_tuple):
            parts.append(None)
            continue
        asize = _axis_size(mesh, ax_tuple)
        if asize <= 1 or size % asize != 0:
            parts.append(None)
            continue
        used.update(ax_tuple)
        parts.append(ax_tuple if len(ax_tuple) > 1 else ax_tuple[0])
    # strip trailing Nones for tidiness
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def sharding_for(mesh: Mesh, rules: ShardingRules, shape: Sequence[int],
                 dims: Sequence[str | None]) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, rules, shape, dims))


# ---------------------------------------------------------------------------
# Parameter specs: shape + logical dims + init
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    dims: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init: str = "normal"      # normal | zeros | ones | scaled
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def initialize(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    if spec.init == "scaled":
        return (spec.scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init}")


def init_params(specs, key: jax.Array):
    """Initialize a pytree of ParamSpec into a pytree of arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    arrs = [initialize(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def param_structs(specs):
    return jax.tree.map(lambda s: s.struct(), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_shardings(specs, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda s: sharding_for(mesh, rules, s.shape, s.dims), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(s.size for s in leaves)


def constrain(x: jax.Array, mesh: Mesh, rules: ShardingRules,
              dims: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint by logical dims (no-op outside jit/mesh)."""
    try:
        spec = spec_for(mesh, rules, x.shape, dims)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# Activation constraint hook (sequence parallelism and friends)
# ---------------------------------------------------------------------------
# Installed by the step builder at trace time; model code calls
# ``act_constrain(x, dims)`` between blocks.  When no hook is installed it is
# a no-op, so models stay mesh-agnostic (R8).
_ACT_HOOK: list = [None]


def set_act_hook(mesh: Mesh | None, rules: ShardingRules | None):
    if mesh is None:
        _ACT_HOOK[0] = None
    else:
        _ACT_HOOK[0] = (mesh, rules)


def act_constrain(x: jax.Array, dims: Sequence[str | None]) -> jax.Array:
    hook = _ACT_HOOK[0]
    if hook is None or len(dims) != x.ndim:
        return x
    mesh, rules = hook
    try:
        spec = spec_for(mesh, rules, x.shape, dims)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except Exception:
        return x
