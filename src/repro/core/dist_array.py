"""Tiled distributed arrays with whole-array metadata (paper §4.1).

Phylanx: "Each of the tiles of the data arrays handled by a locality is
internally represented exactly like a fully local data array except that it
carries additional meta-information describing the whole (distributed)
array."  ``jax.Array`` + ``NamedSharding`` already is that representation;
``TiledArray`` adds the logical-dimension metadata (so re-tiling is a
declarative operation) and the paper's *overlapped tiling* (halo) support.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import collectives, compat
from .sharding import ShardingRules, sharding_for, spec_for


@dataclasses.dataclass
class TiledArray:
    """A distributed array + the tiling plan that produced it."""

    data: jax.Array
    dims: tuple[str | None, ...]     # logical dim names, len == ndim
    mesh: Mesh
    rules: ShardingRules

    # -- construction -------------------------------------------------------
    @classmethod
    def tile(cls, x: jax.Array, dims: Sequence[str | None], mesh: Mesh,
             rules: ShardingRules) -> "TiledArray":
        sh = sharding_for(mesh, rules, x.shape, dims)
        return cls(jax.device_put(x, sh), tuple(dims), mesh, rules)

    # -- metadata ------------------------------------------------------------
    @property
    def spec(self) -> P:
        return spec_for(self.mesh, self.rules, self.data.shape, self.dims)

    @property
    def global_shape(self) -> tuple[int, ...]:
        return tuple(self.data.shape)

    def tile_shape(self) -> tuple[int, ...]:
        """Shape of the per-device tile."""
        sh = self.data.sharding.shard_shape(self.data.shape)
        return tuple(sh)

    # -- re-tiling (declarative redistribution) ------------------------------
    def retile(self, rules: ShardingRules) -> "TiledArray":
        sh = sharding_for(self.mesh, rules, self.data.shape, self.dims)
        return TiledArray(jax.device_put(self.data, sh), self.dims,
                          self.mesh, rules)

    def replicated(self) -> "TiledArray":
        sh = NamedSharding(self.mesh, P())
        return TiledArray(jax.device_put(self.data, sh), self.dims,
                          self.mesh, ShardingRules({}))

    # -- overlapped tiling ----------------------------------------------------
    def with_halo(self, dim_name: str, halo: int) -> jax.Array:
        """Return the array where each tile of ``dim_name`` is extended with
        ``halo`` ghost rows from its neighbours (spatial parallelism)."""
        axis = self.rules.axis_for(dim_name)
        if axis is None or (isinstance(axis, str) and axis not in self.mesh.shape):
            return self.data  # dimension not distributed: nothing to exchange
        assert isinstance(axis, str), "halo exchange over a single mesh axis"
        dim = self.dims.index(dim_name)
        in_spec = self.spec

        def body(x):
            return collectives.halo_exchange(x, axis, halo, dim=dim)

        out_parts = list(in_spec) + [None] * (len(self.dims) - len(in_spec))
        fn = compat.shard_map(body, mesh=self.mesh, in_specs=in_spec,
                              out_specs=P(*out_parts), check_vma=False)
        return fn(self.data)
