"""Host-side futurized execution (the paper's futurization, where dynamism
lives).

Phylanx turns user code into a *futurized execution tree* scheduled by HPX:
every operation becomes a task whose execution is constrained only by the
resolution of its inputs.  Under XLA the *device* dataflow is compiled ahead
of time (see DESIGN.md §2), but the host side of a training/serving loop
retains real asynchrony: JAX dispatch is async, transfers/saves can proceed
concurrently, and several steps can be kept in flight.  This module is that
runtime:

  * ``FuturizedGraph.defer`` builds a DAG of host tasks.  Dependencies are
    discovered by *pytree traversal* of the arguments - any ``PhyFuture``
    found anywhere inside nested containers becomes an edge.  A task runs
    when its inputs resolve (constraint-based synchronization); the
    submitting thread never blocks and never calls ``.result()`` on behalf
    of a task.
  * ``when_all`` / ``when_any`` combinators compose futures; ``tree_join``
    turns a pytree-of-futures into a future-of-pytree (the paper's "tree of
    futures").
  * Errors and cancellations propagate along dependency edges to all
    transitive dependents, so a failed prefetch poisons exactly the steps
    that consumed it and nothing else.
  * Ready tasks are drained by priority *lane*: compute dispatch beats
    prefetch beats checkpoint I/O, so background saves never delay the
    step-critical path.
  * ``promise`` creates an *externally resolved* node (HPX's promise):
    the distributed layer (``repro.distrib``) fulfils it when a result
    frame arrives from another locality, and the usual edge propagation
    takes over from there.
  * ``stats()`` reports tasks run / failed / cancelled, max in-flight, and
    worker idle time - the observability hook the benchmarks read.

``Pipeline`` (keep N device steps in flight with donation) rides on JAX's
own async dispatch and is how the training loop bounds its lead over the
device.  Device arrays pass through ``defer`` untouched: they are already
futures under JAX's async dispatch.
"""
from __future__ import annotations

import bisect
import collections
import dataclasses
import enum
import heapq
import itertools
import threading
import time
import weakref
from concurrent.futures import CancelledError
from typing import Any, Callable, Iterable, Optional, Sequence

import jax

# stdlib-only module: safe to import here without a package cycle
from ..analysis import sanitize as _san

__all__ = [
    "CancelledError", "FuturizedGraph", "HIST_EDGES_S", "InFlight", "Lane",
    "PhyFuture", "Pipeline", "REQUEST_PHASES", "RuntimeStats", "TaskState",
    "hist_labels",
]


class Lane(enum.IntEnum):
    """Priority lanes, highest first.  Ready tasks drain in lane order:
    step-critical work is never queued behind background I/O.  Note the
    loop *blocks* on prefetch results, so only work the loop waits on
    sooner belongs in COMPUTE; metric forcing and step retirement are
    observability/checkpoint-path work and ride CHECKPOINT."""
    COMPUTE = 0      # host work on the step-critical path
    PREFETCH = 1     # next-batch build + host->device transfer
    CHECKPOINT = 2   # checkpoint I/O, metric forcing, retirement


class TaskState(enum.Enum):
    PENDING = "pending"        # waiting on dependency edges
    READY = "ready"            # all inputs resolved; queued for a worker
    RUNNING = "running"
    DONE = "done"
    ERROR = "error"
    CANCELLED = "cancelled"


_TERMINAL = (TaskState.DONE, TaskState.ERROR, TaskState.CANCELLED)


# wall-time histogram bucket edges (seconds): tasks land in the first
# bucket whose edge exceeds their duration; the last bucket is open-ended
HIST_EDGES_S = (1e-4, 1e-3, 1e-2, 1e-1, 1.0)

# per-request latency phases the serving gateway histograms (same bucket
# edges as the lane histograms): time queued before prefill started, the
# prefill itself, each decoded token, and submit->finish end to end
REQUEST_PHASES = ("queue_wait", "prefill", "decode_token", "total")


def _fmt_s(s: float) -> str:
    if s < 1e-3:
        return f"{s * 1e6:g}us"
    if s < 1.0:
        return f"{s * 1e3:g}ms"
    return f"{s:g}s"


def hist_labels() -> list[str]:
    """Human-readable bucket names for ``HIST_EDGES_S``: ``"<100us"`` ...
    ``">=1s"`` - one label per histogram cell, last bucket open-ended."""
    return ([f"<{_fmt_s(e)}" for e in HIST_EDGES_S]
            + [f">={_fmt_s(HIST_EDGES_S[-1])}"])


@dataclasses.dataclass
class RuntimeStats:
    """Counters for one ``FuturizedGraph``; read via ``graph.stats()``.

    ``to_json()`` schema::

        {
          "submitted" | "completed" | "failed" | "cancelled": int,
          "max_in_flight": int,          # peak concurrently-RUNNING tasks
          "idle_s" | "busy_s": float,    # summed worker wall time
          "per_lane": {lane: int},       # completions per Lane name
          "lane_time_hist": {
            "edges_s": [1e-4, 1e-3, 1e-2, 1e-1, 1.0],   # bucket edges (s)
            "labels": ["<100us", "<1ms", "<10ms", "<100ms", "<1s", ">=1s"],
            "counts": {lane: [int] * 6},  # counts[i] tasks in labels[i]
          },
          "serve": {counter: int},       # gateway admission/cache counters
          "serve_replicas": {            # the same counters split by the
            "0": {counter: int}, ...},   # serve replica that incurred them
          "request_latency_hist": {      # per-request phases, same buckets
            "edges_s": [...], "labels": [...],
            "counts": {phase: [int] * 6},   # phase in REQUEST_PHASES
          },
        }

    ``serve``, ``serve_replicas`` and ``request_latency_hist`` are fed by
    the serving gateway (``frontend/gateway.py``) through
    ``FuturizedGraph.record_serve``; all serialize as empty/all-zeros for
    graphs that never serve.  ``serve_replicas`` keys are string replica
    indices (JSON-stable) and appear only for counters recorded with
    ``replica=``.

    A task of duration ``d`` lands in the first bucket whose edge exceeds
    ``d``; the last bucket is open-ended.  For scheduler-run tasks the
    ``counts`` row sums equal the lane's ``per_lane`` completion count.
    Nodes with no local duration are the exceptions: ``promise`` nodes
    (e.g. cross-process results) count in ``per_lane`` but not in the
    histogram, and ``immediate`` values count in ``submitted``/
    ``completed`` only."""
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    cancelled: int = 0
    max_in_flight: int = 0
    idle_s: float = 0.0        # total worker time spent waiting for work
    busy_s: float = 0.0        # total worker time spent running tasks
    per_lane: dict = dataclasses.field(
        default_factory=lambda: {lane.name: 0 for lane in Lane})
    # per-task wall time, histogrammed by lane over HIST_EDGES_S buckets
    lane_hist: dict = dataclasses.field(
        default_factory=lambda: {lane.name: [0] * (len(HIST_EDGES_S) + 1)
                                 for lane in Lane})
    # serving-gateway counters (admitted/rejected/expired/..., paged-cache
    # hits, padded-slot tokens); open-keyed so the gateway can grow them
    serve: dict = dataclasses.field(default_factory=dict)
    # the same counters split per serve replica ({"0": {...}, "1": {...}})
    serve_replicas: dict = dataclasses.field(default_factory=dict)
    # per-request latency, histogrammed by phase over HIST_EDGES_S buckets
    request_hist: dict = dataclasses.field(
        default_factory=lambda: {p: [0] * (len(HIST_EDGES_S) + 1)
                                 for p in REQUEST_PHASES})

    def record_task(self, lane: "Lane", dt_s: float):
        self.lane_hist[lane.name][bisect.bisect_right(HIST_EDGES_S,
                                                      dt_s)] += 1

    def record_request_phase(self, phase: str, dt_s: float):
        """One request-latency sample: ``phase`` must be in
        ``REQUEST_PHASES``; ``dt_s`` buckets exactly like ``record_task``."""
        self.request_hist[phase][bisect.bisect_right(HIST_EDGES_S,
                                                     dt_s)] += 1

    def hist_lines(self) -> list[str]:
        """Human-readable per-lane wall-time histograms (non-empty lanes)."""
        labels = hist_labels()
        lines = []
        for lane, counts in self.lane_hist.items():
            if not sum(counts):
                continue
            cells = " ".join(f"{lb}:{c}" for lb, c in zip(labels, counts)
                             if c)
            lines.append(f"{lane:10s} {cells}")
        return lines

    def to_json(self) -> dict:
        """Serialize to the documented schema (see the class docstring);
        the histogram buckets carry their edges *and* labels so downstream
        reports never have to hard-code them."""
        out = dataclasses.asdict(self)
        hist = out.pop("lane_hist")
        out["lane_time_hist"] = {"edges_s": list(HIST_EDGES_S),
                                 "labels": hist_labels(),
                                 "counts": hist}
        req = out.pop("request_hist")
        out["request_latency_hist"] = {"edges_s": list(HIST_EDGES_S),
                                       "labels": hist_labels(),
                                       "counts": req}
        return out


def _is_future(x) -> bool:
    return isinstance(x, PhyFuture)


class PhyFuture:
    """A node of the futurized execution tree.

    Created by ``FuturizedGraph.defer`` / ``promise`` (and the
    combinators), never directly.  ``result()`` blocks the *caller*; the
    runtime itself only ever runs a node once every input has resolved.

    ``home`` is the locality rank a node's work was placed on by the
    distributed layer (``repro.distrib``); ``None`` for purely local
    nodes.  Placement reads it for data affinity.
    """

    __slots__ = ("_graph", "name", "lane", "home", "_fn", "_args",
                 "_kwargs", "_state", "_value", "_exc", "_ndeps",
                 "_dependents", "_callbacks", "_seq", "_promise",
                 "_kind", "_producer", "_observed", "_deps", "_fanout",
                 "__weakref__")

    def __init__(self, graph: "FuturizedGraph", fn: Optional[Callable],
                 args, kwargs, *, lane: Lane, name: str, seq: int,
                 kind: str = "task"):
        self._graph = graph
        self.name = name
        self.lane = lane
        self.home: Optional[int] = None
        self._fn = fn
        self._args = args
        self._kwargs = kwargs
        self._state = TaskState.PENDING
        self._value = None
        self._exc: Optional[BaseException] = None
        self._ndeps = 0
        self._dependents: list[PhyFuture] = []
        self._callbacks: list[Callable[["PhyFuture"], None]] = []
        self._seq = seq
        self._promise = False
        self._kind = kind         # task | promise | immediate | join
        self._producer = ""       # promise nodes: who committed to resolve it
        self._observed = False    # result()/exception()/done-callback seen
        self._fanout = 0          # dependents ever attached (never reset:
                                  # _dependents is consumed at retirement)
        self._deps: tuple = ()    # dependency seqs at submission (analysis)

    # -- inspection ---------------------------------------------------------
    @property
    def state(self) -> TaskState:
        return self._state

    def done(self) -> bool:
        return self._state in _TERMINAL

    def exception(self) -> Optional[BaseException]:
        """The task's exception, if it errored (blocks until terminal)."""
        self._observed = True
        self._graph._wait_terminal(self)
        return self._exc

    # -- consumption --------------------------------------------------------
    def result(self, timeout: Optional[float] = None):
        """Block the caller until resolved; raise the task's exception (or
        ``CancelledError``) if it did not complete."""
        self._observed = True
        self._graph._wait_terminal(self, timeout)
        if self._state is TaskState.DONE:
            return self._value
        if self._state is TaskState.CANCELLED:
            raise self._exc or CancelledError(self.name)
        raise self._exc

    def cancel(self) -> bool:
        """Cancel if not yet running; cancellation propagates to all
        transitive dependents.  Returns False once running/terminal."""
        return self._graph._cancel(self)

    def add_done_callback(self, cb: Callable[["PhyFuture"], None]):
        """Run ``cb(self)`` once terminal (immediately if already)."""
        fire = False
        self._observed = True
        with self._graph._lock:
            if self.done():
                fire = True
            else:
                self._callbacks.append(cb)
        if fire:
            cb(self)

    # -- external resolution (promise nodes only) ---------------------------
    def set_result(self, value) -> bool:
        """Resolve a ``FuturizedGraph.promise`` node with ``value``.

        Returns:
            True if this call resolved the node; False if it was already
            terminal (e.g. cancelled locally while the work was remote -
            late results are discarded, not an error).
        Raises:
            RuntimeError: on a non-promise node, whose value is owned by
                the scheduler.
        """
        if not self._promise:
            raise RuntimeError(f"{self.name!r} is not a promise node")
        with self._graph._lock:
            if self.done():
                return False
            self._graph._complete_locked(self, value=value)
            return True

    def set_exception(self, exc: BaseException, *,
                      cancelled: bool = False) -> bool:
        """Poison a ``FuturizedGraph.promise`` node (and, via the normal
        edge propagation, its transitive dependents) with ``exc``.

        Args:
            exc: the exception ``result()`` will raise.
            cancelled: record the node as CANCELLED rather than ERROR.
        Returns:
            True if this call poisoned the node; False if already terminal.
        Raises:
            RuntimeError: on a non-promise node.
        """
        if not self._promise:
            raise RuntimeError(f"{self.name!r} is not a promise node")
        with self._graph._lock:
            if self.done():
                return False
            self._graph._fail_locked(self, exc, cancelled=cancelled)
            return True

    def __repr__(self):
        return f"<PhyFuture {self.name!r} {self._state.value} lane={self.lane.name}>"


@dataclasses.dataclass
class InFlight:
    step: int
    outputs: Any


class FuturizedGraph:
    """Futurized execution tree: nodes run when their dependencies resolve,
    drained by worker threads in priority-lane order."""

    def __init__(self, max_workers: int = 4, name: str = "phyrax"):
        self.name = name
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)   # terminal transitions
        self._work = threading.Condition(self._lock)   # ready-queue pushes
        self._heap: list[tuple[int, int, PhyFuture]] = []
        self._seq = itertools.count()
        self._unfinished = 0          # nodes not yet terminal
        self._in_flight = 0           # nodes currently RUNNING
        self._stats = RuntimeStats()
        self._trace_hooks: list[Callable[[PhyFuture, tuple], None]] = []
        self._closed = False
        # analysis support: weak registry of every node (snapshot()), the
        # node each worker thread is running, and the per-thread blocked
        # waits the sanitizer's deadlock watchdog walks
        self._node_refs: list[weakref.ref] = []
        self._refs_hwm = 256
        self._running: dict[int, PhyFuture] = {}
        self._waits: dict[int, tuple[Optional[PhyFuture], float]] = {}
        self._workers = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"{name}-futures-{i}")
            for i in range(max(1, max_workers))]
        for t in self._workers:
            t.start()

    # -- task construction --------------------------------------------------
    def defer(self, fn: Callable, *args, lane: Lane = Lane.COMPUTE,
              name: str = "", **kwargs) -> PhyFuture:
        """Add a node running ``fn`` once every ``PhyFuture`` found (by
        pytree traversal) in ``args``/``kwargs`` has resolved.  Non-future
        leaves - including device arrays, which are already async under JAX
        - pass through untouched.

        Args:
            fn: host callable; runs on a worker thread with every future
                in its arguments replaced by that future's value.
            *args, **kwargs: arguments, searched for ``PhyFuture`` leaves
                by pytree traversal - each becomes a dependency edge.
            lane: priority lane the node drains in once READY.
            name: display name (defaults to ``fn.__name__``).
        Returns:
            The node's ``PhyFuture``.  If a dependency has already
            errored/cancelled, the node is created pre-poisoned.
        Raises:
            ValueError: a dependency belongs to a different graph.
            RuntimeError: the graph has been shut down.
        """
        deps = [x for x in jax.tree.leaves((args, kwargs), is_leaf=_is_future)
                if _is_future(x)]
        for d in deps:   # validate before touching any graph state
            if d._graph is not self:
                raise ValueError("dependency belongs to a different graph")
        with self._lock:
            if self._closed:
                raise RuntimeError(f"graph {self.name!r} is shut down")
            node = PhyFuture(self, fn, args, kwargs, lane=lane,
                             name=name or getattr(fn, "__name__", "task"),
                             seq=next(self._seq))
            node._deps = tuple(d._seq for d in deps)
            self._register_locked(node)
            self._stats.submitted += 1
            self._unfinished += 1
            poisoned: Optional[PhyFuture] = None
            for d in deps:
                d._fanout += 1
                if d._state is TaskState.DONE:
                    continue
                if d._state in _TERMINAL:      # errored / cancelled upstream
                    poisoned = d
                    break
                d._dependents.append(node)
                node._ndeps += 1
            if poisoned is not None:
                self._fail_locked(node, poisoned._exc
                                  or CancelledError(poisoned.name),
                                  cancelled=poisoned._state
                                  is TaskState.CANCELLED)
            elif node._ndeps == 0:
                self._enqueue_locked(node)
        self._notify_trace(node, tuple(deps))
        return node

    def immediate(self, value: Any, name: str = "immediate") -> PhyFuture:
        """An already-resolved future - wraps a value the caller computed
        synchronously so downstream nodes can depend on it by edge."""
        with self._lock:
            node = PhyFuture(self, None, (), {}, lane=Lane.COMPUTE,
                             name=name, seq=next(self._seq),
                             kind="immediate")
            node._state = TaskState.DONE
            node._value = value
            self._register_locked(node)
            self._stats.submitted += 1
            self._stats.completed += 1
        self._notify_trace(node, ())
        return node

    def promise(self, *, name: str = "promise",
                lane: Lane = Lane.COMPUTE, producer: str = "") -> PhyFuture:
        """An *externally resolved* node: HPX's promise.

        The returned future never runs on a worker; whoever holds it calls
        ``set_result`` / ``set_exception`` when the out-of-graph work (a
        result frame from another locality, an external callback) lands.
        Dependents hang edges off it exactly as off a deferred node, and
        ``barrier``/``shutdown`` wait for it, so an unresolved promise
        must always be fulfilled or poisoned by its creator.

        Args:
            name: display name.
            lane: lane recorded for stats/affinity (never scheduled).
            producer: who committed to resolving this promise (e.g.
                ``"L2"`` for a locality).  A promise with no producer is
                an orphan to the static linter (PHY002) and, if a wait
                stalls on one, to the runtime sanitizer (PHY101) - name
                the resolver whenever one exists.
        Returns:
            A PENDING ``PhyFuture`` resolvable from outside the graph.
        Raises:
            RuntimeError: the graph has been shut down.
        """
        with self._lock:
            if self._closed:
                raise RuntimeError(f"graph {self.name!r} is shut down")
            node = PhyFuture(self, None, (), {}, lane=lane, name=name,
                             seq=next(self._seq), kind="promise")
            node._promise = True
            node._producer = producer
            self._register_locked(node)
            self._stats.submitted += 1
            self._unfinished += 1
        self._notify_trace(node, ())
        return node

    # -- tracing hooks ------------------------------------------------------
    def add_trace_hook(self, cb: Callable[[PhyFuture, tuple], None]
                       ) -> Callable[[], None]:
        """Register ``cb(node, deps)``, fired for every node added to the
        graph (after submission, outside the scheduler lock) - the hook the
        frontend tracer uses to record the futurized tree as it is built.
        Returns a zero-arg function that unregisters the hook."""
        with self._lock:
            self._trace_hooks.append(cb)

        def remove():
            with self._lock:
                try:
                    self._trace_hooks.remove(cb)
                except ValueError:
                    pass
        return remove

    def _notify_trace(self, node: PhyFuture, deps: tuple):
        if not self._trace_hooks:
            return
        with self._lock:
            hooks = list(self._trace_hooks)
        for cb in hooks:
            try:
                cb(node, deps)
            except Exception:   # noqa: BLE001 - tracing must not kill callers
                pass

    # -- combinators --------------------------------------------------------
    def when_all(self, futures: Sequence[PhyFuture], *,
                 lane: Lane = Lane.COMPUTE, name: str = "when_all"
                 ) -> PhyFuture:
        """Future of the list of results, in input order.

        Args:
            futures: the inputs; an empty sequence resolves immediately
                with ``[]``.
            lane, name: as for ``defer``.
        Returns:
            A future of ``[f.result() for f in futures]``; any input's
            error or cancellation propagates to it (and onward).
        """
        futures = list(futures)
        return self.defer(lambda *vs: list(vs), *futures, lane=lane,
                          name=name)

    def when_any(self, futures: Sequence[PhyFuture], *, name: str = "when_any"
                 ) -> PhyFuture:
        """Resolves with ``(index, value)`` of the first future to complete
        successfully; errors only if *every* input fails or is cancelled.

        Args:
            futures: non-empty sequence of candidate futures.
        Returns:
            A future of ``(index, value)`` for the first success.
        Raises:
            ValueError: ``futures`` is empty.
        """
        futures = list(futures)
        if not futures:
            raise ValueError("when_any of no futures")
        with self._lock:
            node = PhyFuture(self, None, (), {}, lane=Lane.COMPUTE,
                             name=name, seq=next(self._seq), kind="join")
            node._deps = tuple(f._seq for f in futures)
            self._register_locked(node)
            self._stats.submitted += 1
            self._unfinished += 1
        self._notify_trace(node, tuple(futures))
        remaining = [len(futures)]

        def on_done(i: int, f: PhyFuture):
            with self._lock:
                if node.done():
                    return
                if f._state is TaskState.DONE:
                    self._complete_locked(node, value=(i, f._value))
                else:
                    remaining[0] -= 1
                    if remaining[0] == 0:   # every input failed/cancelled
                        self._fail_locked(
                            node, f._exc or CancelledError(f.name),
                            cancelled=f._state is TaskState.CANCELLED)

        for i, f in enumerate(futures):
            f.add_done_callback(lambda f, i=i: on_done(i, f))
        return node

    def tree_join(self, tree: Any, *, lane: Lane = Lane.COMPUTE,
                  name: str = "tree_join") -> PhyFuture:
        """Pytree-of-futures -> future-of-pytree (the tree of futures).

        Args:
            tree: any pytree; ``PhyFuture`` leaves become edges, other
                leaves pass through untouched.
            lane, name: as for ``defer``.
        Returns:
            A future of ``tree`` with every future leaf replaced by its
            value, resolved once the last leaf resolves; leaf errors and
            cancellations propagate.
        """
        leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_future)
        futs = [(i, x) for i, x in enumerate(leaves) if _is_future(x)]

        def rebuild(*vals):
            out = list(leaves)
            for (i, _), v in zip(futs, vals):
                out[i] = v
            return jax.tree.unflatten(treedef, out)

        return self.defer(rebuild, *[f for _, f in futs], lane=lane,
                          name=name)

    def gather(self, futures: Iterable[PhyFuture]) -> list:
        """Block the caller for all results (edge of the futurized world)."""
        return [f.result() for f in futures]

    # -- analysis support ---------------------------------------------------
    def _register_locked(self, node: PhyFuture):
        refs = self._node_refs
        refs.append(weakref.ref(node))
        if len(refs) >= self._refs_hwm:   # amortized O(1) compaction
            self._node_refs = [r for r in refs if r() is not None]
            self._refs_hwm = max(256, 2 * len(self._node_refs))

    def snapshot(self) -> list[dict]:
        """A consistent structural snapshot of every live node, for the
        static linter (``repro.analysis.lint.LintGraph.from_graph``).

        Returns:
            One dict per node still alive (non-terminal nodes are always
            strongly held by the scheduler; terminal ones only as long as
            someone holds their future), in submission order::

                {"seq": int, "name": str, "lane": "COMPUTE"|...,
                 "kind": "task"|"promise"|"immediate"|"join",
                 "state": "PENDING"|..., "producer": str,
                 "observed": bool, "fanout": int, "deps": (seq, ...)}

        ``fanout`` counts dependents ever attached - a collected
        dependent drops its edge from the snapshot, but not this count,
        so consumed nodes never read as dead (PHY004).
        """
        with self._lock:
            nodes = [n for n in (r() for r in self._node_refs)
                     if n is not None]
            return [{"seq": n._seq, "name": n.name, "lane": n.lane.name,
                     "kind": n._kind, "state": n._state.name,
                     "producer": n._producer, "observed": n._observed,
                     "fanout": n._fanout, "deps": n._deps} for n in nodes]

    # -- lifecycle ----------------------------------------------------------
    def barrier(self, timeout: Optional[float] = None):
        """Block until every submitted node is terminal."""
        with self._lock:
            if _san.active():
                self._sanitized_wait_locked(
                    lambda: self._unfinished == 0, None, timeout)
                return
            if not self._cond.wait_for(lambda: self._unfinished == 0,
                                       timeout):
                raise TimeoutError(
                    f"{self._unfinished} tasks still pending")

    def stats(self) -> RuntimeStats:
        with self._lock:
            return dataclasses.replace(
                self._stats, per_lane=dict(self._stats.per_lane),
                lane_hist={k: list(v)
                           for k, v in self._stats.lane_hist.items()},
                serve=dict(self._stats.serve),
                serve_replicas={k: dict(v) for k, v
                                in self._stats.serve_replicas.items()},
                request_hist={k: list(v)
                              for k, v in self._stats.request_hist.items()})

    def record_serve(self, *, phase: Optional[str] = None, dt_s: float = 0.0,
                     replica: Optional[int] = None, **counters: int):
        """Serving-gateway telemetry sink: bump ``stats().serve`` counters
        by the given keyword amounts and, when ``phase`` is set (one of
        ``REQUEST_PHASES``), add one ``dt_s`` sample to that per-request
        latency histogram.  With ``replica`` set the counters are also
        recorded under ``stats().serve_replicas[str(replica)]`` - the
        per-replica split the multi-replica gateway reports.  Thread-safe;
        callable from node bodies."""
        with self._lock:
            if phase is not None:
                self._stats.record_request_phase(phase, dt_s)
            per = (None if replica is None
                   else self._stats.serve_replicas.setdefault(
                       str(replica), {}))
            for k, v in counters.items():
                self._stats.serve[k] = self._stats.serve.get(k, 0) + int(v)
                if per is not None:
                    per[k] = per.get(k, 0) + int(v)

    def load(self) -> dict[str, int]:
        """Instantaneous queue pressure: ``{"ready": n, "running": n,
        "unfinished": n}``.  An elastic locality polls this to decide it
        is idle enough to post a ``steal_request`` (DESIGN.md §13)."""
        with self._lock:
            ready = sum(1 for _, _, n in self._heap
                        if n._state is TaskState.READY)
            return {"ready": ready, "running": self._in_flight,
                    "unfinished": self._unfinished}

    def shutdown(self, wait: bool = True, cancel_pending: bool = False):
        """Drain (or cancel) outstanding work, then stop the workers.
        With ``wait=True`` every pending node - including low-priority
        checkpoint I/O - completes before return: the shutdown barrier."""
        with self._lock:
            if cancel_pending:
                for _, _, node in list(self._heap):
                    self._cancel_locked(node)
        if wait:
            self.barrier()
        with self._lock:
            self._closed = True
            self._work.notify_all()
        for t in self._workers:
            t.join(timeout=5.0)

    # -- scheduler internals ------------------------------------------------
    def _enqueue_locked(self, node: PhyFuture):
        node._state = TaskState.READY
        heapq.heappush(self._heap, (int(node.lane), node._seq, node))
        self._work.notify()

    def _worker(self):
        while True:
            with self._lock:
                t0 = time.perf_counter()
                while not self._heap and not self._closed:
                    self._work.wait()
                self._stats.idle_s += time.perf_counter() - t0
                if not self._heap:          # closed and drained
                    return
                _, _, node = heapq.heappop(self._heap)
                if node._state is not TaskState.READY:  # lazily cancelled
                    continue
                node._state = TaskState.RUNNING
                self._running[threading.get_ident()] = node
                self._in_flight += 1
                self._stats.max_in_flight = max(self._stats.max_in_flight,
                                                self._in_flight)
                args, kwargs, fn = node._args, node._kwargs, node._fn

            def resolve(x):
                return x._value if _is_future(x) else x

            t1 = time.perf_counter()
            try:
                a, kw = jax.tree.map(resolve, (args, kwargs),
                                     is_leaf=_is_future)
                value = fn(*a, **kw)
            except BaseException as e:  # noqa: BLE001 - propagated to deps
                dt = time.perf_counter() - t1
                with self._lock:
                    self._running.pop(threading.get_ident(), None)
                    self._stats.busy_s += dt
                    self._stats.record_task(node.lane, dt)
                    self._in_flight -= 1
                    self._fail_locked(node, e)
            else:
                dt = time.perf_counter() - t1
                with self._lock:
                    self._running.pop(threading.get_ident(), None)
                    self._stats.busy_s += dt
                    self._stats.record_task(node.lane, dt)
                    self._in_flight -= 1
                    self._complete_locked(node, value=value)

    def _complete_locked(self, node: PhyFuture, *, value: Any):
        node._state = TaskState.DONE
        node._value = value
        node._fn = node._args = node._kwargs = None
        self._stats.completed += 1
        self._stats.per_lane[node.lane.name] += 1
        self._unfinished -= 1
        for d in node._dependents:
            if d._state is not TaskState.PENDING:
                continue
            d._ndeps -= 1
            if d._ndeps == 0:
                self._enqueue_locked(d)
        self._finish_locked(node)

    def _fail_locked(self, node: PhyFuture, exc: BaseException,
                     cancelled: bool = False):
        """Mark ``node`` failed/cancelled and poison all transitive
        dependents - constraint-based sync also for the error path."""
        work = [node]
        while work:
            n = work.pop()
            if n._state in _TERMINAL:
                continue
            n._state = (TaskState.CANCELLED if cancelled
                        else TaskState.ERROR)
            n._exc = exc
            n._fn = n._args = n._kwargs = None
            if cancelled:
                self._stats.cancelled += 1
            else:
                self._stats.failed += 1
            self._unfinished -= 1
            work.extend(n._dependents)
            self._finish_locked(n)

    def _finish_locked(self, node: PhyFuture):
        cbs, node._callbacks = node._callbacks, []
        deps = node._dependents
        node._dependents = []
        del deps
        self._cond.notify_all()
        for cb in cbs:
            try:
                cb(node)
            except Exception:   # noqa: BLE001 - callbacks must not kill workers
                pass

    def _cancel(self, node: PhyFuture) -> bool:
        with self._lock:
            return self._cancel_locked(node)

    def _cancel_locked(self, node: PhyFuture) -> bool:
        if node._state not in (TaskState.PENDING, TaskState.READY):
            return False
        self._fail_locked(node, CancelledError(node.name), cancelled=True)
        return True

    def _wait_terminal(self, node: PhyFuture,
                       timeout: Optional[float] = None):
        with self._lock:
            if _san.active():
                self._sanitized_wait_locked(node.done, node, timeout)
                return
            if not self._cond.wait_for(node.done, timeout):
                raise TimeoutError(f"task {node.name!r} still "
                                   f"{node._state.value}")

    # -- sanitizer: deadlock watchdog (DESIGN.md §12) ------------------------
    def _sanitized_wait_locked(self, pred: Callable[[], bool],
                               node: Optional[PhyFuture],
                               timeout: Optional[float]):
        """Chunked condition wait that registers itself in the wait-for
        graph and periodically runs the deadlock scan; raises
        ``sanitize.DeadlockError`` on a provable non-progress state
        instead of hanging.  ``node`` is None for ``barrier()`` (waiting
        on *every* unfinished node)."""
        cfg = _san.config()
        ident = threading.get_ident()
        t0 = time.monotonic()
        deadline = None if timeout is None else t0 + timeout
        self._waits[ident] = (node, t0)
        try:
            while not pred():
                now = time.monotonic()
                if deadline is not None and now >= deadline:
                    what = (f"task {node.name!r} still {node._state.value}"
                            if node is not None else
                            f"{self._unfinished} tasks still pending")
                    raise TimeoutError(what)
                step = cfg.chunk if deadline is None else min(
                    cfg.chunk, deadline - now)
                if self._cond.wait_for(pred, step):
                    return
                waited = time.monotonic() - t0
                if waited >= cfg.deadlock_after:
                    self._watchdog_locked(node, waited, cfg)
        finally:
            self._waits.pop(ident, None)

    def _watchdog_locked(self, node: Optional[PhyFuture], waited: float,
                         cfg) -> None:
        """One deadlock scan over the wait-for graph; raises on proof.

        Vertices are ``("T", thread_ident)`` and ``("N", node_seq)``.
        Edges: a blocked thread -> the node(s) it waits on; a PENDING
        node -> its unresolved deps; a RUNNING node -> its worker thread
        *if that thread is itself blocked*; a READY node -> every blocked
        worker, but only when ALL workers are blocked (otherwise a free
        worker will drain it - progress).  A cycle reachable from the
        calling thread can never resolve -> raise.  Separately, if the
        wait has outlived ``orphan_after`` and every reachable frontier
        leaf is an unproduced promise, nothing inside the process can
        make progress either -> raise (PHY101 both ways)."""
        alive = {n._seq: n for n in (r() for r in self._node_refs)
                 if n is not None and not n.done()}
        edges: dict = {}
        by_seq_running = {id(rn): tid for tid, rn in self._running.items()}
        worker_idents = {t.ident for t in self._workers}
        blocked_workers = [i for i in worker_idents if i in self._waits]
        all_workers_blocked = (len(blocked_workers) == len(self._workers))
        for tid, (wnode, _) in self._waits.items():
            if wnode is None:   # barrier: waits on every unfinished node
                edges[("T", tid)] = tuple(("N", s) for s in alive)
            elif not wnode.done():
                edges[("T", tid)] = (("N", wnode._seq),)
        for seq, n in alive.items():
            if n._state is TaskState.PENDING and not n._promise:
                edges[("N", seq)] = tuple(
                    ("N", s) for s in n._deps
                    if s in alive)
            elif n._state is TaskState.READY and all_workers_blocked:
                edges[("N", seq)] = tuple(
                    ("T", i) for i in blocked_workers)
            elif n._state is TaskState.RUNNING:
                tid = by_seq_running.get(id(n))
                if tid is not None and tid in self._waits:
                    edges[("N", seq)] = (("T", tid),)
        root = ("T", threading.get_ident())
        cycle = _san.find_cycle(edges, (root,))
        if cycle is not None:
            names = [self._vertex_name(v, alive) for v in cycle]
            idents = tuple(v[1] for v in cycle if v[0] == "T")
            detail = (" -> ".join(names) + " -> (cycle)\n"
                      + _san.thread_stacks(idents))
            _san.get().record(
                "PHY101", f"deadlock: wait-for cycle in graph "
                f"{self.name!r} after {waited:.1f}s", detail=detail,
                once_key=f"cycle:{self.name}:{names[0]}")
            raise _san.DeadlockError(
                f"PHY101 deadlock in graph {self.name!r}: "
                + " -> ".join(names) + " -> (cycle)\n" + detail)
        if waited < cfg.orphan_after:
            return
        # reachability: is every frontier leaf an unproduced promise?
        seen = {root}
        frontier: list[PhyFuture] = []
        progress = False
        stack = [root]
        while stack:
            v = stack.pop()
            nbrs = edges.get(v, ())
            if not nbrs and v[0] == "N":
                n = alive.get(v[1])
                if n is None:
                    continue
                if n._promise:
                    frontier.append(n)
                else:           # READY with a free worker / RUNNING free
                    progress = True
            for w in nbrs:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        if progress or not frontier:
            return
        if any(n._producer for n in frontier):
            # a declared producer means out-of-process work may still
            # land; only an all-unproduced frontier is provably stuck
            return
        names = ", ".join(f"{n.name!r} (no producer)" for n in frontier)
        detail = _san.thread_stacks(tuple(
            t for t in self._waits))
        _san.get().record(
            "PHY101", f"stalled wait in graph {self.name!r}: every "
            f"progress path ends in an unresolved promise ({names}) "
            f"after {waited:.1f}s", detail=detail,
            once_key=f"stall:{self.name}")
        raise _san.DeadlockError(
            f"PHY101 stalled wait in graph {self.name!r}: every progress "
            f"path ends in an unresolved promise ({names}); waited "
            f"{waited:.1f}s\n{detail}")

    @staticmethod
    def _vertex_name(v: tuple, alive: dict) -> str:
        if v[0] == "T":
            for t in threading.enumerate():
                if t.ident == v[1]:
                    return f"thread[{t.name}]"
            return f"thread[{v[1]}]"
        n = alive.get(v[1])
        return (f"{n.name}({n._state.value})" if n is not None
                else f"node[{v[1]}]")


class Pipeline:
    """Keep up to ``depth`` device steps in flight (constraint-based sync:
    block only when the pipeline is full, never earlier).  This is the
    device-side complement of ``FuturizedGraph``: XLA programs are already
    async-dispatched, so the only host obligation is to bound how far the
    host may run ahead (donation safety + host memory)."""

    def __init__(self, depth: int = 2):
        self.depth = depth
        self._q: collections.deque[InFlight] = collections.deque()

    def __len__(self) -> int:
        return len(self._q)

    def push(self, step: int, outputs: Any) -> InFlight | None:
        """Register async outputs of a step; returns the retired step whose
        results are now forced (or None while the pipeline fills)."""
        self._q.append(InFlight(step, outputs))
        if len(self._q) > self.depth:
            oldest = self._q.popleft()
            jax.block_until_ready(oldest.outputs)
            return oldest
        return None

    def drain(self) -> list[InFlight]:
        out = list(self._q)
        self._q.clear()
        for item in out:
            jax.block_until_ready(item.outputs)
        return out
