"""Host-side futurized execution (paper's futurization, where dynamism lives).

Phylanx turns user code into a futurized execution tree scheduled by HPX.
Under XLA the *device* dataflow is compiled ahead of time (see DESIGN.md §2),
but the host side of a training/serving loop retains real asynchrony: JAX
dispatch is async, transfers/saves can proceed concurrently, and several
steps can be kept in flight.  This module gives that a Phylanx-flavoured
API: ``defer`` builds a DAG of host tasks whose inputs may be device arrays
(already-async) or other futures; ``Pipeline`` keeps N steps in flight with
donation, which is how the training loop overlaps data loading, compute and
checkpoint I/O.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Iterable

import jax


class PhyFuture:
    """A future over host work; device arrays pass through untouched
    (they are already futures under JAX's async dispatch)."""

    __slots__ = ("_f",)

    def __init__(self, f: Future):
        self._f = f

    def result(self):
        return self._f.result()

    def done(self) -> bool:
        return self._f.done()


class FuturizedGraph:
    """Tiny futurized execution tree: nodes run when dependencies resolve."""

    def __init__(self, max_workers: int = 4):
        self._pool = ThreadPoolExecutor(max_workers=max_workers)

    def defer(self, fn: Callable, *args, **kwargs) -> PhyFuture:
        def run():
            a = [x.result() if isinstance(x, PhyFuture) else x for x in args]
            kw = {k: (v.result() if isinstance(v, PhyFuture) else v)
                  for k, v in kwargs.items()}
            return fn(*a, **kw)
        return PhyFuture(self._pool.submit(run))

    def gather(self, futures: Iterable[PhyFuture]) -> list:
        return [f.result() for f in futures]

    def shutdown(self):
        self._pool.shutdown(wait=True)


@dataclasses.dataclass
class InFlight:
    step: int
    outputs: Any


class Pipeline:
    """Keep up to ``depth`` device steps in flight (constraint-based sync:
    block only when the pipeline is full, never earlier)."""

    def __init__(self, depth: int = 2):
        self.depth = depth
        self._q: collections.deque[InFlight] = collections.deque()

    def push(self, step: int, outputs: Any) -> InFlight | None:
        """Register async outputs of a step; returns the retired step whose
        results are now forced (or None while the pipeline fills)."""
        self._q.append(InFlight(step, outputs))
        if len(self._q) > self.depth:
            oldest = self._q.popleft()
            jax.block_until_ready(oldest.outputs)
            return oldest
        return None

    def drain(self) -> list[InFlight]:
        out = list(self._q)
        self._q.clear()
        for item in out:
            jax.block_until_ready(item.outputs)
        return out
