"""Named asynchronous collectives and communication schedules.

Maps Phylanx's asynchronous active-messaging collectives onto jax.lax
collectives (asynchronous-by-construction under XLA's latency-hiding
scheduler) plus explicitly scheduled variants built from collective_permute
for the cases where we control the schedule ourselves (ring pipelines, halo
exchange, flash-decoding split-KV combines).

Everything here is usable inside ``jax.shard_map`` bodies; the top-level
pjit path instead relies on the SPMD partitioner inserting the equivalent
ops from sharding constraints.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import compat


# ---------------------------------------------------------------------------
# Fused collectives over pytrees (tensor fusion applied to collectives)
# ---------------------------------------------------------------------------
def fused_psum(tree, axis_name, cap_bytes: int = 32 * 1024 * 1024):
    """All-reduce a pytree in dtype-homogeneous fused buckets (paper R5)."""
    from . import fusion
    return fusion.fused_apply(tree, lambda b: lax.psum(b, axis_name), cap_bytes)


def fused_pmean(tree, axis_name, cap_bytes: int = 32 * 1024 * 1024):
    from . import fusion
    return fusion.fused_apply(tree, lambda b: lax.pmean(b, axis_name), cap_bytes)


def naive_psum(tree, axis_name):
    """Horovod-baseline: one all-reduce per tensor, no fusion (Fig. 1)."""
    return jax.tree.map(lambda g: lax.psum(g, axis_name), tree)


def reduce_scatter(x: jax.Array, axis_name: str, *, axis: int = 0):
    """psum_scatter with tiling (ZeRO-style gradient shard)."""
    return lax.psum_scatter(x, axis_name, scatter_dimension=axis, tiled=True)


def all_gather(x: jax.Array, axis_name: str, *, axis: int = 0):
    return compat.all_gather(x, axis_name, axis=axis, tiled=True)


# ---------------------------------------------------------------------------
# Explicit ring schedules (collective_permute based)
# ---------------------------------------------------------------------------
def _ring_perm(n: int, step: int = 1):
    return [(i, (i + step) % n) for i in range(n)]


def ring_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Bandwidth-optimal ring all-reduce written as reduce-scatter +
    all-gather over collective_permute steps.

    This is the schedule Horovod's ring_allreduce and Phylanx's asynchronous
    collectives both lower to; having it explicit lets the pipeline examples
    overlap each hop with compute and lets tests count hops.
    """
    n = compat.axis_size(axis_name)
    if n == 1:
        return x
    if x.size % n != 0:  # fallback for indivisible payloads
        return lax.psum(x, axis_name)
    flat = x.reshape(n, -1)
    idx = lax.axis_index(axis_name)

    # reduce-scatter phase: at step s each rank sends its accumulated
    # chunk (idx - s) % n to the right neighbour; after n-1 hops rank r
    # holds the fully reduced chunk (r + 1) % n.
    send = lax.dynamic_index_in_dim(flat, idx % n, 0, keepdims=False)
    for s in range(n - 1):
        recv = lax.ppermute(send, axis_name, _ring_perm(n, +1))
        c = (idx - s - 1) % n
        send = lax.dynamic_index_in_dim(flat, c, 0, keepdims=False) + recv

    # all-gather phase: row r of the gather holds chunk (r+1)%n, so chunk i
    # lives at row (i-1)%n.
    full = compat.all_gather(send, axis_name, axis=0, tiled=False)
    order = (jnp.arange(n) - 1) % n
    return full[order].reshape(x.shape)


def halo_exchange(x: jax.Array, axis_name: str, halo: int, *, dim: int = 0):
    """Overlapped tiling (paper: spatial parallelism halo exchange).

    Each shard sends its ``halo`` boundary slices to both neighbours and
    returns the tile extended with received ghost cells (edge shards are
    zero-padded: non-periodic boundary).
    """
    n = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    lo = lax.slice_in_dim(x, 0, halo, axis=dim)
    hi = lax.slice_in_dim(x, x.shape[dim] - halo, x.shape[dim], axis=dim)
    from_left = lax.ppermute(hi, axis_name, _ring_perm(n, +1))    # rank i-1's hi
    from_right = lax.ppermute(lo, axis_name, _ring_perm(n, -1))   # rank i+1's lo
    zeros = jnp.zeros_like(lo)
    from_left = jnp.where(idx == 0, zeros, from_left)
    from_right = jnp.where(idx == n - 1, zeros, from_right)
    return jnp.concatenate([from_left, x, from_right], axis=dim)


# ---------------------------------------------------------------------------
# Flash-decoding split-KV combine (long-context decode over sharded KV)
# ---------------------------------------------------------------------------
def softmax_combine(partials: tuple[jax.Array, jax.Array, jax.Array],
                    axis_name: str):
    """Combine per-shard (m, l, o) softmax partials across a sharded KV axis.

    m: running max [...,1], l: running denominator [...,1], o: weighted
    values [...,d].  Exact merge of block-local softmaxes; communication is
    two small psums + one psum over o - O(d) per token instead of an O(S)
    all-gather of the KV cache.
    """
    m, l, o = partials
    m_glob = lax.pmax(m, axis_name)
    scale = jnp.exp(m - m_glob)
    l_glob = lax.psum(l * scale, axis_name)
    o_glob = lax.psum(o * scale, axis_name)
    return o_glob / jnp.maximum(l_glob, 1e-30)


# ---------------------------------------------------------------------------
# Pipeline (GPipe-style) primitives
# ---------------------------------------------------------------------------
def pipeline_shift(x: jax.Array, axis_name: str, *, reverse: bool = False):
    """Hand activations (or grads, reverse) to the neighbouring stage."""
    n = compat.axis_size(axis_name)
    return lax.ppermute(x, axis_name, _ring_perm(n, -1 if reverse else 1))
