"""Tensor fusion: coalesce small tensors into capped buckets (paper R5).

Phylanx "runtime-adaptively coalesces messages into larger units (tensor
fusion) ... which further reduces the latencies and overheads caused by the
necessary communication operations".  The same trick appears as gradient
bucketing in PyTorch-DDP and tensor fusion in Horovod; the paper's point is
that it must be *integrated* into the framework (unified, R6) rather than
bolted on through proxies.

Here the fusion plan is a pure-JAX transformation: a pytree of tensors is
flattened into a small number of 1-D buffers, each at most ``cap_bytes``
large and dtype-homogeneous, so one collective per buffer replaces one
collective per tensor.  Pack/unpack are reshape/concat/slice only, so they
fuse into the surrounding XLA program and cost ~no extra HBM traffic beyond
the copy into the fused buffer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class _Entry:
    index: int                 # position in flattened tree
    shape: tuple[int, ...]
    size: int
    offset: int                # offset inside its bucket


@dataclasses.dataclass(frozen=True)
class Bucket:
    dtype: Any
    entries: tuple[_Entry, ...]
    total: int                 # elements (unpadded)
    padded: int = 0            # elements incl. shard-divisibility padding

    @property
    def nbytes(self) -> int:
        return max(self.total, self.padded) * jnp.dtype(self.dtype).itemsize

    @property
    def size(self) -> int:
        return max(self.total, self.padded)


@dataclasses.dataclass(frozen=True)
class FusionPlan:
    treedef: Any
    buckets: tuple[Bucket, ...]
    n_leaves: int

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)


def make_plan(tree, cap_bytes: int = 32 * 1024 * 1024,
              pad_to: int = 1) -> FusionPlan:
    """Greedy first-fit bucketing in flatten order, per dtype.

    Keeping flatten order (rather than size-sorting) preserves the backward-
    pass readiness order: gradients produced late in the backward (early
    layers) land in late buckets, so each bucket's collective can launch as
    soon as its last member is produced - the overlap property PyTorch-DDP
    relies on and the paper's async-collective requirement (R3).
    """
    leaves, treedef = jax.tree.flatten(tree)
    open_buckets: dict[Any, list] = {}     # dtype -> [entries, total]
    done: list[Bucket] = []

    def _close(dt):
        entries, total = open_buckets.pop(dt)
        padded = ((total + pad_to - 1) // pad_to) * pad_to
        done.append(Bucket(dt, tuple(entries), total, padded))

    for i, leaf in enumerate(leaves):
        dt = jnp.dtype(leaf.dtype)
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        item = dt.itemsize
        if dt in open_buckets and (open_buckets[dt][1] + size) * item > cap_bytes:
            _close(dt)
        if dt not in open_buckets:
            open_buckets[dt] = [[], 0]
        entries, total = open_buckets[dt]
        entries.append(_Entry(i, tuple(leaf.shape), size, total))
        open_buckets[dt][1] = total + size
    for dt in list(open_buckets):
        _close(dt)
    return FusionPlan(treedef=treedef, buckets=tuple(done), n_leaves=len(leaves))


def pack(tree, plan: FusionPlan) -> list[jax.Array]:
    """Pytree -> list of fused 1-D buffers (one per bucket)."""
    leaves = jax.tree.leaves(tree)
    assert len(leaves) == plan.n_leaves
    out = []
    for b in plan.buckets:
        parts = [jnp.ravel(leaves[e.index]).astype(b.dtype) for e in b.entries]
        if b.padded > b.total:
            parts.append(jnp.zeros((b.padded - b.total,), b.dtype))
        out.append(jnp.concatenate(parts) if len(parts) > 1 else parts[0])
    return out


def unpack(buffers: Sequence[jax.Array], plan: FusionPlan):
    """List of fused buffers -> pytree with original shapes."""
    assert len(buffers) == plan.n_buckets
    leaves: list = [None] * plan.n_leaves
    for buf, b in zip(buffers, plan.buckets):
        for e in b.entries:
            leaves[e.index] = jax.lax.dynamic_slice_in_dim(
                buf, e.offset, e.size).reshape(e.shape)
    return jax.tree.unflatten(plan.treedef, leaves)


def fused_apply(tree, fn: Callable[[jax.Array], jax.Array],
                cap_bytes: int = 32 * 1024 * 1024):
    """Apply ``fn`` (e.g. a collective) per fused bucket instead of per leaf."""
    plan = make_plan(tree, cap_bytes)
    return unpack([fn(b) for b in pack(tree, plan)], plan)


def collective_stats(tree, cap_bytes: int) -> dict:
    """Napkin-math readout: collectives saved by fusion (for logs/tests)."""
    leaves = jax.tree.leaves(tree)
    plan = make_plan(tree, cap_bytes)
    return {
        "tensors": len(leaves),
        "buckets": plan.n_buckets,
        "bytes": int(sum(b.nbytes for b in plan.buckets)),
        "launches_saved": len(leaves) - plan.n_buckets,
    }
