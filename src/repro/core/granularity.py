"""Runtime-adaptive grain-size control (paper R4).

Phylanx adapts task grain size and message coalescing at runtime to maximise
utilisation.  On a TPU the knobs with the same effect are chosen per compile
from static shape/mesh arithmetic instead of per task at runtime:

  * gradient-fusion bucket bytes        (tensor fusion cap, R5)
  * microbatch count                    (pipeline / gradient accumulation)
  * remat (activation checkpoint) policy
  * flash-attention / kernel block shapes

``GrainPolicy.derive`` does the napkin math: it balances per-collective fixed
latency against the bandwidth cost of delaying overlap (bigger buckets start
later), and activation memory against recompute FLOPs.  Every decision is
returned with the numbers that produced it so logs/EXPERIMENTS.md can show
*why* a grain was picked - the paper's "runtime-adaptive" requirement made
auditable.
"""
from __future__ import annotations

import dataclasses
from typing import Any

# TPU v5e model constants (per chip) - same numbers as the roofline.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
ICI_LINKS = 3                # usable links/chip in a 2/3-D torus
COLL_LATENCY = 5e-6          # per-collective launch+sync latency (s), per hop


@dataclasses.dataclass(frozen=True)
class GrainDecision:
    bucket_bytes: int
    n_microbatches: int
    remat: str                     # "none" | "block" | "full"
    attn_block_q: int
    attn_block_kv: int
    rationale: dict[str, Any]


class GrainPolicy:
    """Derive grain sizes from (model stats, mesh, shape) napkin math."""

    @staticmethod
    def bucket_bytes(total_grad_bytes: int, n_tensors: int, dp_degree: int,
                     backward_time_s: float) -> int:
        """Pick the fusion cap.

        Cost model for DP all-reduce of G bytes in k buckets overlapped with
        a backward pass of duration T:
          exposed = max(0, G*2(n-1)/n / BW_wire - T*(k-1)/k) + k * lat * hops
        Larger k hides more (first bucket launches earlier) but pays k
        latencies.  We approximate the optimum by matching per-bucket wire
        time to ~4x collective latency, clamped to [1 MiB, 64 MiB].
        """
        if dp_degree <= 1 or total_grad_bytes == 0:
            return max(total_grad_bytes, 1)
        wire_bw = ICI_BW * ICI_LINKS
        hops = dp_degree - 1
        target = 4.0 * COLL_LATENCY * hops * wire_bw / max(2 * (dp_degree - 1) / dp_degree, 1e-9)
        cap = int(min(max(target, 1 << 20), 64 << 20))
        # never fewer than 2 buckets if there is anything to overlap
        if total_grad_bytes > cap and total_grad_bytes // cap < 2:
            cap = total_grad_bytes // 2 + 1
        return cap

    @staticmethod
    def microbatches(global_batch: int, dp_degree: int, seq: int, d_model: int,
                     n_layers: int, hbm_bytes: float = 16e9,
                     per_act_bytes: int = 2) -> int:
        """Split the per-replica batch until checkpointed activations fit."""
        local_b = max(global_batch // max(dp_degree, 1), 1)
        act = local_b * seq * d_model * per_act_bytes * n_layers  # 1 residual/layer
        n = 1
        while act / n > 0.25 * hbm_bytes and n < local_b:
            n *= 2
        return min(n, local_b)

    @staticmethod
    def remat_policy(n_layers: int, d_model: int, seq: int, local_batch: int,
                     hbm_bytes: float = 16e9) -> str:
        full_acts = n_layers * local_batch * seq * d_model * 2 * 12  # ~12 tensors/block
        if full_acts < 0.3 * hbm_bytes:
            return "none"
        return "block"

    @staticmethod
    def attn_blocks(seq: int, head_dim: int) -> tuple[int, int]:
        """Flash-attention tile shapes: MXU-aligned, VMEM-bounded.

        VMEM ~= 64 MiB usable/2 for double buffering; working set per tile is
        (bq*d + bkv*d*2 + bq*bkv) * 4B.  128 alignment for the MXU.
        """
        bq = 128 if seq >= 128 else max(8, seq)
        bkv = 128
        while (bq * head_dim + 2 * bkv * head_dim + bq * bkv) * 4 < 8 << 20 and bkv < min(seq, 2048):
            bkv *= 2
        bkv = min(bkv, max(seq, 128))
        return bq, bkv

    @classmethod
    def derive(cls, *, n_params: int, n_tensors: int, global_batch: int,
               seq: int, d_model: int, n_layers: int, head_dim: int,
               dp_degree: int, grad_bytes_per_param: int = 2) -> GrainDecision:
        grad_bytes = n_params * grad_bytes_per_param
        # rough backward time: 4N*D flops (bwd ~2x fwd) at 40% MFU
        tokens = global_batch * seq
        bwd_t = 4 * n_params * tokens / max(dp_degree, 1) / (0.4 * PEAK_FLOPS)
        cap = cls.bucket_bytes(grad_bytes, n_tensors, dp_degree, bwd_t)
        micro = cls.microbatches(global_batch, dp_degree, seq, d_model, n_layers)
        remat = cls.remat_policy(n_layers, d_model, seq,
                                 max(global_batch // max(dp_degree, 1), 1))
        bq, bkv = cls.attn_blocks(seq, head_dim)
        return GrainDecision(
            bucket_bytes=cap, n_microbatches=micro, remat=remat,
            attn_block_q=bq, attn_block_kv=bkv,
            rationale={
                "grad_bytes": grad_bytes, "est_backward_s": bwd_t,
                "dp_degree": dp_degree, "n_tensors": n_tensors,
                "tokens": tokens,
            })
