"""phyrax core: the paper's infrastructure contribution, composable.

Modules:
  sharding     - divisibility-aware tiling plans (logical dims -> mesh axes)
  dist_array   - tiled arrays with whole-array metadata + overlapped tiling
  collectives  - named async collectives, ring schedules, halo exchange
  fusion       - tensor fusion (capped collective buckets)
  granularity  - runtime-adaptive grain-size policy
  futures      - host-side futurized execution / in-flight step pipeline
  paging       - page-pool allocator + paged per-request inference cache
  resilience   - replay / replicate+consensus / checksums
  overlap      - communication/computation overlap strategies (DP schedules)
  steps        - train/prefill/decode step builders
"""
from . import (  # noqa: F401
    sharding, fusion, collectives, granularity, futures, paging, resilience,
)
