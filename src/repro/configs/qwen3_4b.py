"""qwen3-4b [dense]: qk_norm + GQA [hf:Qwen/Qwen3-8B family config]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab=151936,
    qk_norm=True, norm="rms", mlp_kind="swiglu", rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B",
)
