"""ArchConfig: declarative architecture description (paper R8 - the user
describes the network; distribution is the framework's job)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16, "f16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | xlstm | zamba | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # attention details
    norm: str = "rms"                # rms | ln
    mlp_kind: str = "swiglu"         # swiglu | gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    use_rope: bool = True
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None
    q_chunk: int = 1024
    kv_chunk: int = 1024

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_group: int = 512
    moe_dispatch: str = "einsum"     # einsum (GShard baseline) | sort (opt)
    capacity_factor: float = 1.25

    # SSM / recurrent
    expand: int = 2
    ssm_head_dim: int = 64
    ssm_state: int = 64
    ssm_groups: int = 1
    ssm_d_conv: int = 4
    ssm_chunk: int = 256
    slstm_every: int = 8             # xlstm: 1 sLSTM per this many layers
    slstm_heads: int = 4
    shared_every: int = 6            # zamba: shared attn block cadence

    # encoder-decoder
    n_enc_layers: int = 0
    enc_frames: int = 1500           # stub audio frontend output length
    max_dec_len: int = 65536

    # decode cache write: "dus" (dynamic-update-slice) or "masked"
    # (iota-mask select: no resharding when the seq dim is sharded)
    cache_update: str = "dus"

    # numerics
    param_dtype: str = "f32"
    compute_dtype: str = "bf16"
    cache_dtype_str: str = "bf16"

    # stacking / remat
    scan_layers: bool = True
    remat: bool = True

    # metadata
    source: str = ""
    aux_weight: float = 0.01
    subquadratic: bool = False       # eligible for long_500k

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- dtypes ---------------------------------------------------------------
    @property
    def p_dtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def c_dtype(self):
        return _DTYPES[self.compute_dtype]

    @property
    def cache_dtype(self):
        return _DTYPES[self.cache_dtype_str]

    # -- parameter counts (for 6ND roofline bookkeeping) ----------------------
    def _layer_params(self) -> tuple[int, int]:
        """(total, active) params per layer."""
        d, ff = self.d_model, self.d_ff
        attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.family in ("dense", "encdec"):
            mlp_mults = 3 if self.mlp_kind == "swiglu" else 2
            return attn + mlp_mults * d * ff, attn + mlp_mults * d * ff
        if self.family == "moe":
            router = d * self.n_experts
            expert = 3 * d * ff
            tot = attn + router + self.n_experts * expert
            act = attn + router + self.top_k * expert
            return tot, act
        if self.family == "xlstm":
            d_in = self.expand * d
            m = d * 2 * d_in + 3 * d_in * d_in + d_in * d
            return m, m
        if self.family == "zamba":
            d_in = self.expand * d
            H = d_in // self.ssm_head_dim
            gn = self.ssm_groups * self.ssm_state
            mamba = d * (2 * d_in + 2 * gn + H) + d_in * d
            return mamba, mamba
        raise ValueError(self.family)

    def n_params(self) -> tuple[int, int]:
        """(total, active) including embeddings."""
        tot, act = self._layer_params()
        n_l = self.n_layers + self.n_enc_layers
        tot, act = tot * n_l, act * n_l
        if self.family == "zamba":
            # shared transformer block, one copy
            d, ff = self.d_model, self.d_ff
            attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
            shared = attn + 3 * d * ff
            tot += shared
            act += shared * (self.n_layers // self.shared_every)
        emb = self.vocab * self.d_model * 2   # embed + unembed
        return tot + emb, act + emb

    # -- reductions for smoke tests -------------------------------------------
    def tiny(self) -> "ArchConfig":
        changes = dict(
            n_layers=min(self.n_layers, 4 if self.family in ("xlstm", "zamba")
                         else 2),
            d_model=128, n_heads=4, head_dim=32,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256, vocab=512,
            q_chunk=64, kv_chunk=64, ssm_chunk=32, moe_group=64,
            expand=2, ssm_head_dim=32, ssm_state=16, slstm_heads=2,
            compute_dtype="f32", cache_dtype_str="f32",
        )
        if self.family == "moe":
            changes.update(n_experts=min(self.n_experts, 4),
                           top_k=min(self.top_k, 2))
        if self.family == "xlstm":
            changes.update(n_layers=4, slstm_every=4)
        if self.family == "zamba":
            changes.update(n_layers=4, shared_every=2)
        if self.family == "encdec":
            changes.update(n_enc_layers=2, enc_frames=16)
        return dataclasses.replace(self, **changes)
