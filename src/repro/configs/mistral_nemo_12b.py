"""mistral-nemo-12b [dense]: 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].
head_dim 128 is explicit (32 x 128 != 5120)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072,
    norm="rms", mlp_kind="swiglu", rope_theta=1e6,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
