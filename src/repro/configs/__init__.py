"""Architecture registry: --arch <id> resolves here."""
from .base import ArchConfig  # noqa: F401

from . import (chameleon_34b, granite_moe_1b, phi35_moe, xlstm_350m,
               whisper_medium, mistral_nemo_12b, qwen3_4b, qwen25_3b,
               phi3_mini, zamba2_27b)

REGISTRY = {m.CONFIG.name: m.CONFIG for m in (
    chameleon_34b, granite_moe_1b, phi35_moe, xlstm_350m, whisper_medium,
    mistral_nemo_12b, qwen3_4b, qwen25_3b, phi3_mini, zamba2_27b)}

ARCH_IDS = sorted(REGISTRY)


def get_config(name: str, *, tiny: bool = False) -> ArchConfig:
    cfg = REGISTRY[name]
    return cfg.tiny() if tiny else cfg


# the paper's own benchmark input shapes (Fig. 1)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}
