"""xlstm-350m [ssm]: sLSTM + mLSTM blocks, ratio 7:1 (xLSTM[7:1])
[arXiv:2405.04517; unverified].  d_ff=0: xLSTM blocks carry their own
up/down projections.  Sub-quadratic -> runs long_500k."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="xlstm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab=50304,
    expand=2, slstm_every=8, slstm_heads=4, ssm_d_conv=4,
    norm="ln", use_rope=False,
    subquadratic=True,
    source="arXiv:2405.04517",
)
