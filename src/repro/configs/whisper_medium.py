"""whisper-medium [audio]: encoder-decoder; conv frontend is a STUB -
input_specs() provides precomputed frame embeddings [arXiv:2212.04356].
24 encoder + 24 decoder layers, LayerNorm + GELU, learned/sinusoidal
positions (no RoPE), biased QKV."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51865,
    norm="ln", mlp_kind="gelu", use_rope=False, qkv_bias=True,
    enc_frames=1500,
    source="arXiv:2212.04356",
)
