"""zamba2-2.7b [hybrid]: Mamba2 backbone + one shared attention+MLP block
applied every 6 mamba layers [arXiv:2411.15242].  Simplifications noted in
DESIGN.md: shared block on the residual stream (no concat-with-embedding or
per-application LoRA).  Sub-quadratic -> runs long_500k."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="zamba",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab=32000,
    expand=2, ssm_head_dim=64, ssm_state=64, ssm_groups=1, ssm_d_conv=4,
    shared_every=6,
    norm="rms", mlp_kind="swiglu",
    subquadratic=True,
    source="arXiv:2411.15242",
)
