"""chameleon-34b [vlm]: early-fusion VQ image tokens share the text vocab,
so the backbone is a dense decoder; modality frontend is a stub
[arXiv:2405.09818; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=65536,
    qk_norm=True,              # chameleon's qk-norm stabilization
    norm="rms", mlp_kind="swiglu", rope_theta=10000.0,
    source="arXiv:2405.09818",
)
