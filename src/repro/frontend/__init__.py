"""Productivity frontend (DESIGN.md §8): the ``@futurize`` tracing
decorator that turns plain Python into the futurized execution tree, and
the declarative ``Plan`` -> ``Session`` API the launchers are shims over."""
from .cli import cli_args, plan_from_args  # noqa: F401
from .futurize import (Trace, TraceNode, current_trace,  # noqa: F401
                       futurize, tracing)
from .plan import Plan, Session  # noqa: F401

__all__ = ["Plan", "Session", "Trace", "TraceNode", "cli_args",
           "current_trace", "futurize", "plan_from_args", "tracing"]
