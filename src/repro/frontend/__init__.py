"""Productivity frontend (DESIGN.md §8): the ``@futurize`` tracing
decorator that turns plain Python into the futurized execution tree, and
the declarative ``Plan`` -> ``Session`` API the launchers are shims over."""
from .cli import cli_args, plan_from_args, serve_flags  # noqa: F401
from .futurize import (Trace, TraceNode, current_trace,  # noqa: F401
                       futurize, tracing)
from .gateway import (DeadlineExpired, Gateway, RequestHandle,  # noqa: F401
                      RequestQueue, RequestRejected)
from .plan import Plan, Session  # noqa: F401

__all__ = ["DeadlineExpired", "Gateway", "Plan", "RequestHandle",
           "RequestQueue", "RequestRejected", "Session", "Trace",
           "TraceNode", "cli_args", "current_trace", "futurize",
           "plan_from_args", "serve_flags", "tracing"]
