"""Fabric DDP: the per-locality train engine behind ``Plan(ddp=True)``
(DESIGN.md §11).

Unlike the SPMD shadow loop (``frontend/spmd.py``), which mirrors the
FULL computation on every process, DDP divides the work: the global
batch is split into ``Plan.ddp_shards`` row shards, each locality
computes gradients for its contiguous block of shards, and the partials
are summed across localities by ``distrib.collectives.RingAllReduce`` -
active messages on our own TCP fabric, with a pluggable codec (``fp32``
exact, ``onebit`` 1-bit + error feedback).  Every locality then applies
the identical optimizer update to the identical averaged gradient, so
parameters stay replicated without ever being exchanged.

Determinism is the proof obligation (tests/test_ddp.py): batches come
from the same step-keyed stream on every process
(``stream.batch_at(it)``, the §10 batch keying), shard slices are pure
row indexing, and both the within-locality partial accumulation and the
ring's combine run in fixed shard/rank order - float addition commutes
but does not associate, so order IS the contract.  With the fp32 codec
and one shard per locality, a W-locality run is bit-identical in loss
to a 1-locality run over the same ``ddp_shards``.

The loop is started by a ``ddp_train`` active message
(``DistributedGraph.ddp_train`` -> ``Locality._on_ddp_train``) and
reports completion - and its ``grad_wire_bytes`` - through a
``ddp_done`` post.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from ..checkpoint.checkpoint import CheckpointManager
from ..core import steps as steps_lib
from ..data.pipeline import stream_for

__all__ = ["DDPEngine", "ddp_shadow_train", "shard_batch"]


def shard_batch(batch: dict, shard: int, n_shards: int) -> dict:
    """Row shard ``shard`` of ``n_shards`` of a batch dict: contiguous
    dim-0 slices, so shards 0..n-1 concatenate back to the batch.

    Raises:
        ValueError: a batch dim is not divisible by ``n_shards``.
    """
    out = {}
    for k, v in batch.items():
        n = v.shape[0]
        if n % n_shards:
            raise ValueError(f"batch field {k!r} has {n} rows, not "
                             f"divisible into {n_shards} ddp shards")
        per = n // n_shards
        out[k] = v[shard * per:(shard + 1) * per]
    return out


class DDPEngine:
    """One locality's half of a DDP run: local gradients in, globally
    averaged update out.

    Every locality (driver included - it is ring rank 0) builds one of
    these from the same ``Plan``, so the step functions, fusion plan,
    codec, and initial state are identical everywhere.  ``rank`` owns
    the contiguous shard block ``[rank*S/W, (rank+1)*S/W)`` of the
    ``S = plan.ddp_shards or world`` batch shards.

    Args:
        plan: the run's ``Plan`` (``ddp=True``).
        ring: this locality's ``RingAllReduce`` (configured here).
        gen: explicit ring generation (the driver's, shipped in the
            ``ddp_train`` spec); None lets the ring self-increment.
    Raises:
        ValueError: shard count not divisible by the world size, batch
            not divisible by the shard count, or an unsupported
            strategy (see ``core.steps.make_ddp_step``).
    """

    def __init__(self, plan, ring, *, gen: Optional[int] = None):
        self.plan = plan
        self.ring = ring
        self.world = ring.world
        shards = plan.ddp_shards or self.world
        if shards % self.world:
            raise ValueError(f"ddp_shards={shards} must be a multiple of "
                             f"the locality count {self.world}")
        if plan.batch % shards:
            raise ValueError(f"batch={plan.batch} must be divisible by "
                             f"ddp_shards={shards}")
        self.shards = shards
        self.step = steps_lib.make_ddp_step(
            shape={"seq_len": plan.seq, "global_batch": plan.batch // shards,
                   "kind": "train"},
            plan=plan)
        self.codec = ring.configure(plan.grad_codec, self.step.grad_plan,
                                    gen=gen)
        #: exact payload bytes ONE locality sends per exchange hop
        self.codec_bytes = self.codec.wire_bytes(self.step.grad_plan)
        per = shards // self.world
        self.owned = range(ring.rank * per, (ring.rank + 1) * per)

    def init(self):
        """Deterministic (params, opt) from ``Plan.seed`` - identical on
        every locality."""
        return self.step.init(jax.random.PRNGKey(self.plan.seed))

    def train_step(self, it: int, batch: dict, params, opt):
        """One DDP step: owned-shard gradients -> ring all-reduce ->
        identical optimizer update.

        Args:
            it: step index (keys the ring exchange).
            batch: the GLOBAL batch dict for step ``it`` (every
                locality draws the same one from the step-keyed
                stream and slices its own shards).
        Returns:
            ``(metrics, params, opt)`` with ``metrics["loss"]`` the
            global mean loss as a host ``np.float32`` and
            ``metrics["grad_norm"]`` the post-average gradient norm.
        Raises:
            LocalityLostError: a peer died mid-all-reduce.
        """
        step = self.step
        part: Optional[list] = None
        loss = np.float32(0.0)
        for s in self.owned:                    # fixed shard order
            sb = {k: jax.device_put(v, step.batch_shardings.get(k))
                  for k, v in shard_batch(batch, s, self.shards).items()}
            l, bufs = step.grad_fn(params, sb)
            bufs = [np.asarray(b) for b in bufs]
            loss = loss + np.float32(l)
            part = bufs if part is None else [a + b
                                              for a, b in zip(part, bufs)]
        summed, metas = self.ring.allreduce(it, part, meta={"loss": loss})
        total = np.float32(0.0)
        for o in range(self.world):             # fixed rank order
            total = total + np.float32(metas[o]["loss"])
        ns = np.float32(self.shards)
        mean = [b / ns for b in summed]
        gnorm, params, opt = step.apply_fn(mean, params, opt)
        return ({"loss": total / ns, "grad_norm": gnorm}, params, opt)


def ddp_shadow_train(spec: dict, endpoint: Optional[Any] = None,
                     ring=None) -> dict:
    """What a worker locality runs for ``Plan(ddp=True)``: the DDP loop
    over this locality's shard block (see module docstring).

    Checkpoints are driver-only in DDP mode - parameters are replicated,
    so the driver's save IS the global state; on ``resume`` this loop
    restores the same latest checkpoint from the shared directory.

    Args:
        spec: ``{"plan", "steps", "ckpt_dir", "resume", "stream",
            "gen"}`` as posted by ``DistributedGraph.ddp_train``.
        endpoint: this locality's active-message ``Endpoint``.
        ring: the locality's long-lived ``RingAllReduce``; built from
            ``endpoint`` when None (test use).
    Returns:
        dict with ``step``, ``grad_wire_bytes`` (payload bytes this
        locality sent), and ``final_loss``.
    """
    plan = spec["plan"]
    steps: int = spec["steps"]
    ckpt_dir: str = spec.get("ckpt_dir") or ""
    if ring is None:
        from ..distrib.collectives import RingAllReduce
        ring = RingAllReduce(endpoint, plan.localities)
    engine = DDPEngine(plan, ring, gen=spec.get("gen"))
    params, opt = engine.init()
    start = 0
    if spec.get("resume") and ckpt_dir:
        with CheckpointManager(ckpt_dir, async_save=False) as cm:
            if cm.latest_step() is not None:
                start, (params, opt) = cm.restore(
                    (params, opt),
                    shardings=(engine.step.param_shardings,
                               engine.step.opt_shardings))
    stream = spec.get("stream")
    if stream is None:
        stream = stream_for(plan.config(), batch=plan.batch, seq=plan.seq,
                            seed=plan.seed)
    metrics = None
    try:
        for it in range(start, steps):
            metrics, params, opt = engine.train_step(
                it, stream.batch_at(it), params, opt)
    finally:
        ring.deactivate()
    return {"step": steps, "grad_wire_bytes": int(ring.wire_bytes),
            "final_loss": (float(metrics["loss"])
                           if metrics is not None else float("nan"))}
