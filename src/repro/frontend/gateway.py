"""Serving gateway: async continuous batching over the futurized runtime.

``Session.serve`` drains a fixed request list in synchronized waves: every
slot prefills together, decodes ``gen_len`` tokens together, and a slot
that finishes early idles (padded) until the wave barrier.  This module is
the serve path the paper's runtime story actually implies - requests as
*first-class futurized node chains* arriving mid-flight, scheduled by
constraint resolution rather than wave barriers (DESIGN.md §14):

  * ``RequestQueue`` accepts arrivals while the gateway is decoding; each
    ``submit`` returns a ``RequestHandle`` the caller can block on or
    cancel.  Deterministic *traces* (`at_round`-tagged submissions) drive
    the test battery; live threads drive real streams.
  * Admission control: at most ``max_inflight`` requests hold resources
    (queued requests wait; a full queue rejects); a request's deadline
    expiring before it reaches a slot cancels its node chain cleanly.
  * A request prefills ONCE, at admission, in its own ``prefill:r{i}``
    node (batch=1); the resulting KV/conv/SSM decode state parks in the
    paged ``core.paging.InferenceCache`` until a slot frees up.  Slot
    refill *loads pages* (``refill:e{k}``) instead of recomputing - the
    paged-cache hit counter equals the refill counter by construction.
  * The continuous batch decodes with *per-slot positions* (``[B]`` pos
    vectors through ``models``), so co-tenants at different offsets share
    one jitted decode step.  Every decode round is a named graph node
    (``decode:e{k}:t{j}``), its token fan-out a chained CHECKPOINT
    ``emit`` node, and each request's completion a ``finish:r{i}`` node
    resolving the ``request:r{i}`` promise (producer-backed, so the
    PHY002/PHY101 linters trust it).

Graph shape per request i (epoch k = one slot-membership period)::

    stack:r{i} --> prefill:r{i} --\\
    ... decode:e{k-1}:t{J} --------> refill:e{k} -> decode:e{k}:t0 -> ...
                                          decode:e{k}:t{j} -> emit:e{k}:t{j}
    emit chain (prev emit -> next emit) ... -> finish:r{i} => request:r{i}

Token streams are *bit-identical* across co-tenancy: prefill is batch=1,
decode math is row-independent (one-hot cache writes, per-row masks and
argmax), so a request's stream depends only on its prompt - the property
the fault-injection and multiproc parity tests pin down.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from concurrent.futures import CancelledError
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.futures import FuturizedGraph, Lane
from ..core.paging import InferenceCache

__all__ = ["DeadlineExpired", "Gateway", "RequestHandle", "RequestQueue",
           "RequestRejected"]


class RequestRejected(RuntimeError):
    """Admission control refused the request (queue at capacity)."""


class DeadlineExpired(TimeoutError):
    """The request's deadline passed before it reached a decode slot."""


def _stack_request(prompt):
    """Host prep of one request's prompt (module-level: ships to a worker
    locality by reference when the plan is multi-locality)."""
    return np.asarray(prompt, np.int32)


class RequestHandle:
    """One request's client-side view: token stream, status, cancel.

    Statuses: ``queued`` -> ``rejected`` | ``admitted`` -> ``active`` ->
    ``done`` | ``cancelled`` | ``expired`` | ``failed``.  ``tokens`` is
    the prefill token plus one token per decode round the request was
    resident for; ``result()`` blocks for the terminal state.
    """

    def __init__(self, rid: str, prompt, *, at_round: int = 0,
                 deadline_ms: Optional[float] = None,
                 cancel_after: Optional[int] = None,
                 inject: Optional[str] = None):
        self.rid = rid
        self.prompt = prompt
        self.at_round = int(at_round)
        self.deadline_s = None if deadline_ms is None else deadline_ms / 1e3
        self.cancel_after = cancel_after
        self.inject = inject
        self.status = "queued"
        self.tokens: list[int] = []
        self.submit_t = time.perf_counter()
        self._done = threading.Event()
        self._exc: Optional[BaseException] = None
        self._cancel_requested = False
        self._last_t: Optional[float] = None    # previous token's emit time
        self._emitted = 0                       # decode rounds built for it
        self._slot: Optional[int] = None
        self._promise = None                    # request:{rid} graph node
        self._stack = None
        self._prefill = None
        self._first: Optional[int] = None       # prefill token

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> list[int]:
        """Block for the terminal state; the token stream on success,
        else the failure (``DeadlineExpired`` / ``CancelledError`` /
        ``RequestRejected`` / the poisoning exception)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} still in flight")
        if self._exc is not None:
            raise self._exc
        return list(self.tokens)

    def cancel(self):
        """Ask the gateway to drop this request (client disconnect); it
        takes effect at the next round boundary, wherever the request is
        in its lifecycle."""
        self._cancel_requested = True

    def __repr__(self):
        return (f"<RequestHandle {self.rid} {self.status} "
                f"tokens={len(self.tokens)}>")


class RequestQueue:
    """Thread-safe arrival stream feeding a ``Gateway``.

    ``submit`` may be called from any thread while the gateway runs; a
    trace-driven run pre-submits ``at_round``-tagged requests and calls
    ``close()``.  With ``max_queue`` set, submissions beyond the backlog
    cap are *rejected* (the handle terminates with ``RequestRejected``) -
    the admission-control back edge.
    """

    def __init__(self, max_queue: Optional[int] = None):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._items: collections.deque[RequestHandle] = collections.deque()
        self._ids = itertools.count()
        self.max_queue = max_queue
        self.closed = False
        self.submitted = 0
        self.rejected = 0

    def submit(self, prompt, *, at_round: int = 0,
               deadline_ms: Optional[float] = None,
               cancel_after: Optional[int] = None,
               inject: Optional[str] = None) -> RequestHandle:
        """Enqueue one request; returns its handle (possibly already
        terminal with ``RequestRejected`` when the backlog is full or the
        queue closed)."""
        with self._cv:
            rid = f"r{next(self._ids)}"
            h = RequestHandle(rid, prompt, at_round=at_round,
                              deadline_ms=deadline_ms,
                              cancel_after=cancel_after, inject=inject)
            if self.closed or (self.max_queue is not None
                               and len(self._items) >= self.max_queue):
                why = ("queue closed" if self.closed
                       else f"backlog at capacity {self.max_queue}")
                h.status = "rejected"
                h._exc = RequestRejected(f"{rid}: {why}")
                h._done.set()
                self.rejected += 1
                return h
            self.submitted += 1
            self._items.append(h)
            self._cv.notify_all()
            return h

    def close(self):
        """No further submissions; the gateway drains what is queued and
        returns once everything in flight is terminal."""
        with self._cv:
            self.closed = True
            self._cv.notify_all()

    # -- gateway side --------------------------------------------------------
    def take_ready(self, round_: int) -> list[RequestHandle]:
        """Pop every queued handle whose ``at_round`` has arrived, in
        submission order."""
        with self._lock:
            ready = [h for h in self._items if h.at_round <= round_]
            for h in ready:
                self._items.remove(h)
            return ready

    def next_round(self) -> Optional[int]:
        """The earliest ``at_round`` still queued (trace fast-forward)."""
        with self._lock:
            return min((h.at_round for h in self._items), default=None)

    def wait_nonempty(self, timeout: float) -> bool:
        """Block up to ``timeout`` for a submission or ``close()``."""
        with self._cv:
            return self._cv.wait_for(
                lambda: self._items or self.closed, timeout)


class Gateway:
    """The continuous-batching driver (one ``run()`` per instance).

    Owns the paged ``InferenceCache``, the request registry and the
    fault/tombstone accounting; emits every admission/cache counter and
    per-request latency histogram into ``runtime.stats()`` via
    ``record_serve``.  Built by ``Session.serve_stream``, which supplies
    the jitted batch=1 prefill step and the ``slots``-wide decode step.
    """

    def __init__(self, runtime: FuturizedGraph, *, distributed=None,
                 prefill_step, decode_step, params, prompt_len: int,
                 gen_len: int, slots: int,
                 max_inflight: Optional[int] = None,
                 deadline_ms: Optional[float] = None,
                 page_bytes: int = 1 << 16, lookahead: int = 2):
        if gen_len < 1:
            raise ValueError("gen_len must be >= 1")
        self.runtime = runtime
        self.distributed = distributed
        self.pre = prefill_step
        self.dec = decode_step
        self.params = params
        self.prompt_len = prompt_len
        self.gen_len = gen_len
        self.slots = slots
        self.max_inflight = max(1, max_inflight if max_inflight is not None
                                else 2 * slots)
        self.default_deadline_s = (None if deadline_ms is None
                                   else deadline_ms / 1e3)
        self.lookahead = max(1, lookahead)
        self.icache = InferenceCache(page_bytes=page_bytes)
        self.tok_sh = decode_step.batch_shardings["tokens"]
        self._lock = threading.Lock()
        self._handles: dict[str, RequestHandle] = {}
        self._tombstones: set[str] = set()

    # -- request lifecycle ---------------------------------------------------
    def _register(self, h: RequestHandle):
        # the request's graph-visible terminal: a producer-backed promise
        # the finish node resolves (PHY002/PHY101 trust the producer tag)
        h._promise = self.runtime.promise(name=f"request:{h.rid}",
                                          lane=Lane.CHECKPOINT,
                                          producer="gateway")
        with self._lock:
            self._handles[h.rid] = h

    def _admit(self, h: RequestHandle):
        if self.distributed is not None:
            h._stack = self.distributed.defer(
                _stack_request, h.prompt, lane=Lane.PREFETCH,
                name=f"stack:{h.rid}")
        else:
            h._stack = self.runtime.defer(
                _stack_request, h.prompt, lane=Lane.PREFETCH,
                name=f"stack:{h.rid}")
        h._prefill = self.runtime.defer(self._prefill_fn(h), h._stack,
                                        name=f"prefill:{h.rid}")
        h.status = "admitted"
        self.runtime.record_serve(admitted=1)

    def _prefill_fn(self, h: RequestHandle):
        def prefill(arr):
            t0 = time.perf_counter()
            self.runtime.record_serve(phase="queue_wait",
                                      dt_s=t0 - h.submit_t)
            if h.inject == "poison-prefill":
                raise RuntimeError(f"injected prefill poison on {h.rid}")
            toks = jax.device_put(jnp.asarray(arr)[None, :],
                                  self.pre.batch_shardings["tokens"])
            logits, cache1 = self.pre.fn(self.params, {"tokens": toks})
            first = int(np.asarray(jnp.argmax(logits, -1))[0])
            state = jax.tree.map(np.asarray, cache1)
            self.runtime.record_serve(phase="prefill",
                                      dt_s=time.perf_counter() - t0)
            with self._lock:
                if h.rid in self._tombstones:   # dropped while running:
                    return first                 # park nothing, leak nothing
                self.icache.put(h.rid, state)
                h._last_t = time.perf_counter()
            return first
        return prefill

    def _resolve(self, h: RequestHandle, status: str,
                 exc: Optional[BaseException], counter: str):
        with self._lock:
            if h._done.is_set():
                return
            h.status = status
            h._exc = exc
            if h._promise is not None:
                if exc is None:
                    h._promise.set_result(list(h.tokens))
                else:
                    h._promise.set_exception(
                        exc, cancelled=isinstance(exc, CancelledError))
            h._done.set()
        self.runtime.record_serve(**{counter: 1})

    def _kill_admitted(self, h: RequestHandle, exc: BaseException,
                       status: str, counter: str):
        """Reclaim an admitted-but-not-resident request: cancel its chain
        if possible, tombstone it against a racing ``put``, and free any
        pages it already parked."""
        if h._stack is not None:
            h._stack.cancel()
        if h._prefill is not None and not h._prefill.cancel():
            # running or already terminal: mark observed so the live graph
            # lints clean (PHY004) and a poison is not re-raised at close
            h._prefill.add_done_callback(lambda f: None)
        with self._lock:
            self._tombstones.add(h.rid)
            self.icache.drop(h.rid)
        self._resolve(h, status, exc, counter)

    def _expired(self, h: RequestHandle, now: float) -> bool:
        deadline = (h.deadline_s if h.deadline_s is not None
                    else self.default_deadline_s)
        return deadline is not None and now - h.submit_t >= deadline

    def _force_prefill(self, h: RequestHandle) -> bool:
        """Block for the request's prefill before giving it a slot; on
        failure (poison, upstream cancel) reclaim and report False."""
        try:
            h._first = h._prefill.result()
        except BaseException as e:  # noqa: BLE001 - resolved into the handle
            cancelled = isinstance(e, CancelledError)
            self._kill_admitted(h, e,
                                "cancelled" if cancelled else "failed",
                                "cancelled" if cancelled else "failed")
            return False
        with self._lock:
            h.tokens.append(h._first)
        return True

    # -- device-side node bodies --------------------------------------------
    def _fresh_carry(self):
        cache = jax.tree.map(
            lambda sp: jnp.zeros(sp.shape, sp.dtype), self.dec.cache_specs)
        tok = jnp.zeros((self.slots, 1), jnp.int32)
        return tok, cache

    def _recompute(self, rid: str):
        """Paged-cache miss fallback: rerun the prefill.  Never taken when
        the page accounting holds - the tests assert its counter is 0."""
        h = self._handles[rid]
        toks = jax.device_put(jnp.asarray(np.asarray(h.prompt, np.int32)
                                          )[None, :],
                              self.pre.batch_shardings["tokens"])
        logits, cache1 = self.pre.fn(self.params, {"tokens": toks})
        first = int(np.asarray(jnp.argmax(logits, -1))[0])
        return jax.tree.map(np.asarray, cache1), first

    def _refill_fn(self, joins: tuple):
        def refill(carry, *firsts):
            tok, cache = carry if carry is not None else self._fresh_carry()
            for (slot, rid), first in zip(joins, firsts):
                with self._lock:
                    state = self.icache.get(rid)
                    if state is not None:
                        self.icache.drop(rid)   # device-resident from here
                if state is None:
                    self.runtime.record_serve(prefill_recompute=1)
                    state, first = self._recompute(rid)
                else:
                    self.runtime.record_serve(page_hits=1)

                def scatter(c, s, sp, slot=slot):
                    ax = sp.dims.index("batch")
                    row = jnp.asarray(np.take(s, 0, axis=ax))
                    idx = (slice(None),) * ax + (slot,)
                    return jnp.asarray(c).at[idx].set(row.astype(c.dtype))
                cache = jax.tree.map(scatter, cache, state,
                                     self.dec.cache_specs)
                tok = tok.at[slot, 0].set(first)
                self.runtime.record_serve(refills=1)
            tok = jax.device_put(tok, self.tok_sh)
            cache = jax.device_put(cache, self.dec.cache_shardings)
            return tok, cache
        return refill

    def _decode_fn(self, carry, pos):
        tok, cache = carry
        logits, cache = self.dec.fn(self.params, cache, {"tokens": tok}, pos)
        tok = jax.device_put(
            jnp.argmax(logits, -1)[:, None].astype(jnp.int32), self.tok_sh)
        return tok, cache

    def _emit_fn(self, live_rows: tuple):
        def emit(carry, *_prev_emit):
            tokv = np.asarray(carry[0])[:, 0]   # forces the transfer
            now = time.perf_counter()
            with self._lock:
                for slot, rid in live_rows:
                    h = self._handles[rid]
                    h.tokens.append(int(tokv[slot]))
                    if h._last_t is not None:
                        self.runtime.record_serve(
                            phase="decode_token", dt_s=now - h._last_t)
                    h._last_t = now
            self.runtime.record_serve(
                real_tokens=len(live_rows),
                padded_slot_tokens=self.slots - len(live_rows))
        return emit

    def _finish_fn(self, h: RequestHandle, cancelled: bool):
        def finish(_emit_val):
            self.runtime.record_serve(
                phase="total", dt_s=time.perf_counter() - h.submit_t)
            if cancelled:
                self._resolve(h, "cancelled", CancelledError(h.rid),
                              "cancelled")
            else:
                self._resolve(h, "done", None, "completed")
        return finish

    # -- the driver ----------------------------------------------------------
    def run(self, queue: RequestQueue) -> dict:
        """Drive the gateway until the queue closes and everything in
        flight is terminal.  Returns the run summary (handles in intake
        order plus driver-side counts); all counters/histograms land in
        ``runtime.stats()``."""
        runtime = self.runtime
        pending: collections.deque[RequestHandle] = collections.deque()
        admitted: collections.deque[RequestHandle] = collections.deque()
        residents: list[Optional[RequestHandle]] = [None] * self.slots
        intake: list[RequestHandle] = []
        finishes = []
        emit_hist: collections.deque = collections.deque()
        carry = None
        prev_emit = None
        epoch = -1
        round_ = 0
        j = 0

        def inflight() -> int:
            return len(admitted) + sum(r is not None for r in residents)

        try:
            while True:
                now = time.perf_counter()
                # 1. ingest arrivals whose round has come
                for h in queue.take_ready(round_):
                    self._register(h)
                    intake.append(h)
                    pending.append(h)
                # 2. queued-side faults: user cancels, expired deadlines
                for h in list(pending):
                    if h._cancel_requested:
                        pending.remove(h)
                        self._resolve(h, "cancelled",
                                      CancelledError(h.rid), "cancelled")
                    elif self._expired(h, now):
                        pending.remove(h)
                        self._resolve(h, "expired",
                                      DeadlineExpired(h.rid), "expired")
                # 3. admission: launch prefill chains up to max_inflight
                while pending and inflight() < self.max_inflight:
                    h = pending.popleft()
                    self._admit(h)
                    admitted.append(h)
                # 4. admitted-side faults: cancel/expiry mid-prefill,
                #    poisoned chains detected as soon as they are terminal
                for h in list(admitted):
                    exc = None
                    if h._cancel_requested:
                        exc, status = CancelledError(h.rid), "cancelled"
                    elif self._expired(h, now):
                        exc, status = DeadlineExpired(h.rid), "expired"
                    elif (h._prefill.done()
                          and h._prefill.exception() is not None):
                        exc, status = h._prefill.exception(), "failed"
                    if exc is not None:
                        admitted.remove(h)
                        self._kill_admitted(h, exc, status, status)
                # 5. retire residents that finished or were cancelled
                changed = False
                for s, h in enumerate(residents):
                    if h is None:
                        continue
                    cancelled = (h._cancel_requested
                                 or (h.cancel_after is not None
                                     and h._emitted >= h.cancel_after))
                    if cancelled or h._emitted >= self.gen_len:
                        fin = runtime.defer(
                            self._finish_fn(h, cancelled), prev_emit,
                            lane=Lane.CHECKPOINT, name=f"finish:{h.rid}")
                        finishes.append(fin)
                        residents[s] = None
                        changed = True
                # 6. fill free slots from the admitted queue (prefill is
                #    forced first: a slot is only ever given a request
                #    whose state is already parked in pages)
                joiners = []
                free = [s for s in range(self.slots) if residents[s] is None]
                while free and admitted:
                    h = admitted.popleft()
                    if not self._force_prefill(h):
                        continue
                    s = free.pop(0)
                    h._slot, h.status = s, "active"
                    residents[s] = h
                    joiners.append((s, h))
                    changed = True
                # 7. nothing resident: fast-forward to the next arrival,
                #    wait for live traffic, or drain out
                if all(r is None for r in residents):
                    nxt = queue.next_round()
                    if nxt is not None:
                        round_ = max(round_ + 1, nxt)
                        continue
                    if not queue.closed:
                        queue.wait_nonempty(0.05)
                        round_ += 1
                        continue
                    break
                # 8. membership changed: cut an epoch, load pages
                if changed or carry is None:
                    epoch += 1
                    j = 0
                    joins = tuple((s, h.rid) for s, h in joiners)
                    carry = runtime.defer(
                        self._refill_fn(joins), carry,
                        *[h._prefill for _, h in joiners],
                        name=f"refill:e{epoch}")
                # 9. one decode round: per-slot positions, chained emit
                live_rows = tuple((h._slot, h.rid)
                                  for h in residents if h is not None)
                pos = np.full(self.slots, self.prompt_len, np.int32)
                for s, rid in live_rows:
                    pos[s] = self.prompt_len + self._handles[rid]._emitted
                carry = runtime.defer(self._decode_fn, carry,
                                      jnp.asarray(pos),
                                      name=f"decode:e{epoch}:t{j}")
                emit_deps = (carry,) if prev_emit is None \
                    else (carry, prev_emit)
                prev_emit = runtime.defer(self._emit_fn(live_rows),
                                          *emit_deps, lane=Lane.CHECKPOINT,
                                          name=f"emit:e{epoch}:t{j}")
                emit_hist.append(prev_emit)
                if len(emit_hist) > self.lookahead:   # bound the lead so
                    emit_hist.popleft().result()      # faults/arrivals land
                for _, rid in live_rows:
                    self._handles[rid]._emitted += 1
                j += 1
                round_ += 1
            # drain: force the emit chain tail and every finish node
            if prev_emit is not None:
                prev_emit.result()
            for fin in finishes:
                fin.result()
        finally:
            # never leave an unresolved promise behind (barrier/shutdown
            # would hang on it): anything non-terminal is failed out
            for h in intake:
                if not h._done.is_set():
                    self._resolve(h, "failed",
                                  RuntimeError(f"gateway torn down with "
                                               f"{h.rid} in flight"),
                                  "failed")
        self.runtime.record_serve(rejected=queue.rejected,
                                  **self.icache.counters())
        counts = collections.Counter(h.status for h in intake)
        return {"handles": intake,
                "streams": {h.rid: list(h.tokens) for h in intake},
                "completed": counts.get("done", 0),
                "cancelled": counts.get("cancelled", 0),
                "expired": counts.get("expired", 0),
                "failed": counts.get("failed", 0),
                "rejected": queue.rejected,
                "rounds": round_, "epochs": epoch + 1,
                "cache": self.icache.counters()}
