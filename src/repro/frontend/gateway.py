"""Serving gateway: async continuous batching over the futurized runtime.

``Session.serve`` drains a fixed request list in synchronized waves: every
slot prefills together, decodes ``gen_len`` tokens together, and a slot
that finishes early idles (padded) until the wave barrier.  This module is
the serve path the paper's runtime story actually implies - requests as
*first-class futurized node chains* arriving mid-flight, scheduled by
constraint resolution rather than wave barriers (DESIGN.md §14):

  * ``RequestQueue`` accepts arrivals while the gateway is decoding; each
    ``submit`` returns a ``RequestHandle`` the caller can block on or
    cancel.  Deterministic *traces* (`at_round`-tagged submissions) drive
    the test battery; live threads drive real streams.
  * Admission control: at most ``max_inflight`` requests hold resources
    (queued requests wait; a full queue rejects); a request's deadline
    expiring before it reaches a slot cancels its node chain cleanly.
  * A request prefills ONCE, at admission, in its own ``prefill:r{i}``
    node (batch=1); the resulting KV/conv/SSM decode state parks in the
    paged ``core.paging.InferenceCache`` until a slot frees up.  Slot
    refill *loads pages* (``refill:e{k}``) instead of recomputing - the
    paged-cache hit counter equals the refill counter by construction.
  * The continuous batch decodes with *per-slot positions* (``[B]`` pos
    vectors through ``models``), so co-tenants at different offsets share
    one jitted decode step.  Every decode round is a named graph node
    (``decode:e{k}:t{j}``), its token fan-out a chained CHECKPOINT
    ``emit`` node, and each request's completion a ``finish:r{i}`` node
    resolving the ``request:r{i}`` promise (producer-backed, so the
    PHY002/PHY101 linters trust it).

Graph shape per request i (epoch k = one slot-membership period)::

    stack:r{i} --> prefill:r{i} --\\
    ... decode:e{k-1}:t{J} --------> refill:e{k} -> decode:e{k}:t0 -> ...
                                          decode:e{k}:t{j} -> emit:e{k}:t{j}
    emit chain (prev emit -> next emit) ... -> finish:r{i} => request:r{i}

With ``replicas=N`` (DESIGN.md §15) the gateway drives N model replicas -
each a prefill/decode pair with its own decode chain, slot accounting and
*named* ``InferenceCache`` over one shared ``PagePool`` - and a
``ReplicaRouter`` assigns every admitted request to exactly one replica:
page affinity first (the replica already holding its pages), then least
loaded, ties to the lowest index.  Epoch-scoped nodes are namespaced
(``refill:R1:e{k}``...); request-scoped names are unchanged.  When a
replica's home locality dies, its requests migrate to survivors and the
surviving refill *adopts* the dead replica's pages (a counted
``cross_replica_page_fetches``, zero in steady state) - prefill is never
recomputed.

Token streams are *bit-identical* across co-tenancy AND across replica
counts: prefill is batch=1, decode math is row-independent (one-hot cache
writes, per-row masks and argmax), so a request's stream depends only on
its prompt - the property the fault-injection, multiproc parity and
replica-drill tests pin down.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from concurrent.futures import CancelledError
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.futures import FuturizedGraph, Lane
from ..core.paging import InferenceCache, PagePool

__all__ = ["DeadlineExpired", "Gateway", "ReplicaRouter", "RequestHandle",
           "RequestQueue", "RequestRejected"]


class RequestRejected(RuntimeError):
    """Admission control refused the request (queue at capacity)."""


class DeadlineExpired(TimeoutError):
    """The request's deadline passed before it reached a decode slot."""


def _stack_request(prompt):
    """Host prep of one request's prompt (module-level: ships to a worker
    locality by reference when the plan is multi-locality)."""
    return np.asarray(prompt, np.int32)


class RequestHandle:
    """One request's client-side view: token stream, status, cancel.

    Statuses: ``queued`` -> ``rejected`` | ``admitted`` -> ``active`` ->
    ``done`` | ``cancelled`` | ``expired`` | ``failed``.  ``tokens`` is
    the prefill token plus one token per decode round the request was
    resident for; ``result()`` blocks for the terminal state.
    """

    def __init__(self, rid: str, prompt, *, at_round: int = 0,
                 deadline_ms: Optional[float] = None,
                 cancel_after: Optional[int] = None,
                 inject: Optional[str] = None):
        self.rid = rid
        self.prompt = prompt
        self.at_round = int(at_round)
        self.deadline_s = None if deadline_ms is None else deadline_ms / 1e3
        self.cancel_after = cancel_after
        self.inject = inject
        self.status = "queued"
        self.tokens: list[int] = []
        self.submit_t = time.perf_counter()
        self._done = threading.Event()
        self._exc: Optional[BaseException] = None
        self._cancel_requested = False
        self._last_t: Optional[float] = None    # previous token's emit time
        self._emitted = 0                       # decode rounds built for it
        self._slot: Optional[int] = None
        self._promise = None                    # request:{rid} graph node
        self._stack = None
        self._prefill = None
        self._first: Optional[int] = None       # prefill token
        self._prefill_forced = False            # first token already appended
        self._replica: Optional[int] = None     # routed replica index

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> list[int]:
        """Block for the terminal state; the token stream on success,
        else the failure (``DeadlineExpired`` / ``CancelledError`` /
        ``RequestRejected`` / the poisoning exception)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} still in flight")
        if self._exc is not None:
            raise self._exc
        return list(self.tokens)

    def cancel(self):
        """Ask the gateway to drop this request (client disconnect); it
        takes effect at the next round boundary, wherever the request is
        in its lifecycle."""
        self._cancel_requested = True

    def __repr__(self):
        return (f"<RequestHandle {self.rid} {self.status} "
                f"tokens={len(self.tokens)}>")


class RequestQueue:
    """Thread-safe arrival stream feeding a ``Gateway``.

    ``submit`` may be called from any thread while the gateway runs; a
    trace-driven run pre-submits ``at_round``-tagged requests and calls
    ``close()``.  With ``max_queue`` set, submissions beyond the backlog
    cap are *rejected* (the handle terminates with ``RequestRejected``) -
    the admission-control back edge.
    """

    def __init__(self, max_queue: Optional[int] = None):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._items: collections.deque[RequestHandle] = collections.deque()
        self._ids = itertools.count()
        self.max_queue = max_queue
        self.closed = False
        self.submitted = 0
        self.rejected = 0

    def submit(self, prompt, *, at_round: int = 0,
               deadline_ms: Optional[float] = None,
               cancel_after: Optional[int] = None,
               inject: Optional[str] = None) -> RequestHandle:
        """Enqueue one request; returns its handle (possibly already
        terminal with ``RequestRejected`` when the backlog is full or the
        queue closed)."""
        with self._cv:
            rid = f"r{next(self._ids)}"
            h = RequestHandle(rid, prompt, at_round=at_round,
                              deadline_ms=deadline_ms,
                              cancel_after=cancel_after, inject=inject)
            if self.closed or (self.max_queue is not None
                               and len(self._items) >= self.max_queue):
                why = ("queue closed" if self.closed
                       else f"backlog at capacity {self.max_queue}")
                h.status = "rejected"
                h._exc = RequestRejected(f"{rid}: {why}")
                h._done.set()
                self.rejected += 1
                return h
            self.submitted += 1
            self._items.append(h)
            self._cv.notify_all()
            return h

    def close(self):
        """No further submissions; the gateway drains what is queued and
        returns once everything in flight is terminal."""
        with self._cv:
            self.closed = True
            self._cv.notify_all()

    # -- gateway side --------------------------------------------------------
    def take_ready(self, round_: int) -> list[RequestHandle]:
        """Pop every queued handle whose ``at_round`` has arrived, in
        submission order."""
        with self._lock:
            ready = [h for h in self._items if h.at_round <= round_]
            for h in ready:
                self._items.remove(h)
            return ready

    def next_round(self) -> Optional[int]:
        """The earliest ``at_round`` still queued (trace fast-forward)."""
        with self._lock:
            return min((h.at_round for h in self._items), default=None)

    def drained(self) -> bool:
        """Closed AND empty, checked atomically - the gateway's only
        exit test.  A ``submit`` racing ``close()`` either lands in the
        backlog before the close (this stays False until the gateway
        takes it) or is deterministically rejected by ``submit``; a
        non-atomic closed-then-empty check could observe the close, miss
        the racing item, and strand its handle in ``queued`` forever."""
        with self._lock:
            return self.closed and not self._items

    def wait_nonempty(self, timeout: Optional[float] = None) -> bool:
        """Block for a submission or ``close()`` (``timeout=None`` waits
        indefinitely - the idle gateway parks here and ``submit``/
        ``close`` notify the condition variable, instead of the 20 Hz
        poll that used to add up to 50 ms of queue latency)."""
        with self._cv:
            return self._cv.wait_for(
                lambda: self._items or self.closed, timeout)


class ReplicaRouter:
    """Pure routing state for the replica pool (no JAX, no threads - the
    property tests drive it with seeded event soups, and the phylint
    static mirror replays it to predict the live tree).

    Rules (DESIGN.md §15):

      * **Affinity.**  A request already assigned to a live replica stays
        there: its prefill state is parked in that replica's pages, so
        moving it would turn a page hit into cross-replica traffic.
        ``assign`` on a routed rid is therefore idempotent across
        retire/refill.
      * **Least loaded.**  A new request goes to the live replica with
        the fewest routed requests, ties to the lowest index - purely
        structural, so a static mirror reaches the same decision.
      * **Death.**  ``kill`` marks a replica dead and returns its routed
        rids (in routing order) for re-assignment; a request is never
        assigned to two replicas at once and never stranded while any
        replica is alive (``assign`` raises only on an empty pool).
    """

    def __init__(self, replicas: int):
        if replicas < 1:
            raise ValueError(f"need >= 1 replica, got {replicas}")
        self.replicas = replicas
        self.live: set[int] = set(range(replicas))
        self.assignment: dict[str, int] = {}

    def load(self, replica: int) -> int:
        """Requests currently routed to ``replica``."""
        return sum(1 for r in self.assignment.values() if r == replica)

    def assign(self, rid: str) -> int:
        """Route ``rid`` (idempotent while its replica is alive)."""
        cur = self.assignment.get(rid)
        if cur is not None and cur in self.live:
            return cur                       # page affinity: stay put
        if not self.live:
            raise RuntimeError("no live replicas to route to")
        r = min(self.live, key=lambda i: (self.load(i), i))
        self.assignment[rid] = r
        return r

    def release(self, rid: str):
        """Forget a terminal request's routing."""
        self.assignment.pop(rid, None)

    def kill(self, replica: int) -> list[str]:
        """Mark ``replica`` dead; its routed rids, in routing order,
        ready to be re-``assign``-ed to survivors."""
        self.live.discard(replica)
        return [rid for rid, r in self.assignment.items() if r == replica]

    def revive(self, replica: int):
        """Return a replica to the live pool (re-homed or re-spawned)."""
        if not 0 <= replica < self.replicas:
            raise ValueError(f"unknown replica {replica}")
        self.live.add(replica)


class _Replica:
    """Driver-side state of one serve replica: its own named page cache
    (over the gateway's shared pool), admitted queue, slot residents and
    decode chain.  ``ns`` prefixes epoch-scoped node names so N decode
    chains coexist in one graph (empty for a single-replica gateway -
    the PR-9 names are unchanged)."""

    def __init__(self, idx: int, home: int, slots: int, pool: PagePool,
                 namespaced: bool):
        self.idx = idx
        self.home = home                    # host locality rank (0=driver)
        self.alive = True
        self.ns = f"R{idx}:" if namespaced else ""
        self.icache = InferenceCache(pool,
                                     name=f"R{idx}" if namespaced else "")
        self.admitted: collections.deque = collections.deque()
        self.residents: list[Optional[RequestHandle]] = [None] * slots
        self.carry = None                   # decode chain carry future
        self.prev_emit = None               # emit chain tail
        self.emit_hist: collections.deque = collections.deque()
        self.epoch = -1
        self.j = 0
        self.round_work = (False, [])       # (changed, joiners) this round

    def has_residents(self) -> bool:
        return any(r is not None for r in self.residents)


class Gateway:
    """The continuous-batching driver (one ``run()`` per instance).

    Owns the shared ``PagePool`` (one named ``InferenceCache`` per
    replica), the ``ReplicaRouter``, the request registry and the
    fault/tombstone accounting; emits every admission/cache counter and
    per-request latency histogram into ``runtime.stats()`` via
    ``record_serve`` (per-replica split included).  Built by
    ``Session.serve_stream``, which supplies the jitted batch=1 prefill
    step and the ``slots``-wide decode step - both shared across
    replicas (same shapes, same seed: params are replicated, which is
    what keeps N-replica streams bit-identical to one replica).

    ``replicas``/``homes`` place each replica's host-side request prep
    (``stack`` nodes) on its home locality via ``DistributedGraph``
    placement; homes default to cycling over the live worker ranks then
    the driver.  ``kill_replica_at_round`` is the deterministic
    replica-death drill seam; ``kill_replica()`` is the live one.
    """

    def __init__(self, runtime: FuturizedGraph, *, distributed=None,
                 prefill_step, decode_step, params, prompt_len: int,
                 gen_len: int, slots: int,
                 max_inflight: Optional[int] = None,
                 deadline_ms: Optional[float] = None,
                 page_bytes: int = 1 << 16, lookahead: int = 2,
                 replicas: int = 1, homes: Optional[list[int]] = None,
                 kill_replica_at_round: Optional[tuple] = None):
        if gen_len < 1:
            raise ValueError("gen_len must be >= 1")
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.runtime = runtime
        self.distributed = distributed
        self.pre = prefill_step
        self.dec = decode_step
        self.params = params
        self.prompt_len = prompt_len
        self.gen_len = gen_len
        self.slots = slots
        self.max_inflight = max(1, max_inflight if max_inflight is not None
                                else 2 * slots * replicas)
        self.default_deadline_s = (None if deadline_ms is None
                                   else deadline_ms / 1e3)
        self.lookahead = max(1, lookahead)
        if homes is None:
            homes = self._default_homes(replicas)
        elif len(homes) != replicas:
            raise ValueError(f"homes={homes} must name one locality per "
                             f"replica ({replicas})")
        self.pool = PagePool(page_bytes)
        self.replicas = [_Replica(i, homes[i], slots, self.pool,
                                  namespaced=replicas > 1)
                         for i in range(replicas)]
        self.router = ReplicaRouter(replicas)
        # single-replica alias (the PR-9 surface tests/benchmarks use)
        self.icache = self.replicas[0].icache
        self.tok_sh = decode_step.batch_shardings["tokens"]
        self._lock = threading.Lock()
        self._handles: dict[str, RequestHandle] = {}
        self._tombstones: set[str] = set()
        self._killed: set[int] = set()      # kill_replica() drill marks
        self._kill_at = (tuple(kill_replica_at_round)
                         if kill_replica_at_round is not None else None)

    def _default_homes(self, replicas: int) -> list[int]:
        """Cycle replicas over live worker localities, then the driver -
        so with 2 replicas on 2 localities, killing the worker kills
        exactly replica 0 and the driver-homed replica survives.  A
        single replica (or a single-process run) stays on the driver."""
        if self.distributed is None or replicas == 1:
            return [0] * replicas
        workers = [r for r in self.distributed.alive_localities() if r != 0]
        ranks = workers + [0] if workers else [0]
        return [ranks[i % len(ranks)] for i in range(replicas)]

    # -- request lifecycle ---------------------------------------------------
    def _register(self, h: RequestHandle):
        # the request's graph-visible terminal: a producer-backed promise
        # the finish node resolves (PHY002/PHY101 trust the producer tag)
        h._promise = self.runtime.promise(name=f"request:{h.rid}",
                                          lane=Lane.CHECKPOINT,
                                          producer="gateway")
        with self._lock:
            self._handles[h.rid] = h

    def _admit(self, h: RequestHandle) -> _Replica:
        """Route to a replica, then launch the request's prefill chain;
        its ``stack`` prep is pinned to the replica's home locality."""
        h._replica = self.router.assign(h.rid)
        rep = self.replicas[h._replica]
        if self.distributed is not None:
            pin = rep.home if len(self.replicas) > 1 else None
            try:
                h._stack = self.distributed.defer(
                    _stack_request, h.prompt, lane=Lane.PREFETCH,
                    name=f"stack:{h.rid}", locality=pin)
            except ValueError:
                # the home died between the liveness sweep and this defer:
                # place anywhere; the next sweep migrates the replica
                h._stack = self.distributed.defer(
                    _stack_request, h.prompt, lane=Lane.PREFETCH,
                    name=f"stack:{h.rid}")
        else:
            h._stack = self.runtime.defer(
                _stack_request, h.prompt, lane=Lane.PREFETCH,
                name=f"stack:{h.rid}")
        h._prefill = self.runtime.defer(self._prefill_fn(h), h._stack,
                                        name=f"prefill:{h.rid}")
        h.status = "admitted"
        self.runtime.record_serve(admitted=1, replica=h._replica)
        return rep

    def _prefill_fn(self, h: RequestHandle):
        def prefill(arr):
            t0 = time.perf_counter()
            self.runtime.record_serve(phase="queue_wait",
                                      dt_s=t0 - h.submit_t)
            if h.inject == "poison-prefill":
                raise RuntimeError(f"injected prefill poison on {h.rid}")
            toks = jax.device_put(jnp.asarray(arr)[None, :],
                                  self.pre.batch_shardings["tokens"])
            logits, cache1 = self.pre.fn(self.params, {"tokens": toks})
            first = int(np.asarray(jnp.argmax(logits, -1))[0])
            state = jax.tree.map(np.asarray, cache1)
            self.runtime.record_serve(phase="prefill",
                                      dt_s=time.perf_counter() - t0)
            with self._lock:
                if h.rid in self._tombstones:   # dropped while running:
                    return first                 # park nothing, leak nothing
                # park into the request's *current* replica: a migration
                # mid-prefill parks into the old cache and the new
                # replica's refill adopts the pages cross-replica
                self.replicas[h._replica].icache.put(h.rid, state)
                h._last_t = time.perf_counter()
            return first
        return prefill

    def _drop_pages(self, rid: str):
        """Free ``rid``'s pages wherever they are parked (a migrated
        request's pages may sit in its old replica's cache)."""
        for rep in self.replicas:
            if rid in rep.icache:
                rep.icache.drop(rid)

    def _resolve(self, h: RequestHandle, status: str,
                 exc: Optional[BaseException], counter: str):
        with self._lock:
            if h._done.is_set():
                return
            h.status = status
            h._exc = exc
            if h._promise is not None:
                if exc is None:
                    h._promise.set_result(list(h.tokens))
                else:
                    h._promise.set_exception(
                        exc, cancelled=isinstance(exc, CancelledError))
            h._done.set()
        # pages are retained until the request is terminal (migration
        # replays decode from the parked state); reclaim is here, total
        self._drop_pages(h.rid)
        self.runtime.record_serve(**{counter: 1})

    def _kill_admitted(self, h: RequestHandle, exc: BaseException,
                       status: str, counter: str):
        """Reclaim an admitted-but-not-resident request: cancel its chain
        if possible, tombstone it against a racing ``put``, and free any
        pages it already parked."""
        if h._stack is not None:
            h._stack.cancel()
        if h._prefill is not None and not h._prefill.cancel():
            # running or already terminal: mark observed so the live graph
            # lints clean (PHY004) and a poison is not re-raised at close
            h._prefill.add_done_callback(lambda f: None)
        with self._lock:
            self._tombstones.add(h.rid)
        self._drop_pages(h.rid)
        self._resolve(h, status, exc, counter)

    def _expired(self, h: RequestHandle, now: float) -> bool:
        deadline = (h.deadline_s if h.deadline_s is not None
                    else self.default_deadline_s)
        return deadline is not None and now - h.submit_t >= deadline

    def _force_prefill(self, h: RequestHandle) -> bool:
        """Block for the request's prefill before giving it a slot; on
        failure (poison, upstream cancel) reclaim and report False.
        Idempotent on the token stream: a migrated request re-joining a
        surviving replica's slot does not re-append its first token."""
        try:
            h._first = h._prefill.result()
        except BaseException as e:  # noqa: BLE001 - resolved into the handle
            cancelled = isinstance(e, CancelledError)
            self._kill_admitted(h, e,
                                "cancelled" if cancelled else "failed",
                                "cancelled" if cancelled else "failed")
            return False
        if not h._prefill_forced:
            h._prefill_forced = True
            with self._lock:
                h.tokens.append(h._first)
        return True

    # -- device-side node bodies --------------------------------------------
    def _fresh_carry(self):
        cache = jax.tree.map(
            lambda sp: jnp.zeros(sp.shape, sp.dtype), self.dec.cache_specs)
        tok = jnp.zeros((self.slots, 1), jnp.int32)
        return tok, cache

    def _recompute(self, rid: str):
        """Paged-cache miss fallback: rerun the prefill.  Never taken when
        the page accounting holds - the tests assert its counter is 0."""
        h = self._handles[rid]
        toks = jax.device_put(jnp.asarray(np.asarray(h.prompt, np.int32)
                                          )[None, :],
                              self.pre.batch_shardings["tokens"])
        logits, cache1 = self.pre.fn(self.params, {"tokens": toks})
        first = int(np.asarray(jnp.argmax(logits, -1))[0])
        return jax.tree.map(np.asarray, cache1), first

    def _refill_fn(self, rep: _Replica, joins: tuple):
        def refill(carry, *firsts):
            tok, cache = carry if carry is not None else self._fresh_carry()
            for (slot, rid), first in zip(joins, firsts):
                with self._lock:
                    state = rep.icache.get(rid)
                    if state is None:
                        # the pages may be parked under another replica
                        # (this request migrated off a dead one): adopt
                        # them - a fetch, never a recompute
                        for other in self.replicas:
                            if other is not rep and rid in other.icache:
                                other.icache.transfer(rid, rep.icache)
                                state = rep.icache.get(rid)
                                self.runtime.record_serve(
                                    cross_replica_page_fetches=1,
                                    replica=rep.idx)
                                break
                if state is None:
                    self.runtime.record_serve(prefill_recompute=1,
                                              replica=rep.idx)
                    state, first = self._recompute(rid)
                else:
                    self.runtime.record_serve(page_hits=1, replica=rep.idx)

                def scatter(c, s, sp, slot=slot):
                    ax = sp.dims.index("batch")
                    row = jnp.asarray(np.take(s, 0, axis=ax))
                    idx = (slice(None),) * ax + (slot,)
                    return jnp.asarray(c).at[idx].set(row.astype(c.dtype))
                cache = jax.tree.map(scatter, cache, state,
                                     self.dec.cache_specs)
                tok = tok.at[slot, 0].set(first)
                self.runtime.record_serve(refills=1, replica=rep.idx)
            tok = jax.device_put(tok, self.tok_sh)
            cache = jax.device_put(cache, self.dec.cache_shardings)
            return tok, cache
        return refill

    def _decode_fn(self, carry, pos):
        tok, cache = carry
        logits, cache = self.dec.fn(self.params, cache, {"tokens": tok}, pos)
        tok = jax.device_put(
            jnp.argmax(logits, -1)[:, None].astype(jnp.int32), self.tok_sh)
        return tok, cache

    def _emit_fn(self, rep: _Replica, live_rows: tuple):
        def emit(carry, *_prev_emit):
            tokv = np.asarray(carry[0])[:, 0]   # forces the transfer
            now = time.perf_counter()
            with self._lock:
                for slot, rid in live_rows:
                    h = self._handles[rid]
                    if h._replica != rep.idx:   # migrated off mid-round:
                        continue                 # the token is stale
                    h.tokens.append(int(tokv[slot]))
                    if h._last_t is not None:
                        self.runtime.record_serve(
                            phase="decode_token", dt_s=now - h._last_t)
                    h._last_t = now
            self.runtime.record_serve(
                real_tokens=len(live_rows),
                padded_slot_tokens=self.slots - len(live_rows),
                replica=rep.idx)
        return emit

    def _finish_fn(self, h: RequestHandle, cancelled: bool):
        def finish(_emit_val):
            self.runtime.record_serve(
                phase="total", dt_s=time.perf_counter() - h.submit_t)
            if cancelled:
                self._resolve(h, "cancelled", CancelledError(h.rid),
                              "cancelled")
            else:
                self._resolve(h, "done", None, "completed")
        return finish

    # -- replica liveness ----------------------------------------------------
    def kill_replica(self, idx: int):
        """Drill seam: mark replica ``idx`` dead; the next round's
        liveness sweep retires it and migrates its requests to the
        survivors.  Thread-safe (a feeder thread may call it mid-run)."""
        if not 0 <= idx < len(self.replicas):
            raise ValueError(f"unknown replica {idx}")
        self._killed.add(idx)

    def _sweep_dead_replicas(self, round_: int):
        """Retire replicas whose home locality died (or that a drill
        killed) and migrate everything they held to the survivors."""
        if self._kill_at is not None and round_ >= self._kill_at[1]:
            self._killed.add(int(self._kill_at[0]))
            self._kill_at = None
        alive_ranks = (set(self.distributed.alive_localities())
                       if self.distributed is not None else None)
        for rep in self.replicas:
            if not rep.alive:
                continue
            home_lost = (alive_ranks is not None and rep.home != 0
                         and rep.home not in alive_ranks
                         and len(self.replicas) > 1)
            if rep.idx in self._killed or home_lost:
                self._retire_replica(rep)

    def _retire_replica(self, rep: _Replica):
        """Replica-death rebalance (DESIGN.md §15): land the dead
        replica's in-flight emits, rewind its residents' streams to the
        prefill token, and re-route everything it held - the survivors'
        refill adopts its pages via a cross-replica fetch and replays
        decode from the parked state, so the final streams are
        bit-identical and prefill never recomputes."""
        rep.alive = False
        self.router.kill(rep.idx)
        # force the emit chain first so stale in-flight token appends
        # land before the stream rewind below (order matters)
        if rep.prev_emit is not None:
            try:
                rep.prev_emit.result()
            except BaseException:  # noqa: BLE001 - chain died with replica
                pass
        movers = list(rep.admitted) + [h for h in rep.residents
                                       if h is not None]
        rep.admitted.clear()
        rep.residents = [None] * self.slots
        rep.carry = None
        rep.prev_emit = None
        rep.emit_hist.clear()
        self.runtime.record_serve(replica_deaths=1)
        if not self.router.live:
            # last replica standing died: revive it homed on the driver
            # so queued work is never stranded
            rep.home = 0
            rep.alive = True
            self._killed.discard(rep.idx)
            self.router.revive(rep.idx)
            self.runtime.record_serve(replica_revivals=1)
        for h in movers:
            if h._done.is_set():
                self.router.release(h.rid)
                continue
            with self._lock:
                if h._first is not None:
                    # rewind to the prefill token: the adopting replica
                    # replays decode from the parked page state
                    h.tokens = [h._first]
                h._emitted = 0
                h._slot = None
                h._last_t = None
                h.status = "admitted"
            target = self.router.assign(h.rid)
            h._replica = target
            self.replicas[target].admitted.append(h)
            self.runtime.record_serve(replica_migrations=1, replica=target)

    def _cache_counters(self) -> dict:
        """Cache counters summed across replicas + the shared pool's."""
        out: dict = {}
        for rep in self.replicas:
            for k, v in rep.icache.counters().items():
                if k.startswith("cache_"):
                    out[k] = out.get(k, 0) + v
        out.update(self.pool.counters())
        return out

    # -- the driver ----------------------------------------------------------
    def run(self, queue: RequestQueue) -> dict:
        """Drive the gateway until the queue closes and everything in
        flight is terminal.  Returns the run summary (handles in intake
        order plus driver-side counts); all counters/histograms land in
        ``runtime.stats()``."""
        runtime = self.runtime
        pending: collections.deque[RequestHandle] = collections.deque()
        intake: list[RequestHandle] = []
        finishes = []
        round_ = 0

        def inflight() -> int:
            return sum(len(rep.admitted)
                       + sum(r is not None for r in rep.residents)
                       for rep in self.replicas)

        try:
            while True:
                now = time.perf_counter()
                # 0. liveness: retire dead replicas, migrate their work
                self._sweep_dead_replicas(round_)
                # 1. ingest arrivals whose round has come
                for h in queue.take_ready(round_):
                    self._register(h)
                    intake.append(h)
                    pending.append(h)
                # 2. queued-side faults: user cancels, expired deadlines
                for h in list(pending):
                    if h._cancel_requested:
                        pending.remove(h)
                        self._resolve(h, "cancelled",
                                      CancelledError(h.rid), "cancelled")
                    elif self._expired(h, now):
                        pending.remove(h)
                        self._resolve(h, "expired",
                                      DeadlineExpired(h.rid), "expired")
                # 3. admission: route + launch prefill chains up to the cap
                while pending and inflight() < self.max_inflight:
                    h = pending.popleft()
                    rep = self._admit(h)
                    rep.admitted.append(h)
                # 4. admitted-side faults: cancel/expiry mid-prefill,
                #    poisoned chains detected as soon as they are terminal
                for rep in self.replicas:
                    for h in list(rep.admitted):
                        exc = None
                        if h._cancel_requested:
                            exc, status = CancelledError(h.rid), "cancelled"
                        elif self._expired(h, now):
                            exc, status = DeadlineExpired(h.rid), "expired"
                        elif (h._prefill.done()
                              and h._prefill.exception() is not None):
                            exc, status = h._prefill.exception(), "failed"
                        if exc is not None:
                            rep.admitted.remove(h)
                            self.router.release(h.rid)
                            self._kill_admitted(h, exc, status, status)
                # 5/6 per replica: retire finished residents, fill free
                #     slots from its admitted queue (prefill forced first:
                #     a slot is only ever given a request whose state is
                #     already parked in pages)
                for rep in self.replicas:
                    if not rep.alive:
                        rep.round_work = (False, [])
                        continue
                    changed = False
                    for s, h in enumerate(rep.residents):
                        if h is None:
                            continue
                        cancelled = (h._cancel_requested
                                     or (h.cancel_after is not None
                                         and h._emitted >= h.cancel_after))
                        if cancelled or h._emitted >= self.gen_len:
                            fin = runtime.defer(
                                self._finish_fn(h, cancelled), rep.prev_emit,
                                lane=Lane.CHECKPOINT,
                                name=f"finish:{h.rid}")
                            finishes.append(fin)
                            rep.residents[s] = None
                            self.router.release(h.rid)
                            changed = True
                    joiners = []
                    free = [s for s in range(self.slots)
                            if rep.residents[s] is None]
                    while free and rep.admitted:
                        h = rep.admitted.popleft()
                        if not self._force_prefill(h):
                            self.router.release(h.rid)
                            continue
                        s = free.pop(0)
                        h._slot, h.status = s, "active"
                        rep.residents[s] = h
                        joiners.append((s, h))
                        changed = True
                    rep.round_work = (changed, joiners)
                # 7. nothing resident anywhere: fast-forward to the next
                #    arrival, block on the queue CV, or drain out
                if not any(rep.has_residents() for rep in self.replicas):
                    nxt = queue.next_round()
                    if nxt is not None:
                        round_ = max(round_ + 1, nxt)
                        continue
                    if queue.drained():
                        break
                    queue.wait_nonempty()   # CV: submit()/close() wakes us
                    round_ += 1
                    continue
                # 8/9 per replica with residents: cut an epoch on
                #     membership change (load pages), then one decode
                #     round with per-slot positions and a chained emit
                for rep in self.replicas:
                    changed, joiners = rep.round_work
                    if not rep.has_residents():
                        continue
                    if changed or rep.carry is None:
                        rep.epoch += 1
                        rep.j = 0
                        joins = tuple((s, h.rid) for s, h in joiners)
                        rep.carry = runtime.defer(
                            self._refill_fn(rep, joins), rep.carry,
                            *[h._prefill for _, h in joiners],
                            name=f"refill:{rep.ns}e{rep.epoch}")
                    live_rows = tuple((h._slot, h.rid)
                                      for h in rep.residents if h is not None)
                    pos = np.full(self.slots, self.prompt_len, np.int32)
                    for s, rid in live_rows:
                        pos[s] = self.prompt_len \
                            + self._handles[rid]._emitted
                    rep.carry = runtime.defer(
                        self._decode_fn, rep.carry, jnp.asarray(pos),
                        name=f"decode:{rep.ns}e{rep.epoch}:t{rep.j}")
                    emit_deps = (rep.carry,) if rep.prev_emit is None \
                        else (rep.carry, rep.prev_emit)
                    rep.prev_emit = runtime.defer(
                        self._emit_fn(rep, live_rows), *emit_deps,
                        lane=Lane.CHECKPOINT,
                        name=f"emit:{rep.ns}e{rep.epoch}:t{rep.j}")
                    rep.emit_hist.append(rep.prev_emit)
                    if len(rep.emit_hist) > self.lookahead:  # bound the
                        rep.emit_hist.popleft().result()     # lead so
                    for _, rid in live_rows:                 # faults land
                        self._handles[rid]._emitted += 1
                    rep.j += 1
                round_ += 1
            # drain: force every replica's emit tail and every finish node
            for rep in self.replicas:
                if rep.prev_emit is not None:
                    rep.prev_emit.result()
            for fin in finishes:
                fin.result()
        finally:
            # never leave an unresolved promise behind (barrier/shutdown
            # would hang on it): anything non-terminal is failed out
            for h in intake:
                if not h._done.is_set():
                    self._resolve(h, "failed",
                                  RuntimeError(f"gateway torn down with "
                                               f"{h.rid} in flight"),
                                  "failed")
        self.runtime.record_serve(rejected=queue.rejected,
                                  **self._cache_counters())
        counts = collections.Counter(h.status for h in intake)
        return {"handles": intake,
                "streams": {h.rid: list(h.tokens) for h in intake},
                "completed": counts.get("done", 0),
                "cancelled": counts.get("cancelled", 0),
                "expired": counts.get("expired", 0),
                "failed": counts.get("failed", 0),
                "rejected": queue.rejected,
                "rounds": round_,
                "epochs": sum(rep.epoch + 1 for rep in self.replicas),
                "replicas": len(self.replicas),
                "replica_assignments": {h.rid: h._replica for h in intake
                                        if h._replica is not None},
                "cache": self._cache_counters()}
