"""``@futurize``: plain Python traced into the futurized execution tree.

Phylanx translates user Python into PhySL, where every function application
becomes a future whose execution is constrained only by its inputs
(DESIGN.md §2, §8).  This module is the jax-side analogue at the *host*
level: decorate a function with ``@futurize`` and, inside a ``tracing()``
block, each call becomes a ``FuturizedGraph`` node -

  * dependency edges are discovered from the arguments (any ``PhyFuture``
    anywhere inside nested containers, by pytree traversal - ``defer``'s
    contract);
  * control flow stays in Python: the user's loops and conditionals run
    eagerly and only the *calls* become nodes, so the traced tree is exactly
    the dynamic call structure;
  * outside a ``tracing()`` block - including on runtime worker threads,
    where a futurized function called by another futurized function lands -
    the call executes inline and returns a plain value (untraced fallback).

``Trace`` records the tree as it is built (via the graph's trace hook) and
exposes a deterministic ``signature()`` for tests and tooling: node names
are counted per trace (``load:0``, ``load:1``, ...), so the same program
traces to the same shape on every run.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import threading
from typing import Callable, Optional

from ..core.futures import FuturizedGraph, Lane, PhyFuture

__all__ = ["Trace", "TraceNode", "current_trace", "futurize", "tracing"]

_tls = threading.local()


def current_trace() -> Optional["Trace"]:
    """The innermost active ``tracing()`` context on this thread, if any."""
    stack = getattr(_tls, "stack", None)
    return stack[-1] if stack else None


@dataclasses.dataclass(frozen=True)
class TraceNode:
    index: int
    name: str
    lane: str
    deps: tuple            # indices of in-trace dependency nodes, sorted
    # structural metadata for the static linter (repro.analysis.lint):
    # node kind (task | promise | immediate | join) and, for promises,
    # the declared producer - absent from signature() on purpose, so the
    # trace-shape contract of PR 2 is unchanged
    kind: str = "task"
    producer: str = ""


class Trace:
    """The recorded shape of a futurized tree: one ``TraceNode`` per graph
    node added while the trace was installed, in submission order."""

    def __init__(self, graph: FuturizedGraph):
        self.graph = graph
        self.nodes: list[TraceNode] = []
        self._lock = threading.Lock()
        self._index: dict[int, int] = {}       # id(PhyFuture) -> node index
        self._names = collections.Counter()

    def next_name(self, base: str) -> str:
        with self._lock:
            k = self._names[base]
            self._names[base] += 1
        return f"{base}:{k}"

    def record(self, node: PhyFuture, deps: tuple):
        """Graph trace-hook target; safe to call from any thread."""
        with self._lock:
            idx = len(self.nodes)
            self._index[id(node)] = idx
            dep_ids = tuple(sorted(self._index[id(d)] for d in deps
                                   if id(d) in self._index))
            self.nodes.append(TraceNode(
                index=idx, name=node.name, lane=node.lane.name,
                deps=dep_ids, kind=getattr(node, "_kind", "task"),
                producer=getattr(node, "_producer", "")))

    def names(self) -> list[str]:
        return [n.name for n in self.nodes]

    def signature(self) -> list[tuple]:
        """Deterministic tree shape: ``[(name, lane, dep_indices), ...]`` in
        submission order - equal across runs of the same program."""
        return [(n.name, n.lane, n.deps) for n in self.nodes]


def futurize(fn: Optional[Callable] = None, *, lane: Lane = Lane.COMPUTE,
             name: Optional[str] = None):
    """Mark ``fn`` as a node of the futurized tree.

    Inside ``tracing()`` each call defers onto the active graph and returns
    a ``PhyFuture`` (composable with ``when_all`` / ``tree_join`` and any
    other deferred work); outside, the call runs inline.

    Args:
        fn: the function to wrap (or None when used as ``@futurize(...)``
            with keyword arguments).
        lane: priority lane its nodes ride.
        name: per-trace node name base (default ``fn.__name__``); calls
            become ``name:0``, ``name:1``, ... within a trace.
    Returns:
        The wrapped function (original accessible as ``__futurized__``).
    """
    if fn is None:
        return functools.partial(futurize, lane=lane, name=name)
    base = name or fn.__name__

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        tr = current_trace()
        if tr is None:
            return fn(*args, **kwargs)
        return tr.graph.defer(fn, *args, lane=lane,
                              name=tr.next_name(base), **kwargs)

    wrapper.__futurized__ = fn
    return wrapper


@contextlib.contextmanager
def tracing(graph: Optional[FuturizedGraph] = None, *, max_workers: int = 4,
            name: str = "traced"):
    """Activate futurized tracing: within the block, ``@futurize`` calls on
    this thread become nodes of ``graph`` (one is created - and shut down on
    exit - if not supplied).  Yields the ``Trace``."""
    own = graph is None
    g = graph if graph is not None else FuturizedGraph(
        max_workers=max_workers, name=name)
    tr = Trace(g)
    remove = g.add_trace_hook(tr.record)
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    stack.append(tr)
    try:
        yield tr
    finally:
        stack.pop()
        remove()
        if own:
            g.shutdown(wait=True)
