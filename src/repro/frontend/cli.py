"""Shared launcher flags: the `--arch/--tiny/--data/--model/--seq/--batch`
block that was copied across launch/train.py, launch/serve.py and
launch/dryrun.py lives here once, and maps 1:1 onto ``Plan`` fields."""
from __future__ import annotations

import argparse
from typing import Optional

from ..configs import ARCH_IDS
from .plan import Plan

__all__ = ["cli_args", "plan_from_args", "serve_flags"]


def cli_args(ap: Optional[argparse.ArgumentParser] = None, *,
             arch_default: Optional[str] = "qwen3-4b", tiny: bool = True,
             mesh: bool = True, batch: Optional[int] = None,
             seq: Optional[int] = None,
             seed: bool = True) -> argparse.ArgumentParser:
    """Add the shared launcher flags to ``ap`` (created if None).  ``batch``
    and ``seq`` are the default values when those flags apply (None omits
    them); ``arch_default=None`` adds ``--arch`` without a default."""
    if ap is None:
        ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=arch_default, choices=ARCH_IDS)
    if tiny:
        ap.add_argument("--tiny", action="store_true", default=True)
        ap.add_argument("--full", dest="tiny", action="store_false")
    if mesh:
        ap.add_argument("--data", type=int, default=1)
        ap.add_argument("--model", type=int, default=1)
        ap.add_argument("--localities", type=int, default=1,
                        help="total process count for the multi-locality "
                             "runtime (1 = in-process)")
        ap.add_argument("--spmd", action="store_true",
                        help="multi-host SPMD mode over jax.distributed "
                             "(needs --localities > 1): every process "
                             "trains in lockstep and checkpoints only "
                             "its addressable shards (DESIGN.md §10)")
        ap.add_argument("--ddp", action="store_true",
                        help="data-parallel training over the "
                             "active-message fabric: each locality "
                             "trains its own batch shards and gradients "
                             "are ring-all-reduced (DESIGN.md §11)")
        ap.add_argument("--grad-codec", dest="grad_codec",
                        default="fp32", choices=("fp32", "onebit"),
                        help="DDP gradient wire codec: fp32 (exact) or "
                             "onebit (1-bit + error feedback, ~1/31 of "
                             "the bytes)")
        ap.add_argument("--ddp-shards", dest="ddp_shards", type=int,
                        default=0,
                        help="batch shard count for --ddp (0 = one per "
                             "locality); must divide --batch and be a "
                             "multiple of --localities")
        ap.add_argument("--elastic", action="store_true",
                        help="elastic membership: accept --join dial-ins "
                             "mid-run, arm the work-stealing loop on "
                             "every locality, and rebalance AGAS objects "
                             "toward newcomers (DESIGN.md §13)")
        ap.add_argument("--elastic-port", dest="elastic_port", type=int,
                        default=0,
                        help="fixed driver listen port for --join "
                             "dialers (0 = ephemeral; printed at start)")
    if seq is not None:
        ap.add_argument("--seq", type=int, default=seq)
    if batch is not None:
        ap.add_argument("--batch", type=int, default=batch)
    if seed:
        ap.add_argument("--seed", type=int, default=0)
    return ap


def serve_flags(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """The serving-gateway flag block (``launch/serve.py``): opt into the
    streamed gateway and its admission-control knobs (DESIGN.md §14)."""
    ap.add_argument("--serve-stream", dest="serve_stream",
                    action="store_true",
                    help="serve through the continuous-batching gateway "
                         "(Session.serve_stream): requests arrive "
                         "mid-flight, prefill state parks in the paged "
                         "inference cache, slot refill loads pages "
                         "instead of recomputing")
    ap.add_argument("--max-inflight", dest="max_inflight", type=int,
                    default=None,
                    help="admission cap on requests holding resources "
                         "(queued-for-a-slot + decoding); default "
                         "2 * slots")
    ap.add_argument("--deadline-ms", dest="deadline_ms", type=float,
                    default=None,
                    help="per-request deadline: a request still short of "
                         "a decode slot when it lapses expires cleanly "
                         "(its node chain is cancelled and its pages "
                         "reclaimed)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="model replica count for --serve-stream "
                         "(DESIGN.md §15): each replica is a prefill/"
                         "decode pair homed on its own locality, and the "
                         "gateway router assigns every request to "
                         "exactly one (page affinity first); streams are "
                         "bit-identical to --replicas 1")
    ap.add_argument("--kill-replica-at", dest="kill_replica_at",
                    default=None, metavar="IDX:ROUND",
                    help="replica-death drill for --serve-stream: mark "
                         "replica IDX dead at decode round ROUND; "
                         "survivors absorb its queued and in-flight "
                         "requests (e.g. 0:2)")
    ap.add_argument("--stats-out", dest="stats_out", default=None,
                    metavar="FILE",
                    help="write the serve summary (gateway counters, "
                         "per-replica split, latency histograms) as JSON "
                         "to FILE - the CI drills assert on it")
    return ap


def plan_from_args(args, **overrides) -> Plan:
    """Build a ``Plan`` from a ``cli_args()`` namespace; keyword overrides
    (e.g. a full ``strategy=Strategy(...)``) win over parsed flags."""
    fields = {name: getattr(args, name)
              for name in ("arch", "tiny", "data", "model", "batch", "seq",
                           "seed", "localities", "spmd", "ddp",
                           "grad_codec", "ddp_shards", "elastic",
                           "elastic_port", "replicas")
              if hasattr(args, name)}
    if hasattr(args, "ckpt"):       # --ckpt -> Plan.ckpt_dir, so worker
        fields["ckpt_dir"] = args.ckpt   # localities get it at spawn
    fields.update(overrides)
    return Plan(**fields)
