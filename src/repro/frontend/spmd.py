"""The SPMD shadow train loop: what a worker locality runs under
``Plan(spmd=True)`` (DESIGN.md §10).

The CPU backend cannot execute one jit across processes, so the
multi-host mode keeps every process's *compute* local and bit-identical
instead: each process builds the same config / local mesh / step
functions / synthetic stream from the same ``Plan`` and steps them in
lockstep - deterministic init (same seed), deterministic batches (keyed
by step index), deterministic CPU kernels - which is exactly the state
evolution a true SPMD program would give each host for its replicated
parameters.  What IS distributed is persistence: at every save point
this loop serializes only the addressable shards of its global
persistence view (``checkpoint.spmd.write_spmd_shard``) into the shared
checkpoint directory, and posts the driver just the manifest *entry*
(offsets, checksums - metadata).  No leaf bytes cross the messaging
layer in either direction.

The loop is started by a ``spmd_train`` active message
(``DistributedGraph.spmd_train`` -> ``Locality._on_spmd_train``) and
reports completion through a ``spmd_done`` post.

Lockstep invariants this loop mirrors from ``Session.train`` - drift
here would corrupt checkpoints (segments from different logical steps):
  * params/opt come from ``step.init(PRNGKey(plan.seed))``;
  * batch ``it`` is ``stream.batch_at(it)`` placed against the step's
    batch shardings;
  * the state advances ONLY through ``step.fn``;
  * saves happen when ``(it + 1) % ckpt_every == 0``, plus a final save
    when ``steps % ckpt_every != 0``, always after the step retired
    (``block_until_ready``);
  * a resume restores the same latest checkpoint the driver restores
    (shared directory, committed manifests only).
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Any, Optional

import jax

from ..checkpoint import spmd as ckspmd
from ..checkpoint.checkpoint import CheckpointManager
from ..core import steps as steps_lib
from ..data.pipeline import stream_for

__all__ = ["shadow_train"]


def shadow_train(spec: dict, endpoint: Optional[Any] = None) -> int:
    """Mirror ``Session.train``'s device computation on this process and
    write this process's checkpoint shards (see module docstring).

    Args:
        spec: ``{"plan", "steps", "ckpt_every", "ckpt_dir", "resume",
            "stream"}`` as posted by ``DistributedGraph.spmd_train``.
        endpoint: this locality's active-message ``Endpoint``; shard
            manifest entries are posted to the driver through it (None
            writes shards without reporting - test use).
    Returns:
        The final step count.
    """
    plan = spec["plan"]
    steps: int = spec["steps"]
    ckpt_every: int = spec.get("ckpt_every") or 0
    ckpt_dir: str = spec.get("ckpt_dir") or ""
    rank = int(os.environ.get("PHYRAX_LOCALITY_RANK", "0"))
    cfg = plan.config()
    mesh = plan.build_mesh()           # local devices (launch.mesh)
    strategy = plan.build_strategy()
    step = steps_lib.make_train_step(cfg, mesh, strategy, plan=plan)
    params, opt = step.init(jax.random.PRNGKey(plan.seed))
    start = 0
    if spec.get("resume") and ckpt_dir:
        with CheckpointManager(ckpt_dir, async_save=False) as cm:
            if cm.latest_step() is not None:
                start, (params, opt) = cm.restore(
                    (params, opt),
                    shardings=(step.param_shardings, step.opt_shardings))
    stream = spec.get("stream")
    if stream is None:
        stream = stream_for(cfg, batch=plan.batch, seq=plan.seq,
                            seed=plan.seed)
    shardings = step.batch_shardings or {}

    def save(s: int, state):
        tmp = Path(ckpt_dir) / f".tmp_step_{s:08d}"
        entry = ckspmd.write_spmd_shard(str(tmp), rank, state)
        if endpoint is not None:
            endpoint.post(0, "ckpt_entries",
                          {"step": int(s), "rank": rank, "entry": entry})

    metrics = None
    for it in range(start, steps):
        batch = {k: jax.device_put(v, shardings.get(k))
                 for k, v in stream.batch_at(it).items()}
        metrics, params, opt = step.fn(params, opt, batch)
        if ckpt_dir and ckpt_every and (it + 1) % ckpt_every == 0:
            jax.block_until_ready(metrics)   # save only retired state
            save(it + 1, (params, opt))
    if ckpt_dir and ckpt_every and steps % ckpt_every != 0:
        if metrics is not None:
            jax.block_until_ready(metrics)
        save(steps, (params, opt))     # mirrors the driver's final save
    return steps
