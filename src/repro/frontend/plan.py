"""Plan/Session: the declarative frontend over the futurized runtime.

A ``Plan`` is the *what* of a run - architecture, mesh axes, strategy,
shapes - a frozen value that touches no device state.  ``plan.compile()``
builds a ``Session``: the mesh is made, step functions are jitted lazily,
and ONE futurized runtime (`core/futures.py`) owns every host-side task of
the session - prefetch, metric forcing, checkpoint I/O, serve wave prep and
the decode chain.  ``session.train`` / ``session.serve`` / ``session.dryrun``
subsume the old launcher bodies; ``launch/{train,serve,dryrun}.py`` are now
thin argparse shims over this API (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import os
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpoint import CheckpointManager
from ..configs import SHAPES, get_config
from ..core import hlo_costs
from ..core import steps as steps_lib
from ..core.futures import FuturizedGraph, Lane, Pipeline
from ..core.resilience import ResilientRunner
from ..core.sharding import init_params, param_structs
from ..data.pipeline import Prefetcher, stream_for
from ..launch.mesh import make_local_mesh, make_production_mesh, mesh_devices
from .futurize import Trace

__all__ = ["Plan", "Session", "cell_is_applicable", "lower_cell",
           "roofline_terms"]

# TPU v5e roofline model constants (per chip); used by session.dryrun and
# the launch/dryrun.py sweep
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9
ICI_LINKS = 3
HBM_BYTES = 16e9


def _stack_wave(wave):
    """Host prep of one serve wave (module-level: ships to a worker
    locality by reference when ``plan.localities > 1``)."""
    return np.stack(wave)


@dataclasses.dataclass(frozen=True)
class Plan:
    """Declarative run description: arch + mesh axes + strategy + shapes.

    A frozen value - building one touches no device state; compile it
    with ``compile()`` to get a runnable ``Session``.

    Fields:
        arch: architecture id from ``configs.ARCH_IDS``.
        tiny: use the reduced smoke-scale config.
        data, model, pod: local mesh axis sizes (``mesh="local"``).
        mesh: "local" (axis sizes over host devices) or "single" /
            "multipod" (the production 256/512-chip meshes).
        strategy: a ``core.steps.Strategy`` or a bare name
            ("phylanx" | "horovod" | "zero1" | "onebit").
        batch, seq: global batch and sequence length when no named
            ``shape`` is given.
        seed: PRNG seed for params and synthetic streams.
        shape: optionally a named cell of ``configs.SHAPES`` (dry-run).
        remat: enable rematerialization on tiny configs.
        localities: total process count for the multi-locality runtime
            (DESIGN.md §9).  1 runs everything in-process; N > 1 spawns
            N-1 worker localities at ``compile()`` and host-side graph
            nodes (prefetch builds, serve wave prep, checkpoint shard
            writes) are placed on them by lane + data affinity.  Device
            dispatch stays on the driver either way.
        spmd: multi-host SPMD mode (DESIGN.md §10; needs
            ``localities > 1``).  ``compile()`` stands up
            ``jax.distributed`` across all localities (the driver picks
            a loopback coordinator and is process 0), every process
            computes the train loop in deterministic lockstep on its
            local mesh, and checkpoints switch to addressable-shard
            serialization: each process writes only the blocks of the
            global persistence view it addresses - zero checkpoint leaf
            bytes cross the messaging layer.  Only ``session.train``
            supports this mode.
        ddp: data-parallel training over the active-message fabric
            (DESIGN.md §11).  The global batch is split into
            ``ddp_shards`` row shards; each locality computes gradients
            for its contiguous shard block, sums them across processes
            with a ring all-reduce of ``grad_codec``-encoded active
            messages, and applies the identical optimizer step - so
            parameters stay replicated without crossing the wire.
            Exclusive with ``spmd``; only ``session.train`` supports it.
        grad_codec: wire codec for the DDP gradient exchange: "fp32"
            (exact - the multi-process run is bit-identical in loss to
            a 1-locality run over the same shards) or "onebit" (1-bit
            signs + per-1024-row scales with error feedback, ~1/31 of
            the fp32 bytes).
        ddp_shards: batch shard count for ``ddp=True``; 0 means one
            shard per locality.  Must be a multiple of ``localities``
            and divide ``batch``; raise it to emulate a bigger world on
            fewer processes (the loss trajectory depends on the shard
            count, not the process count).
        ckpt_dir: checkpoint directory for ``session.train`` ("" leaves
            it to the ``ckpt_dir=`` argument).  All localities write
            their own shards into this one directory (DESIGN.md §10),
            so it must be shared across them (trivially true on one
            machine; a shared mount across hosts); worker localities
            receive it at spawn via ``PHYRAX_CKPT_DIR``.
        elastic: elastic membership + work stealing (DESIGN.md §13).
            The driver accepts dial-in joins (``--join host:port`` /
            ``Session.add_locality()``) mid-run; every locality runs the
            idle-thief steal loop, so newcomers pull work immediately;
            AGAS rebalances pinned objects toward them.  Exclusive with
            ``spmd`` and ``ddp`` (fixed-world collectives).  A
            ``DistributedGraph`` exists even with ``localities=1`` so a
            1-process run can scale out.
        elastic_port: fixed driver listen port for ``--join`` dialers
            (0 = ephemeral; only meaningful with ``elastic=True``).
        replicas: ``serve_stream`` model replicas (DESIGN.md §15).  Each
            replica is a prefill/decode pair with its own slots and
            named page cache, homed on its own locality when
            ``localities > 1``; the gateway router assigns every request
            to exactly one replica (page affinity first).  Token streams
            are bit-identical across replica counts.
        overrides: config field overrides applied last.
    """
    arch: str = "qwen3-4b"
    tiny: bool = True
    data: int = 1
    model: int = 1
    pod: int = 1
    mesh: str = "local"                  # local | single | multipod
    strategy: Any = "phylanx"
    batch: int = 8
    seq: int = 64
    seed: int = 0
    shape: Optional[str] = None          # named SHAPES cell (dryrun)
    remat: bool = False
    localities: int = 1                  # processes incl. the driver
    spmd: bool = False                   # jax.distributed SPMD mode (§10)
    ddp: bool = False                    # fabric data parallelism (§11)
    grad_codec: str = "fp32"             # DDP wire codec: fp32 | onebit
    ddp_shards: int = 0                  # batch shards (0 = localities)
    ckpt_dir: str = ""                   # shared checkpoint dir (§10)
    elastic: bool = False                # dial-in joins + stealing (§13)
    elastic_port: int = 0                # --join listen port (0 = any)
    replicas: int = 1                    # serve_stream model replicas (§15)
    overrides: dict = dataclasses.field(default_factory=dict)

    # -- resolution ---------------------------------------------------------
    def config(self):
        cfg = get_config(self.arch, tiny=self.tiny)
        over = dict(self.overrides)
        if self.tiny:
            over.setdefault("remat", self.remat)
        return dataclasses.replace(cfg, **over) if over else cfg

    def build_mesh(self):
        if self.mesh == "local":
            return make_local_mesh(data=self.data, model=self.model,
                                   pod=self.pod)
        return make_production_mesh(multi_pod=(self.mesh == "multipod"))

    def build_strategy(self) -> steps_lib.Strategy:
        if isinstance(self.strategy, steps_lib.Strategy):
            return self.strategy
        return steps_lib.Strategy(name=self.strategy)

    def shape_of(self, kind: str) -> dict:
        if self.shape is not None:
            return dict(SHAPES[self.shape])
        return {"seq_len": self.seq, "global_batch": self.batch,
                "kind": kind}

    def resolve(self, kind: str, *, cfg=None, mesh=None, strategy=None,
                shape=None) -> tuple:
        """(cfg, mesh, strategy, shape) with explicit arguments winning -
        the hook the ``core.steps`` builders call for ``plan=``."""
        return (cfg if cfg is not None else self.config(),
                mesh if mesh is not None else self.build_mesh(),
                strategy if strategy is not None else self.build_strategy(),
                shape if shape is not None else self.shape_of(kind))

    def compile(self) -> "Session":
        """Build the runnable ``Session`` for this plan (makes the mesh,
        spawns worker localities when ``localities > 1``).

        Returns:
            A ``Session``; use it as a context manager so the shutdown
            barrier (and worker teardown) always runs.
        """
        return Session(self)


class Session:
    """Compiled form of a ``Plan``: mesh + strategy + lazily-built step
    functions, and one futurized runtime for every host-side task.  Use as
    a context manager (or call ``close()``) to run the shutdown barrier.

    With ``plan.localities > 1`` the session also owns a
    ``repro.distrib.DistributedGraph`` (``self.distributed``): worker
    localities are spawned here and host-side nodes are transparently
    placed on them; ``close()`` drains the distributed graph before the
    local shutdown barrier, so worker teardown never strands a promise.
    """

    def __init__(self, plan: Plan, *, max_workers: int = 4):
        self.plan = plan
        self.cfg = plan.config()
        self.strategy = plan.build_strategy()
        self.runtime = FuturizedGraph(max_workers=max_workers,
                                      name=f"session:{plan.arch}")
        self.distributed = None
        if plan.spmd and plan.localities < 2:
            raise ValueError("Plan(spmd=True) needs localities >= 2: "
                             "SPMD mode is the multi-process path")
        if plan.ddp and plan.spmd:
            raise ValueError("Plan(ddp=True) and Plan(spmd=True) are "
                             "exclusive multi-process modes: ddp shards "
                             "the batch, spmd mirrors it")
        if plan.elastic and (plan.spmd or plan.ddp):
            raise ValueError(
                "Plan(elastic=True) does not compose with spmd or ddp: "
                "their collectives assume a fixed world; elastic "
                "membership is for the task-parallel runtime")
        if plan.ddp:
            from ..distrib.collectives import CODECS
            if plan.grad_codec not in CODECS:
                raise ValueError(f"unknown grad_codec "
                                 f"{plan.grad_codec!r} (have: "
                                 f"{sorted(CODECS)})")
            world = max(plan.localities, 1)
            shards = plan.ddp_shards or world
            if shards % world:
                raise ValueError(f"ddp_shards={shards} must be a "
                                 f"multiple of localities={world}")
            if plan.batch % shards:
                raise ValueError(f"batch={plan.batch} must be divisible "
                                 f"by ddp_shards={shards}")
        if plan.localities > 1 or plan.elastic:
            from ..distrib import DistributedGraph
            # workers get the checkpoint dir at spawn (PHYRAX_CKPT_DIR):
            # each locality pre-creates it and writes its own shards
            # there (DESIGN.md §10)
            env = {"PHYRAX_CKPT_DIR": plan.ckpt_dir} if plan.ckpt_dir \
                else {}
            init_thread = None
            if plan.spmd:
                env, init_thread = self._start_jax_distributed(env)
            join_spec = None
            if plan.elastic:
                # dial-in joiners adopt the same environment the spawned
                # workers get (checkpoint dir, sanitizer arming...)
                join_env = dict(env)
                for k in ("PHYRAX_SANITIZE",):
                    if os.environ.get(k):
                        join_env[k] = os.environ[k]
                join_spec = {"env": join_env}
            self.distributed = DistributedGraph(
                localities=plan.localities, graph=self.runtime,
                worker_env=env or None, name=f"session:{plan.arch}",
                elastic=plan.elastic, elastic_port=plan.elastic_port,
                join_spec=join_spec)
            if init_thread is not None:
                init_thread.join(timeout=120.0)
                if init_thread.is_alive():
                    raise TimeoutError(
                        "jax.distributed.initialize did not complete "
                        "on the driver")
                if self._spmd_init_error:
                    raise self._spmd_init_error[0]
        # the mesh is built AFTER jax.distributed init (SPMD mode must
        # see the multi-process world to pick local devices)
        self.mesh = plan.build_mesh()
        self._train_step = None
        self._serve_steps: dict[tuple, tuple] = {}
        self._closed = False

    def _start_jax_distributed(self, env: dict):
        """SPMD bring-up: pick a loopback coordinator, export it to the
        workers' spawn environment, and start the driver's own
        ``jax.distributed.initialize`` (process 0) on a thread - it
        blocks until every process joins, and the workers are only
        spawned by the ``DistributedGraph`` constructed next."""
        import threading

        from ..launch.mesh import free_port, maybe_init_jax_distributed
        coord = f"127.0.0.1:{free_port()}"
        # the coordinator reaches the WORKERS via their spawn env and
        # the driver via explicit arguments: this process's os.environ
        # stays untouched, so a later non-SPMD Session in the same
        # interpreter cannot inherit a stale coordinator
        env = dict(env)
        env["PHYRAX_JAX_COORDINATOR"] = coord
        env["PHYRAX_JAX_NUM_PROCESSES"] = str(self.plan.localities)
        self._spmd_init_error: list = []

        def init():
            try:
                maybe_init_jax_distributed(
                    process_id=0, num_processes=self.plan.localities,
                    coordinator=coord)
            except BaseException as e:  # noqa: BLE001 - re-raised above
                self._spmd_init_error.append(e)

        t = threading.Thread(target=init, daemon=True,
                             name="jax-distributed-init")
        t.start()
        return env, t

    # -- lifecycle ----------------------------------------------------------
    def close(self):
        """Run the shutdown barrier: drain distributed tasks, stop worker
        localities, then drain and stop the local runtime.  In SPMD mode
        the driver also joins the ``jax.distributed`` shutdown barrier
        concurrently with telling the workers to exit - every process
        must arrive at that barrier or teardown turns fatal.
        Idempotent."""
        if not self._closed:
            self._closed = True
            if self.distributed is not None:
                jd_thread = None
                if self.plan.spmd:
                    import threading

                    def _jd_shutdown():
                        try:
                            jax.distributed.shutdown()
                        except Exception:  # noqa: BLE001 - best effort
                            pass
                    jd_thread = threading.Thread(
                        target=_jd_shutdown, daemon=True,
                        name="jax-distributed-shutdown")
                    jd_thread.start()
                self.distributed.shutdown(wait=True)
                if jd_thread is not None:
                    jd_thread.join(timeout=60.0)
            self.runtime.shutdown(wait=True)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self):
        """The session runtime's ``RuntimeStats`` (see its docstring for
        the ``to_json`` schema)."""
        return self.runtime.stats()

    def lint(self, *, strict_lanes: bool = False):
        """Run the static phylint passes over this session's live graph.

        Snapshots every node the runtime still holds (in-flight and
        recently retired) and applies the PHY001-PHY006 rule set
        (DESIGN.md §12).  Works for any locality count - unlike the
        dryrun mirrors in ``repro.analysis.trace_builders``, this sees
        the promise/dispatch pairs a distributed run actually created.

        Returns:
            List of ``repro.analysis.lint.Finding``, empty when clean.
        """
        from ..analysis import lint as lint_mod

        return lint_mod.lint(lint_mod.LintGraph.from_graph(self.runtime),
                             strict_lanes=strict_lanes)

    @property
    def join_address(self) -> Optional[tuple]:
        """``(host, port)`` a ``--join`` dialer should use, or None when
        the session is not elastic."""
        if self.distributed is None or not self.plan.elastic:
            return None
        return tuple(self.distributed.endpoint.address)

    def add_locality(self, timeout: float = 120.0) -> int:
        """Elastic scale-out (DESIGN.md §13): spawn one extra worker
        locality into the *running* session and block until it is a full
        member - peers gossiped, AGAS rebalanced, steal loop armed.
        Safe to call from a training hook; subsequent steerable host
        tasks may be stolen by (or diverted to) the newcomer.

        Returns:
            The new locality's rank.
        Raises:
            RuntimeError: the session was not compiled from an elastic
                plan.
        """
        if self.distributed is None or not self.plan.elastic:
            raise RuntimeError("add_locality needs Plan(elastic=True)")
        return self.distributed.add_locality(timeout=timeout)

    def kill_locality(self, rank: Optional[int] = None) -> Optional[int]:
        """Failure drill: SIGKILL a worker locality (the highest-ranked
        alive one by default).  Its in-flight tasks re-spawn elsewhere.

        Returns:
            The killed rank, or None when no worker locality is alive.
        """
        if self.distributed is None:
            return None
        alive = self.distributed.group.alive_workers()
        if not alive:
            return None
        rank = alive[-1] if rank is None else rank
        self.distributed.group.kill(rank)
        return rank

    # -- steps --------------------------------------------------------------
    @property
    def train_step(self) -> steps_lib.TrainStep:
        if self._train_step is None:
            # already-resolved session state wins; the plan fills the shape
            self._train_step = steps_lib.make_train_step(
                self.cfg, self.mesh, self.strategy, plan=self.plan)
        return self._train_step

    def _serve_steps_for(self, prompt_len: int, gen_len: int, slots: int):
        key = (prompt_len, gen_len, slots)
        if key not in self._serve_steps:
            cache_len = prompt_len + gen_len
            pre = steps_lib.make_prefill_step(
                self.cfg, self.mesh, self.strategy,
                {"seq_len": cache_len, "global_batch": slots,
                 "kind": "prefill"})
            dec = steps_lib.make_decode_step(
                self.cfg, self.mesh, self.strategy,
                {"seq_len": cache_len, "global_batch": slots,
                 "kind": "decode"})
            self._serve_steps[key] = (pre, dec)
        return self._serve_steps[key]

    # -- train --------------------------------------------------------------
    def train(self, stream=None, *, steps: int = 50, hooks: Any = None,
              ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
              log_every: int = 5,
              resume: bool = False, fail_at_step: Optional[int] = None,
              kill_locality_at_step: Optional[int] = None,
              resilience: str = "none", verbose: bool = True) -> dict:
        """The training loop the old ``launch/train.py`` hand-wired: stream
        -> prefetch nodes -> step -> in-flight pipeline -> async checkpoint
        nodes, all on the session runtime.  With ``plan.localities > 1``
        the prefetch *builds* run on worker localities and stream back;
        placement and device dispatch stay here, so the loss trajectory
        is identical to the single-process run.

        With ``plan.ddp=True`` the body is the fabric-DDP loop instead
        (DESIGN.md §11): every locality - the driver included - trains
        its own shard block of the batch and gradients are summed over
        the active-message ring; the result dict (and the report's
        ``grad-wire`` line) gains ``grad_wire_bytes``, the exact
        gradient payload bytes the driver sent.

        Args:
            stream: object with ``batch_at(step) -> dict``; defaults to
                the architecture's synthetic stream (``stream_for``).
                Must be picklable when localities > 1.
            steps: total step count (absolute, not incremental).
            hooks: any object with optional ``on_step(it, metrics)``,
                ``on_log(it, loss)`` and ``on_checkpoint(step, future)``
                methods.
            ckpt_dir: checkpoint directory (defaults to
                ``plan.ckpt_dir``; empty disables snapshots).  With
                ``plan.localities > 1`` every save is split into
                locality-owned shards written by their owners as
                CHECKPOINT-lane tasks, and resumes read shards across
                the current localities - including a checkpoint written
                by a different locality count (DESIGN.md §10).
            ckpt_every / log_every: cadence in steps.
            resume: restore the latest checkpoint in ``ckpt_dir`` first.
            fail_at_step: drill seam - raise an injected node failure at
                this step (ignored under ``resume``).
            kill_locality_at_step: drill seam - SIGKILL a worker
                locality at this step; training must survive via task
                re-spawn (no-op when localities == 1).
            resilience: "none" | "replay" | "replicate" (HPX-style step
                resilience, ``core.resilience``).
            verbose: print progress and the final runtime report.
        Returns:
            dict with ``final_loss``, per-log ``losses``, ``params``,
            ``step``, and ``runtime_stats`` (the documented
            ``RuntimeStats.to_json`` schema, plus ``distributed`` when
            localities > 1).
        Raises:
            RuntimeError: the injected failure of ``fail_at_step``.
        """
        if self.plan.ddp:
            return self._train_ddp(
                stream, steps=steps, hooks=hooks, ckpt_dir=ckpt_dir,
                ckpt_every=ckpt_every, log_every=log_every, resume=resume,
                fail_at_step=fail_at_step,
                kill_locality_at_step=kill_locality_at_step,
                resilience=resilience, verbose=verbose)
        plan, runtime, step = self.plan, self.runtime, self.train_step
        spmd_mode = plan.spmd and self.distributed is not None
        if spmd_mode and resilience != "none":
            raise ValueError("resilience modes are not mirrored by the "
                             "SPMD shadow loop; use resilience='none' "
                             "with Plan(spmd=True)")
        if spmd_mode and kill_locality_at_step is not None:
            raise ValueError(
                "kill_locality_at_step is a multi-locality drill: a "
                "jax.distributed world does not survive losing a "
                "process (coordination-service teardown is collective). "
                "Drill SPMD host loss with fail_at_step + a --resume "
                "run on a different process count instead")
        if ckpt_dir is None:
            ckpt_dir = plan.ckpt_dir
        if stream is None:
            stream = stream_for(self.cfg, batch=plan.batch, seq=plan.seq,
                                seed=plan.seed)
        params, opt = step.init(jax.random.PRNGKey(plan.seed))
        start = 0

        ckpt = (CheckpointManager(ckpt_dir, keep=3, graph=runtime,
                                  dgraph=self.distributed)
                if ckpt_dir else None)
        if ckpt is not None and resume:
            latest = ckpt.latest_step()
            if latest is not None:
                start, (params, opt) = ckpt.restore(
                    (params, opt),
                    shardings=(step.param_shardings, step.opt_shardings))
                if verbose:
                    print(f"[train] resumed from step {start}")

        if spmd_mode:
            # every worker process mirrors this loop in lockstep and
            # writes its own addressable checkpoint shards (DESIGN.md
            # §10); batches build locally on each process, so nothing
            # here is deferred to workers
            self.distributed.spmd_train({
                "plan": plan, "steps": steps, "ckpt_every": ckpt_every,
                "ckpt_dir": ckpt_dir, "resume": resume, "stream": stream})
        prefetch = Prefetcher(stream, step.batch_shardings, graph=runtime,
                              dgraph=None if spmd_mode
                              else self.distributed)
        runner = (ResilientRunner(step.fn_nodonate)
                  if resilience in ("replay", "replicate") else None)
        inflight = Pipeline(depth=2)
        log_futs: list = []
        t_log = time.time()
        on_step = getattr(hooks, "on_step", None)
        on_log = getattr(hooks, "on_log", None)
        on_ckpt = getattr(hooks, "on_checkpoint", None)

        def _force_and_log(it, m, t_start):
            # Runs on a runtime worker: forcing metrics never stalls dispatch.
            loss = float(m["loss"])
            dt = (time.time() - t_start) / log_every
            if verbose:
                print(f"[train] step {it + 1:5d} loss {loss:8.4f} "
                      f"gnorm {float(m['grad_norm']):8.3f} "
                      f"{dt * 1e3:8.1f} ms/step", flush=True)
            if on_log is not None:
                on_log(it, loss)
            return loss

        metrics = None
        try:
            for it in range(start, steps):
                if kill_locality_at_step is not None \
                        and it == kill_locality_at_step:
                    killed = self.kill_locality()
                    if verbose and killed is not None:
                        print(f"[train] drill: killed locality {killed} "
                              f"at step {it}", flush=True)
                batch = prefetch.get(it)
                if fail_at_step is not None and it == fail_at_step \
                        and not resume:
                    raise RuntimeError(f"injected node failure at step {it}")
                if resilience == "replay":
                    metrics, params, opt = runner.replay(params, opt, batch)
                elif resilience == "replicate":
                    metrics, params, opt = runner.replicate(params, opt,
                                                            batch, n=2)
                else:
                    metrics, params, opt = step.fn(params, opt, batch)
                inflight.push(it, metrics)
                if on_step is not None:
                    on_step(it, metrics)
                if (it + 1) % log_every == 0:
                    # CHECKPOINT lane: forcing metrics for logs must never
                    # outrank the PREFETCH nodes the loop blocks on next
                    log_futs.append(runtime.defer(
                        _force_and_log, it, metrics, t_log,
                        lane=Lane.CHECKPOINT, name=f"log:{it}"))
                    t_log = time.time()
                if ckpt is not None and (it + 1) % ckpt_every == 0:
                    # The write node depends on step retirement: file I/O
                    # starts only after the step's outputs resolve on device.
                    retired = runtime.defer(jax.block_until_ready, metrics,
                                            lane=Lane.CHECKPOINT,
                                            name=f"retire:{it}")
                    fut = ckpt.save(it + 1, (params, opt), deps=(retired,),
                                    meta={"arch": plan.arch})
                    if on_ckpt is not None:
                        on_ckpt(it + 1, fut)
            inflight.drain()
            # final snapshot - unless the loop's cadence already saved
            # this exact step (no duplicate serialize/ship/write, and no
            # rmtree+rename window over a just-committed directory)
            if ckpt is not None and steps % ckpt_every != 0:
                ckpt.save(steps, (params, opt), meta={"arch": plan.arch})
        finally:
            # Shutdown barrier - also on the injected-failure path, so a
            # crash never loses a save that was already requested: retire
            # in-flight steps, land every pending checkpoint node.  The
            # runtime itself stays up: it belongs to the session.
            inflight.drain()
            prefetch.close()       # cancel batches nobody will consume
            if ckpt is not None:
                ckpt.close()
            runtime.barrier()

        if spmd_mode:
            # the shadows have posted every entry this run's saves needed
            # (ckpt.close() waited on the commits); now surface a shadow
            # that FAILED - its checkpoints were silently aborted
            done = self.distributed.wait_spmd_done(timeout=600.0)
            failed = [m for m in done.values() if not m.get("ok")]
            if failed:
                raise RuntimeError(
                    f"SPMD shadow train loop failed on locality "
                    f"{failed[0]['rank']}: {failed[0].get('error')}")

        losses = [f.result() for f in log_futs]
        st = runtime.stats()
        stats_json = st.to_json()
        dstats = (self.distributed.stats()
                  if self.distributed is not None else None)
        if dstats is not None:
            stats_json["distributed"] = dstats
        if metrics is None:    # resumed at/after steps: nothing left to run
            if verbose:
                print(f"[train] nothing to do: resumed at step {start} "
                      f">= steps {steps}")
            return {"final_loss": float("nan"), "losses": losses,
                    "params": params, "step": start,
                    "runtime_stats": stats_json}
        final = float(metrics["loss"])
        if verbose:
            print(f"[train] done: final loss {final:.4f} "
                  f"(host tasks {st.completed}, "
                  f"max in-flight {st.max_in_flight})")
            hist = stats_json["lane_time_hist"]
            print(f"[train] task wall-time buckets "
                  f"{' '.join(hist['labels'])} "
                  f"(edges_s={hist['edges_s']})")
            for line in st.hist_lines():
                print(f"[train] task wall-time {line}")
            if dstats is not None:
                print(f"[train] localities: dispatched "
                      f"{dstats['dispatched']} respawned "
                      f"{dstats['respawned']} wire "
                      f"{dstats['bytes_sent']}B out / "
                      f"{dstats['bytes_recv']}B in "
                      f"ckpt-leaf-wire {dstats['ckpt_leaf_wire_bytes']}B")
                if self.plan.elastic:
                    print(f"[train] elastic: joined "
                          f"{dstats['joined_localities']} stolen "
                          f"{dstats['stolen_tasks']} migrated "
                          f"{dstats['migrated_objects']} objects "
                          f"(membership gen "
                          f"{dstats['membership_gen']})")
            if ckpt is not None and ckpt.aborted_saves:
                print(f"[train] WARNING: {ckpt.aborted_saves} SPMD "
                      f"save(s) aborted with a lost writer; the last "
                      f"committed checkpoint is step "
                      f"{ckpt.latest_step()}")
        return {"final_loss": final, "losses": losses,
                "params": params, "step": steps,
                "runtime_stats": stats_json}

    def _train_ddp(self, stream, *, steps, hooks, ckpt_dir, ckpt_every,
                   log_every, resume, fail_at_step, kill_locality_at_step,
                   resilience, verbose) -> dict:
        """The ``Plan(ddp=True)`` body of ``train`` (DESIGN.md §11): the
        driver is ring rank 0 and trains its own shard block in-process
        while ``ddp_train`` active messages start the same loop
        (``frontend.ddp.ddp_shadow_train``) on every worker locality.
        Checkpoints are driver-only - parameters are replicated, so the
        driver's save IS the global state; a failure anywhere poisons
        the ring (``ddp_abort``), so no locality ever hangs."""
        from ..distrib.collectives import RingAllReduce
        from .ddp import DDPEngine
        plan, runtime = self.plan, self.runtime
        if resilience != "none":
            raise ValueError("resilience modes do not compose with "
                             "Plan(ddp=True): the ring's abort-on-loss "
                             "failure model replaces step replay")
        if ckpt_dir is None:
            ckpt_dir = plan.ckpt_dir
        if stream is None:
            stream = stream_for(self.cfg, batch=plan.batch, seq=plan.seq,
                                seed=plan.seed)
        ring = (self.distributed.grad_ring
                if self.distributed is not None else RingAllReduce(None, 1))
        engine = DDPEngine(plan, ring)
        step = engine.step
        params, opt = engine.init()
        start = 0
        ckpt = (CheckpointManager(ckpt_dir, keep=3, graph=runtime)
                if ckpt_dir else None)
        if ckpt is not None and resume:
            if ckpt.latest_step() is not None:
                start, (params, opt) = ckpt.restore(
                    (params, opt),
                    shardings=(step.param_shardings, step.opt_shardings))
                if verbose:
                    print(f"[train] resumed from step {start}")
        if self.distributed is not None:
            self.distributed.ddp_train({
                "plan": plan, "steps": steps, "ckpt_dir": ckpt_dir,
                "resume": resume, "stream": stream, "gen": ring.gen})
        # no shardings: the driver slices its own shards from the raw
        # host batch, exactly as the workers do
        prefetch = Prefetcher(stream, None, graph=runtime)
        on_step = getattr(hooks, "on_step", None)
        on_log = getattr(hooks, "on_log", None)
        on_ckpt = getattr(hooks, "on_checkpoint", None)
        losses: list = []
        t_log = time.time()
        metrics = None
        try:
            for it in range(start, steps):
                if kill_locality_at_step is not None \
                        and it == kill_locality_at_step:
                    killed = self.kill_locality()
                    if verbose and killed is not None:
                        print(f"[train] drill: killed locality "
                              f"{killed} at step {it}", flush=True)
                batch = prefetch.get(it)
                if fail_at_step is not None and it == fail_at_step \
                        and not resume:
                    raise RuntimeError(
                        f"injected node failure at step {it}")
                metrics, params, opt = engine.train_step(
                    it, batch, params, opt)
                if on_step is not None:
                    on_step(it, metrics)
                if (it + 1) % log_every == 0:
                    loss = float(metrics["loss"])
                    losses.append(loss)
                    dt = (time.time() - t_log) / log_every
                    if verbose:
                        print(f"[train] step {it + 1:5d} loss "
                              f"{loss:8.4f} gnorm "
                              f"{float(metrics['grad_norm']):8.3f} "
                              f"{dt * 1e3:8.1f} ms/step", flush=True)
                    if on_log is not None:
                        on_log(it, loss)
                    t_log = time.time()
                if ckpt is not None and (it + 1) % ckpt_every == 0:
                    retired = runtime.defer(
                        jax.block_until_ready, metrics["grad_norm"],
                        lane=Lane.CHECKPOINT, name=f"retire:{it}")
                    fut = ckpt.save(it + 1, (params, opt),
                                    deps=(retired,),
                                    meta={"arch": plan.arch})
                    if on_ckpt is not None:
                        on_ckpt(it + 1, fut)
            if ckpt is not None and steps % ckpt_every != 0 \
                    and metrics is not None:
                ckpt.save(steps, (params, opt), meta={"arch": plan.arch})
        except BaseException:
            # poison the ring everywhere: workers blocked in an
            # all-reduce must abort, not wait out their timeout
            if self.distributed is not None:
                self.distributed.ddp_abort("the driver aborted the DDP run")
            raise
        finally:
            prefetch.close()
            if ckpt is not None:
                ckpt.close()
            runtime.barrier()
            ring.deactivate()

        if self.distributed is not None:
            done = self.distributed.wait_ddp_done(timeout=600.0)
            failed = [m for m in done.values() if not m.get("ok")]
            if failed:
                raise RuntimeError(
                    f"DDP train loop failed on locality "
                    f"{failed[0]['rank']}: {failed[0].get('error')}")
        st = runtime.stats()
        stats_json = st.to_json()
        dstats = (self.distributed.stats()
                  if self.distributed is not None else None)
        if dstats is not None:
            stats_json["distributed"] = dstats
        gwb = (dstats["grad_wire_bytes"] if dstats is not None
               else int(ring.wire_bytes))
        final = (float(metrics["loss"]) if metrics is not None
                 else float("nan"))
        if verbose:
            if metrics is None:
                print(f"[train] nothing to do: resumed at step {start} "
                      f">= steps {steps}")
            else:
                print(f"[train] done: final loss {final:.4f} "
                      f"(ddp world {engine.world}, "
                      f"shards {engine.shards})")
            print(f"[train] grad-wire {gwb}B ({plan.grad_codec} codec, "
                  f"{engine.codec_bytes}B/locality/exchange)")
            if dstats is not None:
                print(f"[train] localities: wire "
                      f"{dstats['bytes_sent']}B out / "
                      f"{dstats['bytes_recv']}B in")
        return {"final_loss": final, "losses": losses, "params": params,
                "step": steps if metrics is not None else start,
                "grad_wire_bytes": gwb, "codec_bytes": engine.codec_bytes,
                "runtime_stats": stats_json}

    # -- serve --------------------------------------------------------------
    def serve(self, requests: int = 8, *, prompt_len: int = 32,
              gen_len: int = 16, slots: int = 4, prompts=None,
              verbose: bool = True) -> dict:
        """Batched prefill + decode with slot refill, as a futurized tree:
        each wave is a ``prefill`` node plus ``gen_len`` chained ``decode``
        nodes (dependency edges carry the (token, cache) pair), while the
        next wave's host prep runs as a PREFETCH node - on a worker
        locality when ``plan.localities > 1``, with placement and device
        work staying on the driver.

        Args:
            requests: request count when ``prompts`` is None (otherwise
                ``len(prompts)`` wins).
            prompt_len: tokens per prompt (synthetic prompts only).
            gen_len: decode steps per request.
            slots: decode slots per wave (idle slots are padded).
            prompts: optional list of int32 token arrays.
            verbose: print the throughput summary line.
        Returns:
            dict with ``tokens_per_s``, ``requests``, ``tokens``, the
            traced node ``nodes``/``trace`` (decode steps are explicit,
            named graph nodes), and ``runtime_stats``.
        """
        plan, runtime, cfg = self.plan, self.runtime, self.cfg
        pre, dec = self._serve_steps_for(prompt_len, gen_len, slots)
        params = init_params(pre.specs, jax.random.PRNGKey(plan.seed))
        params = jax.device_put(params, pre.param_shardings)

        if prompts is None:
            rng = np.random.default_rng(plan.seed)
            prompts = [rng.integers(0, cfg.vocab,
                                    prompt_len).astype(np.int32)
                       for _ in range(requests)]
        waiting = list(prompts)
        requests = len(waiting)
        if not waiting:        # nothing to serve: no dummy wave, no tokens
            return {"tokens_per_s": 0.0, "requests": 0, "tokens": 0,
                    "padded_tokens": 0, "nodes": [], "trace": [],
                    "runtime_stats": self.runtime.stats().to_json()}
        tok_sh = dec.batch_shardings["tokens"]

        def prepare_wave(wave) -> dict:
            # wave: list of prompt arrays, or the already-stacked ndarray
            # a worker locality streamed back (np.stack handles both)
            toks = jax.device_put(jnp.asarray(np.stack(wave)),
                                  pre.batch_shardings["tokens"])
            batch = {"tokens": toks}
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (slots, cfg.enc_frames, cfg.d_model), cfg.c_dtype)
            return batch

        def defer_wave(wave, w: int):
            # multi-locality: the host prep (stacking the prompt arrays)
            # runs on a worker and streams back; placement stays local
            # under the same "wave:{w}" node name either way
            if self.distributed is not None:
                stacked = self.distributed.defer(
                    _stack_wave, wave, lane=Lane.PREFETCH,
                    name=f"stack:{w}")
                return runtime.defer(prepare_wave, stacked,
                                     lane=Lane.PREFETCH, name=f"wave:{w}")
            return runtime.defer(prepare_wave, wave, lane=Lane.PREFETCH,
                                 name=f"wave:{w}")

        def take_wave() -> tuple[list, int]:
            wave = [waiting.pop() for _ in range(min(slots, len(waiting)))]
            n_real = len(wave)
            while len(wave) < slots:            # pad idle slots
                wave.append(np.zeros(prompt_len, np.int32))
            return wave, n_real

        padded_out = 0

        def _prefill(batch, *_prev_tail):
            # *_prev_tail: dispatch-order edge from the previous wave's last
            # decode node; its value is unused
            logits, cache = pre.fn(params, batch)
            tok = jax.device_put(
                jnp.argmax(logits, -1)[:, None].astype(jnp.int32), tok_sh)
            return tok, cache

        def _decode(carry, pos):
            tok, cache = carry
            logits, cache = dec.fn(params, cache, {"tokens": tok}, pos)
            tok = jax.device_put(
                jnp.argmax(logits, -1)[:, None].astype(jnp.int32), tok_sh)
            return tok, cache

        tracer = Trace(runtime)
        remove = runtime.add_trace_hook(tracer.record)
        done, tokens_out, w = 0, 0, 0
        t0 = time.time()
        try:
            wave, n_real = take_wave()
            batch_fut = defer_wave(wave, 0)
            tail = None
            while True:
                nxt = None
                if waiting and done + n_real < requests:
                    next_wave, next_real = take_wave()
                    nxt = (defer_wave(next_wave, w + 1), next_real)
                # The wave's futurized tree, built up-front: nothing below
                # forces a transfer, so prefill and every decode step stay
                # in flight back-to-back under JAX async dispatch.
                deps = (batch_fut,) if tail is None else (batch_fut, tail)
                carry = runtime.defer(_prefill, *deps, name=f"prefill:w{w}")
                for t in range(gen_len):
                    carry = runtime.defer(_decode, carry,
                                          jnp.int32(prompt_len + t),
                                          name=f"decode:w{w}:t{t}")
                tail = carry
                # padded idle slots decode too, but their tokens are not
                # throughput: account them separately so latency/throughput
                # numbers aren't diluted by padding (RuntimeStats "serve")
                tokens_out += n_real * gen_len
                padded_out += (slots - n_real) * gen_len
                runtime.record_serve(
                    real_tokens=n_real * gen_len,
                    padded_slot_tokens=(slots - n_real) * gen_len)
                done += n_real
                if nxt is None:
                    break
                batch_fut, n_real = nxt
                w += 1
            last_tok, _ = tail.result()
            jax.block_until_ready(last_tok)   # honest timing: retire it all
        finally:
            remove()
        dt = time.time() - t0
        tps = tokens_out / dt
        st = runtime.stats()
        stats_json = st.to_json()
        if self.distributed is not None:
            stats_json["distributed"] = self.distributed.stats()
        nodes = tracer.names()
        n_decode = sum(n.startswith("decode:") for n in nodes)
        if verbose:
            print(f"[serve] {requests} requests, {tokens_out} tokens in "
                  f"{dt:.2f}s -> {tps:.1f} tok/s (slots={slots}, "
                  f"padded {padded_out}, decode nodes {n_decode}, "
                  f"host tasks {st.completed})")
        return {"tokens_per_s": tps, "requests": requests,
                "tokens": tokens_out, "padded_tokens": padded_out,
                "nodes": nodes,
                "trace": tracer.signature(), "runtime_stats": stats_json}

    # -- serve (gateway) -----------------------------------------------------
    def serve_stream(self, requests: int = 8, *, prompt_len: int = 32,
                     gen_len: int = 16, slots: int = 4,
                     max_inflight: Optional[int] = None,
                     deadline_ms: Optional[float] = None,
                     trace=None, queue=None, page_bytes: int = 1 << 16,
                     replicas: Optional[int] = None,
                     kill_replica_at_round: Optional[tuple] = None,
                     verbose: bool = True) -> dict:
        """The serving gateway (DESIGN.md §14): async continuous batching
        with mid-flight arrivals, admission control and the paged
        inference cache, instead of ``serve``'s synchronized waves.

        Each request is a first-class node chain (``stack`` -> ``prefill``
        -> ``refill``/``decode``/``emit`` -> ``finish`` resolving its
        ``request:{rid}`` promise); prefill runs once at admission and its
        decode state parks in ``core.paging.InferenceCache`` pages until a
        slot frees, so slot refill never recomputes prefill.

        Args:
            requests: synthetic request count when neither ``trace`` nor
                ``queue`` is given (all arriving at round 0).
            prompt_len, gen_len, slots: as for ``serve``.
            max_inflight: admission cap on requests holding resources
                (queued + decoding); defaults to ``2 * slots``.
            deadline_ms: default per-request deadline; a request still
                short of a slot when it lapses expires cleanly.
            trace: deterministic arrival script - a list of dicts with
                optional ``prompt``, ``at_round`` (decode round of
                arrival), ``deadline_ms``, ``cancel_after`` (cancel after
                that many decoded tokens), ``inject``
                (``"poison-prefill"``).
            queue: a live ``gateway.RequestQueue`` fed from other
                threads; the gateway drains it until ``close()``.
            page_bytes: page size of the inference cache pool (shared
                across replicas; each replica owns a named cache on it).
            replicas: model replica count (defaults to ``plan.replicas``).
                Each replica gets its own ``slots``-wide decode chain and
                the router spreads requests across them (DESIGN.md §15);
                per-request streams are bit-identical to ``replicas=1``.
            kill_replica_at_round: deterministic replica-death drill -
                ``(replica_idx, round)`` marks that replica dead at that
                decode round; survivors absorb its requests.
            verbose: print the summary line.
        Returns:
            dict with per-request ``streams``/``handles``, admission
            counts, ``tokens``/``padded_tokens``/``tokens_per_s``, the
            traced ``nodes``/``trace``, ``replicas``/
            ``replica_assignments`` and ``runtime_stats`` (including the
            ``serve``/``serve_replicas`` counters and
            ``request_latency_hist``).
        """
        from .gateway import Gateway, RequestQueue
        plan, runtime, cfg = self.plan, self.runtime, self.cfg
        n_replicas = plan.replicas if replicas is None else int(replicas)
        if cfg.family == "encdec":
            raise ValueError("serve_stream does not support encdec "
                             "architectures (scalar-only decoder position "
                             "embedding); use serve()")
        pre1 = self._serve_steps_for(prompt_len, gen_len, 1)[0]
        dec = self._serve_steps_for(prompt_len, gen_len, slots)[1]
        params = init_params(pre1.specs, jax.random.PRNGKey(plan.seed))
        params = jax.device_put(params, pre1.param_shardings)

        if queue is None:
            q = RequestQueue()
            entries = trace if trace is not None \
                else [{"at_round": 0} for _ in range(requests)]
            rng = np.random.default_rng(plan.seed)
            for e in entries:
                prompt = e.get("prompt")
                if prompt is None:
                    prompt = rng.integers(0, cfg.vocab,
                                          prompt_len).astype(np.int32)
                q.submit(prompt, at_round=e.get("at_round", 0),
                         deadline_ms=e.get("deadline_ms", deadline_ms),
                         cancel_after=e.get("cancel_after"),
                         inject=e.get("inject"))
            q.close()
        else:
            q = queue

        gw = Gateway(runtime, distributed=self.distributed,
                     prefill_step=pre1, decode_step=dec, params=params,
                     prompt_len=prompt_len, gen_len=gen_len, slots=slots,
                     max_inflight=max_inflight, deadline_ms=deadline_ms,
                     page_bytes=page_bytes, replicas=n_replicas,
                     kill_replica_at_round=kill_replica_at_round)
        self._gateway = gw          # drill seam: tests call kill_replica()
        tracer = Trace(runtime)
        remove = runtime.add_trace_hook(tracer.record)
        t0 = time.time()
        try:
            out = gw.run(q)
        finally:
            remove()
        dt = time.time() - t0
        tokens = sum(max(0, len(h.tokens) - 1) for h in out["handles"])
        st = runtime.stats()
        stats_json = st.to_json()
        if self.distributed is not None:
            stats_json["distributed"] = self.distributed.stats()
        out.update({
            "requests": q.submitted, "tokens": tokens,
            "padded_tokens": st.serve.get("padded_slot_tokens", 0),
            "tokens_per_s": tokens / dt if dt > 0 else 0.0,
            "nodes": tracer.names(), "trace": tracer.signature(),
            "runtime_stats": stats_json,
        })
        if verbose:
            rep_note = (f" across {n_replicas} replicas"
                        if n_replicas > 1 else "")
            print(f"[gateway] {q.submitted} requests{rep_note} "
                  f"({out['completed']} done, {out['cancelled']} "
                  f"cancelled, {out['expired']} expired, "
                  f"{out['failed']} failed, {out['rejected']} rejected), "
                  f"{tokens} tokens in {dt:.2f}s -> "
                  f"{out['tokens_per_s']:.1f} tok/s over {out['epochs']} "
                  f"epochs (page hits {st.serve.get('page_hits', 0)}/"
                  f"{st.serve.get('refills', 0)} refills)")
        return out

    # -- dryrun -------------------------------------------------------------
    def dryrun(self, shape: Optional[str] = None) -> dict:
        """Lower + compile this plan's cell and return its analysis record
        (memory, loop-aware HLO costs, collectives, roofline terms) - the
        per-cell body of ``launch/dryrun.py``.

        Args:
            shape: named ``configs.SHAPES`` cell; defaults to
                ``plan.shape``.
        Returns:
            dict with ``status`` ("ok" | "skipped" | "error") plus, when
            ok, device counts, lower/compile times, per-device flops and
            bytes, memory analysis, collectives, and roofline terms.
        Raises:
            ValueError: neither ``shape`` nor ``plan.shape`` is set.
        """
        shape_name = shape or self.plan.shape
        if shape_name is None:
            raise ValueError("dryrun needs a named shape (Plan.shape or "
                             "the shape= argument)")
        cfg, mesh = self.cfg, self.mesh
        ok, why = cell_is_applicable(cfg, shape_name)
        if not ok:
            return {"status": "skipped", "reason": why}
        n_dev = mesh_devices(mesh)
        try:
            step, lowered, compiled, t_lower, t_compile = lower_cell(
                cfg, mesh, shape_name, self.strategy)
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # old jax: per-program dicts
                ca = ca[0] if ca else {}
            try:
                ma = compiled.memory_analysis()
                mem = {
                    "argument_bytes": ma.argument_size_in_bytes,
                    "output_bytes": ma.output_size_in_bytes,
                    "temp_bytes": ma.temp_size_in_bytes,
                    "alias_bytes": ma.alias_size_in_bytes,
                    "code_bytes": ma.generated_code_size_in_bytes,
                }
                mem["peak_bytes_est"] = (mem["argument_bytes"]
                                         + mem["output_bytes"]
                                         - mem["alias_bytes"]
                                         + mem["temp_bytes"])
            except Exception as e:  # pragma: no cover
                mem = {"error": str(e)}
            # loop-aware analysis (cost_analysis counts while bodies once;
            # see core/hlo_costs.py) - the roofline source of truth
            costs = hlo_costs.analyze(compiled.as_text(), n_dev)
            terms = roofline_terms(cfg, shape_name, costs.flops, costs.bytes,
                                   costs.total_wire_bytes, n_dev)
            return {
                "status": "ok", "n_devices": n_dev,
                "t_lower_s": t_lower, "t_compile_s": t_compile,
                "flops_per_device": costs.flops,
                "bytes_per_device": costs.bytes,
                "memory": mem, "collectives": costs.to_json(),
                "roofline": terms,
                "xla_cost_analysis": {
                    "flops": float(ca.get("flops", 0.0)),
                    "bytes_accessed": float(ca.get("bytes accessed", 0.0))},
                "fits_hbm": bool(mem.get("peak_bytes_est", 0) < HBM_BYTES),
            }
        except Exception as e:
            return {"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]}


# ---------------------------------------------------------------------------
# Cell analysis helpers (shared with launch/dryrun.py and benchmarks)
# ---------------------------------------------------------------------------
def cell_is_applicable(cfg, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("long_500k needs sub-quadratic attention "
                       "(skip noted in DESIGN.md)")
    return True, ""


def lower_cell(cfg, mesh, shape_name: str, strategy: steps_lib.Strategy):
    shape = dict(SHAPES[shape_name])
    kind = shape["kind"]
    step = steps_lib.make_step(cfg, mesh, strategy, shape)

    if kind == "train":
        args = (step.param_structs(), step.opt_structs(),
                steps_lib.input_specs(cfg, shape))
    elif kind == "prefill":
        scfg = steps_lib._serve_cfg(cfg)
        args = (param_structs(step.specs),
                steps_lib.input_specs(scfg, shape))
    else:  # decode
        scfg = steps_lib._serve_cfg(cfg)
        args = (param_structs(step.specs), param_structs(step.cache_specs),
                steps_lib.input_specs(scfg, shape),
                jax.ShapeDtypeStruct((), jnp.int32))

    t0 = time.time()
    lowered = step.fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return step, lowered, compiled, t_lower, t_compile


def roofline_terms(cfg, shape_name: str, flops_dev: float, bytes_dev: float,
                   wire_bytes_dev: float, n_dev: int) -> dict:
    shape = SHAPES[shape_name]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = wire_bytes_dev / (ICI_BW_PER_LINK * ICI_LINKS)
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    # useful model flops: 6 N D (train) / 2 N D (fwd) per token
    tot, act = cfg.n_params()
    tokens = shape["global_batch"] * (shape["seq_len"]
                                      if shape["kind"] != "decode" else 1)
    mult = 6 if shape["kind"] == "train" else 2
    model_flops = mult * act * tokens / n_dev
    return {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_dev": model_flops,
        "useful_flops_ratio": model_flops / flops_dev if flops_dev else 0.0,
        "bound_step_s": max(t_compute, t_memory, t_coll),
        "roofline_fraction": (t_compute / max(t_compute, t_memory, t_coll)
                              if max(t_compute, t_memory, t_coll) > 0
                              else 0.0),
    }
