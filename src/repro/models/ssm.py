"""State-space / recurrent mixers: Mamba-2 (SSD), mLSTM, sLSTM.

All three follow the same contract as attention: a *parallel/chunked* form
for training & prefill (sub-quadratic, O(L) memory in chunks) and a *step*
form for decode carrying an explicit recurrent state - which is what makes
the ``long_500k`` shape feasible for the ssm/hybrid architectures.

Chunked algorithms are validated against direct sequential recurrences in
tests/test_ssm.py; the Pallas mamba2 chunk kernel mirrors the same block
structure on TPU.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..core import compat
from ..core.sharding import ParamSpec
from . import layers

NEG_INF = -1e30


def _segsum(a):
    """a: [..., L] log-decays -> [..., L, L] lower-tri cumulative sums:
    out[t, s] = sum_{r=s+1..t} a_r  (t >= s), -inf above the diagonal."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, NEG_INF)


def causal_conv1d(x, w, b=None, *, cache=None):
    """Depthwise causal conv. x: [B, L, C]; w: [K, C].

    With ``cache`` [B, K-1, C] (decode), prepends it instead of zero pad and
    returns (y, new_cache).
    """
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None].astype(x.dtype)
            for i in range(K))
    if b is not None:
        y = y + b.astype(x.dtype)
    new_cache = xp[:, -(K - 1):, :] if cache is not None else None
    return y, new_cache


# ===========================================================================
# Mamba-2 (SSD)
# ===========================================================================
def mamba2_specs(d: int, *, expand: int = 2, head_dim: int = 64,
                 state: int = 64, n_groups: int = 1, d_conv: int = 4) -> dict:
    d_in = expand * d
    H = d_in // head_dim
    conv_ch = d_in + 2 * n_groups * state
    return {
        "in_proj": ParamSpec((d, 2 * d_in + 2 * n_groups * state + H),
                             ("embed", "inner")),
        "conv_w": ParamSpec((d_conv, conv_ch), ("conv", "inner"), init="scaled",
                            scale=0.1),
        "conv_b": ParamSpec((conv_ch,), ("inner",), init="zeros"),
        "A_log": ParamSpec((H,), ("heads",), init="zeros"),
        "D": ParamSpec((H,), ("heads",), init="ones"),
        "dt_bias": ParamSpec((H,), ("heads",), init="zeros"),
        "norm_w": ParamSpec((d_in,), ("inner",), init="ones"),
        "out_proj": ParamSpec((d_in, d), ("inner", "embed")),
    }


def _mamba2_split(x, p, cfg):
    """Project and split into (z, xbc-conv inputs, dt)."""
    d_in = cfg.expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    gn = cfg.ssm_groups * cfg.ssm_state
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * gn]
    dt = zxbcdt[..., -H:]
    return z, xbc, dt


def mamba2_chunked(x, p, cfg, *, chunk: int = 256, return_state: bool = False):
    """Training/prefill pass. x: [B, L, D] -> [B, L, D] (+ final state)."""
    B, L, D = x.shape
    d_in = cfg.expand * D
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = cfg.ssm_groups
    dt_f = x.dtype

    z, xbc, dt = _mamba2_split(x, p, cfg)
    xbc, _ = causal_conv1d(xbc, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(B, L, H, P)
    Bm = xbc[..., d_in:d_in + G * N].reshape(B, L, G, N)
    Cm = xbc[..., d_in + G * N:].reshape(B, L, G, N)
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=2)                # [B,L,H,N]
    Cm = jnp.repeat(Cm, rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # [B,L,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # [H]
    a = dt * A[None, None]                                        # log decay
    xdt = xs * dt.astype(dt_f)[..., None]                         # dt-weighted input

    chunk = min(chunk, L)
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    # [B,nc,c,...]
    ac = a.reshape(B, nc, chunk, H)
    xc = xdt.reshape(B, nc, chunk, H, P)
    Bc = Bm.reshape(B, nc, chunk, H, N)
    Cc = Cm.reshape(B, nc, chunk, H, N)

    # --- intra-chunk (diagonal blocks) ---
    Lmat = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))             # [B,nc,H,c,c]
    CB = jnp.einsum("bnthe,bnshe->bnhts", Cc, Bc)
    y_diag = jnp.einsum("bnhts,bnhts,bnshp->bnthp",
                        CB.astype(jnp.float32), Lmat,
                        xc.astype(jnp.float32))

    # --- chunk-final states ---
    a_cs = jnp.cumsum(ac, axis=2)                                  # [B,nc,c,H]
    a_tot = a_cs[:, :, -1]                                         # [B,nc,H]
    decay_to_end = jnp.exp(a_tot[:, :, None] - a_cs)               # [B,nc,c,H]
    S_chunk = jnp.einsum("bnshe,bnsh,bnshp->bnhpe",
                         Bc.astype(jnp.float32), decay_to_end,
                         xc.astype(jnp.float32))                   # [B,nc,H,P,N]

    # --- inter-chunk recurrence over nc (sequential scan) ---
    def scan_fn(S_prev, inp):
        S_c, atot = inp                                            # [B,H,P,N],[B,H]
        S_new = S_prev * jnp.exp(atot)[..., None, None] + S_c
        return S_new, S_prev

    S0 = jnp.zeros((B, H, P, N), jnp.float32)
    S_final, S_before = compat.layer_scan(
        scan_fn, S0,
        (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(a_tot, 1, 0)))
    S_before = jnp.moveaxis(S_before, 0, 1)                        # [B,nc,H,P,N]

    # --- inter-chunk contribution ---
    decay_from_start = jnp.exp(a_cs)                               # [B,nc,c,H]
    y_off = jnp.einsum("bnthe,bnth,bnhpe->bnthp",
                       Cc.astype(jnp.float32), decay_from_start, S_before)

    y = (y_diag + y_off).reshape(B, L, H, P).astype(dt_f)
    y = y + xs * p["D"].astype(dt_f)[None, None, :, None]
    y = y.reshape(B, L, d_in)
    y = layers.rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["out_proj"].astype(dt_f)
    if return_state:
        K = p["conv_w"].shape[0]
        conv_in = (x @ p["in_proj"].astype(dt_f))[..., d_in:2 * d_in + 2 * G * N]
        state = {"ssm": S_final, "conv": conv_in[:, -(K - 1):, :]}
        return out, state
    return out


def mamba2_init_state(batch: int, cfg, dtype=jnp.float32) -> dict:
    d_in = cfg.expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, conv_ch), dtype),
    }


def mamba2_step(x, state, p, cfg):
    """Decode one token. x: [B, 1, D] -> (y [B,1,D], new state)."""
    B, _, D = x.shape
    d_in = cfg.expand * D
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = cfg.ssm_groups
    dt_f = x.dtype

    z, xbc, dt = _mamba2_split(x, p, cfg)
    xbc, conv_cache = causal_conv1d(xbc, p["conv_w"], p["conv_b"],
                                    cache=state["conv"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(B, H, P)
    Bm = jnp.repeat(xbc[..., d_in:d_in + G * N].reshape(B, G, N), H // G, 1)
    Cm = jnp.repeat(xbc[..., d_in + G * N:].reshape(B, G, N), H // G, 1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))       # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None])                                  # [B,H]
    S = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bhp,bhe,bh->bhpe", xs.astype(jnp.float32), Bm.astype(jnp.float32), dt)
    y = jnp.einsum("bhpe,bhe->bhp", S, Cm.astype(jnp.float32)).astype(dt_f)
    y = y + xs * p["D"].astype(dt_f)[None, :, None]
    y = y.reshape(B, 1, d_in)
    y = layers.rms_norm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["out_proj"].astype(dt_f), {"ssm": S, "conv": conv_cache}


# ===========================================================================
# mLSTM (xLSTM) - matrix-memory gated linear recurrence
# ===========================================================================
def mlstm_specs(d: int, *, n_heads: int, expand: int = 2,
                d_conv: int = 4) -> dict:
    d_in = expand * d
    return {
        "up_proj": ParamSpec((d, 2 * d_in), ("embed", "inner")),
        "conv_w": ParamSpec((d_conv, d_in), ("conv", "inner"), init="scaled",
                            scale=0.1),
        "conv_b": ParamSpec((d_in,), ("inner",), init="zeros"),
        "wq": ParamSpec((d_in, d_in), ("inner", None)),
        "wk": ParamSpec((d_in, d_in), ("inner", None)),
        "wv": ParamSpec((d_in, d_in), ("inner", None)),
        "w_gates": ParamSpec((d_in, 2 * n_heads), ("inner", None), scale=0.3),
        "b_i": ParamSpec((n_heads,), ("heads",), init="zeros"),
        "b_f": ParamSpec((n_heads,), ("heads",), init="ones"),
        "skip": ParamSpec((d_in,), ("inner",), init="ones"),
        "norm_w": ParamSpec((d_in,), ("inner",), init="ones"),
        "down_proj": ParamSpec((d_in, d), ("inner", "embed")),
    }


def _mlstm_qkvif(x, p, cfg, conv_cache=None):
    d_in = cfg.expand * cfg.d_model
    H = cfg.n_heads
    P = d_in // H
    dt = x.dtype
    up = x @ p["up_proj"].astype(dt)
    xi, z = up[..., :d_in], up[..., d_in:]
    xc, new_cache = causal_conv1d(xi, p["conv_w"], p["conv_b"],
                                  cache=conv_cache)
    xc = jax.nn.silu(xc)
    B, L = x.shape[:2]
    q = (xc @ p["wq"].astype(dt)).reshape(B, L, H, P)
    k = (xc @ p["wk"].astype(dt)).reshape(B, L, H, P) / math.sqrt(P)
    v = (xi @ p["wv"].astype(dt)).reshape(B, L, H, P)
    gates = (xc @ p["w_gates"].astype(dt)).astype(jnp.float32)
    i_pre = gates[..., :H] + p["b_i"].astype(jnp.float32)
    f_pre = gates[..., H:] + p["b_f"].astype(jnp.float32)
    return q, k, v, i_pre, f_pre, xi, z, new_cache


def mlstm_chunked(x, p, cfg, *, chunk: int = 256, return_state: bool = False):
    """Stabilized chunkwise mLSTM. x: [B, L, D] -> [B, L, D].

    Carry across chunks: (C [B,H,P,N], n [B,H,N], m [B,H]) where m is the
    running log-stabilizer (xLSTM eq. 15-19 in chunk form).
    """
    B, L, D = x.shape
    d_in = cfg.expand * D
    H = cfg.n_heads
    P = d_in // H
    dt_f = x.dtype
    q, k, v, i_pre, f_pre, xi, z, _ = _mlstm_qkvif(x, p, cfg)

    chunk = min(chunk, L)
    assert L % chunk == 0
    nc = L // chunk
    log_f = jax.nn.log_sigmoid(f_pre)                    # [B,L,H]

    def reshape_c(t, extra=()):
        return t.reshape((B, nc, chunk) + extra)

    qc = q.reshape(B, nc, chunk, H, P)
    kc = k.reshape(B, nc, chunk, H, P)
    vc = v.reshape(B, nc, chunk, H, P)
    ic = reshape_c(i_pre, (H,))
    fc = reshape_c(log_f, (H,))

    g = jnp.cumsum(fc, axis=2)                           # [B,nc,c,H]
    g_tot = g[:, :, -1]                                  # [B,nc,H]

    def body(carry, inp):
        C, n, m = carry                                  # [B,H,P,P],[B,H,P],[B,H]
        qi, ki, vi, ii, gi, gt = inp
        # state-contribution log-weights at end of chunk
        w = gt[:, None] - gi + ii                        # [B,c,H]
        m_loc = w.max(axis=1)                            # [B,H]
        m_new = jnp.maximum(m + gt, m_loc)
        scale_old = jnp.exp(m + gt - m_new)              # [B,H]
        w_exp = jnp.exp(w - m_new[:, None])               # [B,c,H]
        C_new = C * scale_old[..., None, None] + jnp.einsum(
            "bch,bchp,bchn->bhpn", w_exp, ki.astype(jnp.float32),
            vi.astype(jnp.float32))
        n_new = n * scale_old[..., None] + jnp.einsum(
            "bch,bchp->bhp", w_exp, ki.astype(jnp.float32))

        # outputs: inter (old state) + intra (this chunk)
        u = gi[:, :, None, :] - gi[:, None, :, :] + ii[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        u = jnp.where(tri[None, :, :, None], u, NEG_INF)  # [B,t,s,H]
        m_intra = u.max(axis=2)                           # [B,t,H]
        m_out = jnp.maximum(m[:, None] + gi, m_intra)     # [B,t,H]
        w_inter = jnp.exp(m[:, None] + gi - m_out)        # [B,t,H]
        w_intra = jnp.exp(u - m_out[:, :, None])          # [B,t,s,H]
        qk = jnp.einsum("bthp,bshp->btsh", qi.astype(jnp.float32),
                        ki.astype(jnp.float32))
        h_intra = jnp.einsum("btsh,btsh,bshn->bthn", qk, w_intra,
                             vi.astype(jnp.float32))
        h_inter = jnp.einsum("bthp,bhpn->bthn", qi.astype(jnp.float32),
                             C) * w_inter[..., None]
        num = h_inter + h_intra
        den_inter = jnp.einsum("bthp,bhp->bth", qi.astype(jnp.float32), n) \
            * w_inter
        den_intra = jnp.einsum("btsh,btsh->bth", qk, w_intra)
        den = den_inter + den_intra
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_out))
        h = num / denom[..., None]
        return (C_new, n_new, m_new), h.astype(dt_f)

    C0 = jnp.zeros((B, H, P, P), jnp.float32)
    n0 = jnp.zeros((B, H, P), jnp.float32)
    m0 = jnp.full((B, H), 0.0, jnp.float32)
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, ic, g, g_tot))
    (Cf, nf, mf), hs = compat.layer_scan(body, (C0, n0, m0), inputs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, L, d_in)

    h = h + xi * p["skip"].astype(dt_f)
    h = h * jax.nn.silu(z)
    h = layers.rms_norm(h, p["norm_w"])
    out = h @ p["down_proj"].astype(dt_f)
    if return_state:
        K = p["conv_w"].shape[0]
        conv_in = (x @ p["up_proj"].astype(dt_f))[..., :d_in]
        state = {"C": Cf, "n": nf, "m": mf, "conv": conv_in[:, -(K - 1):, :]}
        return out, state
    return out


def mlstm_init_state(batch: int, cfg, dtype=jnp.float32) -> dict:
    d_in = cfg.expand * cfg.d_model
    H = cfg.n_heads
    P = d_in // H
    return {
        "C": jnp.zeros((batch, H, P, P), jnp.float32),
        "n": jnp.zeros((batch, H, P), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, d_in), dtype),
    }


def mlstm_step(x, state, p, cfg):
    """Decode one token: exact recurrent form."""
    B, _, D = x.shape
    d_in = cfg.expand * D
    H = cfg.n_heads
    dt_f = x.dtype
    q, k, v, i_pre, f_pre, xi, z, conv_cache = _mlstm_qkvif(
        x, p, cfg, conv_cache=state["conv"])
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))   # [B,H,P]
    ii = i_pre[:, 0]
    lf = jax.nn.log_sigmoid(f_pre[:, 0])                          # [B,H]
    m_new = jnp.maximum(lf + state["m"], ii)
    sf = jnp.exp(lf + state["m"] - m_new)
    si = jnp.exp(ii - m_new)
    C = state["C"] * sf[..., None, None] + si[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = state["n"] * sf[..., None] + si[..., None] * k
    num = jnp.einsum("bhp,bhpn->bhn", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, d_in).astype(dt_f)
    h = h + xi * p["skip"].astype(dt_f)
    h = h * jax.nn.silu(z)
    h = layers.rms_norm(h, p["norm_w"])
    y = h @ p["down_proj"].astype(dt_f)
    return y, {"C": C, "n": n, "m": m_new, "conv": conv_cache}


# ===========================================================================
# sLSTM - scalar-memory LSTM with exponential gating (sequential)
# ===========================================================================
def slstm_specs(d: int, *, n_heads: int) -> dict:
    P = d // n_heads
    return {
        "w_in": ParamSpec((d, 4 * d), ("embed", "inner")),
        "r": ParamSpec((n_heads, P, 4 * P), ("heads", "head_dim", None),
                       scale=0.5),
        "b": ParamSpec((4 * d,), ("inner",), init="zeros"),
        "norm_w": ParamSpec((d,), ("embed",), init="ones"),
        "up": ParamSpec((d, 2 * d), ("embed", "d_ff")),
        "down": ParamSpec((d, d), ("d_ff", "embed")),
    }


def _slstm_cell(x_t, h_prev, state, p, H, P):
    """One step. x_t: [B, 4d] preactivations from input; h_prev [B,H,P]."""
    c, n, m = state
    rec = jnp.einsum("bhp,hpq->bhq", h_prev, p["r"].astype(h_prev.dtype))
    pre = x_t.reshape(x_t.shape[0], H, 4 * P) + rec
    zi, ii, fi, oi = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    zt = jnp.tanh(zi)
    it = ii.mean(-1)                       # scalar gates per head
    ft = fi.mean(-1)
    ot = jax.nn.sigmoid(oi)
    m_new = jnp.maximum(ft + m, it)
    ig = jnp.exp(it - m_new)[..., None]
    fg = jnp.exp(ft + m - m_new)[..., None]
    c_new = fg * c + ig * zt
    n_new = fg * n + ig
    h_new = ot * (c_new / jnp.maximum(n_new, 1e-6))
    return h_new, (c_new, n_new, m_new)


def slstm_apply(x, p, cfg, *, return_state: bool = False):
    """x: [B, L, D]; sequential scan over L (no parallel form exists)."""
    B, L, D = x.shape
    H = cfg.n_heads
    P = D // H
    dt_f = x.dtype
    pre = x @ p["w_in"].astype(dt_f) + p["b"].astype(dt_f)

    def step(carry, x_t):
        h_prev, state = carry
        h_new, state = _slstm_cell(x_t, h_prev, state, p, H, P)
        # carry stays f32; the stacked ys are emitted in compute dtype so
        # the per-step save is a thin DUS row, not a full-buffer convert
        # round-trip (see EXPERIMENTS.md §Perf xlstm iteration 2)
        return (h_new, state), h_new.astype(dt_f)

    h0 = jnp.zeros((B, H, P), jnp.float32)
    st0 = (jnp.zeros((B, H, P), jnp.float32),
           jnp.zeros((B, H, P), jnp.float32),
           jnp.zeros((B, H), jnp.float32))
    (hf, stf), hs = compat.layer_scan(step, (h0, st0),
                                      jnp.moveaxis(pre, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, L, D)
    h = layers.rms_norm(h, p["norm_w"])
    u = h @ p["up"].astype(dt_f)
    u = jax.nn.gelu(u[..., :D]) * u[..., D:]
    out = u @ p["down"].astype(dt_f)
    if return_state:
        c, n, m = stf
        return out, {"h": hf, "c": c, "n": n, "m": m}
    return out


def slstm_init_state(batch: int, cfg, dtype=jnp.float32) -> dict:
    H = cfg.n_heads
    P = cfg.d_model // H
    z = lambda *s: jnp.zeros(s, jnp.float32)
    return {"h": z(batch, H, P), "c": z(batch, H, P), "n": z(batch, H, P),
            "m": z(batch, H)}


def slstm_step(x, state, p, cfg):
    B, _, D = x.shape
    H = cfg.n_heads
    P = D // H
    dt_f = x.dtype
    pre = (x @ p["w_in"].astype(dt_f) + p["b"].astype(dt_f))[:, 0]
    h_new, (c, n, m) = _slstm_cell(
        pre, state["h"], (state["c"], state["n"], state["m"]), p, H, P)
    h = h_new.reshape(B, 1, D).astype(dt_f)
    h = layers.rms_norm(h, p["norm_w"])
    u = h @ p["up"].astype(dt_f)
    u = jax.nn.gelu(u[..., :D]) * u[..., D:]
    y = u @ p["down"].astype(dt_f)
    return y, {"h": h_new, "c": c, "n": n, "m": m}
