"""The paper's benchmark model: a 4-layer 1-D CNN for Human Activity
Recognition (Fig. 1 compares Phylanx vs Horovod on its forward pass,
minibatch 8000).  Deduced from the cited Kaggle convo1d project: HAR
windows of 128 timesteps x 9 sensor channels, 6 activity classes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.sharding import ParamSpec


def har_cnn_specs(*, in_ch: int = 9, width: int = 64, classes: int = 6,
                  kernel: int = 3) -> dict:
    c = width
    # gain 1.5 on the conv stack: four VALID relu convs + maxpool + global
    # average pooling attenuate the signal enough that unit-gain init leaves
    # gradients too small to train at the paper's SGD settings
    g = 1.5
    return {
        "conv1": {"w": ParamSpec((kernel, in_ch, c), ("conv", None, "channels"),
                                 scale=g),
                  "b": ParamSpec((c,), ("channels",), init="zeros")},
        "conv2": {"w": ParamSpec((kernel, c, c), ("conv", None, "channels"),
                                 scale=g),
                  "b": ParamSpec((c,), ("channels",), init="zeros")},
        "conv3": {"w": ParamSpec((kernel, c, 2 * c), ("conv", None, "channels"),
                                 scale=g),
                  "b": ParamSpec((2 * c,), ("channels",), init="zeros")},
        "conv4": {"w": ParamSpec((kernel, 2 * c, 2 * c), ("conv", None, "channels"),
                                 scale=g),
                  "b": ParamSpec((2 * c,), ("channels",), init="zeros")},
        "head": {"w": ParamSpec((2 * c, classes), (None, None)),
                 "b": ParamSpec((classes,), (None,), init="zeros")},
    }


def _conv1d(x, w, b):
    """x: [B, L, Cin]; w: [K, Cin, Cout] (VALID padding, as Conv1D default)."""
    y = jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"))
    return y + b.astype(x.dtype)


def har_cnn_forward(params, x):
    """x: [B, 128, 9] -> logits [B, classes]."""
    h = jax.nn.relu(_conv1d(x, params["conv1"]["w"], params["conv1"]["b"]))
    h = jax.nn.relu(_conv1d(h, params["conv2"]["w"], params["conv2"]["b"]))
    # maxpool /2 between the two conv pairs (Kaggle architecture)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 1), (1, 2, 1),
                              "VALID")
    h = jax.nn.relu(_conv1d(h, params["conv3"]["w"], params["conv3"]["b"]))
    h = jax.nn.relu(_conv1d(h, params["conv4"]["w"], params["conv4"]["b"]))
    h = jnp.mean(h, axis=1)  # global average pool
    return h @ params["head"]["w"].astype(h.dtype) + params["head"]["b"].astype(h.dtype)


def har_cnn_loss(params, batch):
    lg = har_cnn_forward(params, batch["x"]).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)
