"""Mixture-of-Experts with capacity-based top-k routing.

Two dispatch engines, selectable per step (and compared in §Perf):

  * ``einsum`` - GShard/Switch-style one-hot dispatch matmuls. The standard
    TPU formulation: partitions cleanly (experts on the "model" axis produce
    all-to-alls), but the dispatch einsums burn non-useful FLOPs
    proportional to tokens*E*capacity*d.
  * ``sort``   - MegaBlocks/Mixtral-style: argsort tokens by expert id,
    gather into per-expert buffers, grouped matmul, scatter back. Flop-free
    dispatch (data movement only).

Routing is token-choice top-k with per-group capacity; overflowing tokens
are dropped (contribute zero), underflow slots are zero-padded - both
standard GShard semantics.  Groups are formed from contiguous token spans so
routing stays local to a data shard.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..core.sharding import ParamSpec


def moe_specs(d: int, ff: int, n_experts: int) -> dict:
    return {
        "router": ParamSpec((d, n_experts), ("embed", "experts"), scale=0.5),
        "w_gate": ParamSpec((n_experts, d, ff), ("experts", "embed", "d_ff")),
        "w_up": ParamSpec((n_experts, d, ff), ("experts", "embed", "d_ff")),
        "w_down": ParamSpec((n_experts, ff, d), ("experts", "d_ff", "embed")),
    }


def capacity(group_tokens: int, n_experts: int, top_k: int,
             factor: float = 1.25) -> int:
    c = int(math.ceil(group_tokens * top_k * factor / n_experts))
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for tiling friendliness


def router_probs(x, w_router, top_k: int):
    """Returns (weights [T,k], expert ids [T,k], aux load-balance loss)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, top_k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e(f_e * p_e)
    E = w_router.shape[-1]
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(gate_i[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)
    aux = E * jnp.sum(me * ce)
    return gate_w, gate_i, aux


def _expert_ffn(xin, p, dt):
    """xin: [E, C', d] -> [E, C', d] per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"].astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", xin, p["w_up"].astype(dt))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))


# ---------------------------------------------------------------------------
# einsum (GShard) dispatch
# ---------------------------------------------------------------------------
def _dispatch_einsum(x, p, top_k: int, group_size: int, cap_factor: float):
    """x: [T, d] (T a multiple of group_size)."""
    T, d = x.shape
    E = p["router"].shape[-1]
    dt = x.dtype
    G = T // group_size
    xg = x.reshape(G, group_size, d)
    gate_w, gate_i, aux = router_probs(x, p["router"], top_k)
    gate_w = gate_w.reshape(G, group_size, top_k)
    gate_i = gate_i.reshape(G, group_size, top_k)
    C = capacity(group_size, E, top_k, cap_factor)

    # position of each (token, k) within its expert's capacity buffer
    e_onehot = jax.nn.one_hot(gate_i, E, dtype=jnp.float32)      # [G,S,k,E]
    # rank among same-expert assignments in (token, k) order
    flat = e_onehot.reshape(G, group_size * top_k, E)
    ranks = jnp.cumsum(flat, axis=1) - flat                       # [G,S*k,E]
    pos = jnp.sum(ranks * flat, axis=-1).reshape(G, group_size, top_k)
    keep = (pos < C).astype(jnp.float32)
    gate_w = gate_w * keep

    pos_onehot = jax.nn.one_hot(pos, C, dtype=jnp.float32)        # [G,S,k,C]
    # combine[g,s,e,c] = sum_k gate_w * onehot(e) * onehot(c)
    combine = jnp.einsum("gske,gskc,gsk->gsec", e_onehot, pos_onehot, gate_w)
    dispatch = (combine > 0).astype(dt)
    xin = jnp.einsum("gsec,gsd->egcd", dispatch, xg)              # [E,G,C,d]
    xin = xin.reshape(E, G * C, d)
    yout = _expert_ffn(xin, p, dt).reshape(E, G, C, d)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(dt), yout)
    return y.reshape(T, d), aux


# ---------------------------------------------------------------------------
# sort-based dispatch (flop-free)
# ---------------------------------------------------------------------------
def _dispatch_sort(x, p, top_k: int, group_size: int, cap_factor: float):
    T, d = x.shape
    E = p["router"].shape[-1]
    dt = x.dtype
    gate_w, gate_i, aux = router_probs(x, p["router"], top_k)
    C = capacity(T, E, top_k, cap_factor)

    flat_e = gate_i.reshape(-1)                                   # [T*k]
    flat_w = gate_w.reshape(-1)
    tok = jnp.repeat(jnp.arange(T), top_k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], tok[order], flat_w[order]
    # rank within expert along the sorted run
    same = jnp.concatenate([jnp.zeros(1, jnp.int32),
                            (se[1:] == se[:-1]).astype(jnp.int32)])
    seg_start = jnp.where(same == 0, jnp.arange(T * top_k), 0)
    run_start = jax.lax.associative_scan(jnp.maximum, seg_start)
    rank = jnp.arange(T * top_k) - run_start
    keep = rank < C
    slot = se * C + jnp.where(keep, rank, 0)

    buf = jnp.zeros((E * C, d), dt)
    buf = buf.at[slot].add(jnp.where(keep[:, None], x[st], 0).astype(dt))
    yout = _expert_ffn(buf.reshape(E, C, d), p, dt).reshape(E * C, d)
    contrib = jnp.where(keep, sw, 0.0).astype(dt)[:, None] * yout[slot]
    y = jnp.zeros((T, d), dt).at[st].add(contrib)
    return y, aux


def apply_moe(x, p, *, top_k: int, group_size: int = 512,
              cap_factor: float = 1.25, dispatch: str = "einsum"):
    """x: [B, S, d] -> [B, S, d], aux-loss scalar."""
    B, S, d = x.shape
    flat = x.reshape(B * S, d)
    gs = min(group_size, flat.shape[0])
    if dispatch == "sort":
        y, aux = _dispatch_sort(flat, p, top_k, gs, cap_factor)
    else:
        y, aux = _dispatch_einsum(flat, p, top_k, gs, cap_factor)
    return y.reshape(B, S, d), aux
