from . import attention, blocks, cnn, layers, model, moe, ssm  # noqa: F401
from .model import build_model  # noqa: F401
