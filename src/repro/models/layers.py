"""Shared neural-net layers (functional; params are plain pytrees).

Every parameter is declared through ``ParamSpec`` with *logical dims* so the
core sharding engine (tiling plans) can place it on any mesh - the paper's
architecture-agnostic requirement R8: models never mention mesh axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.sharding import ParamSpec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-6):
    """Statistics in fp32, scaling in the input dtype (Flax/Megatron
    convention).  Keeping the full tensor in compute dtype keeps the
    backward gradient chain - and its tensor-parallel collectives - in
    bf16 instead of fp32 (§Perf chameleon iteration A4: halves the
    activation-gradient wire bytes)."""
    msq = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(msq + eps).astype(x.dtype)
    return x * scale * w.astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
    return y * w.astype(x.dtype) + b.astype(x.dtype)


def norm_specs(d: int, kind: str) -> dict:
    if kind == "rms":
        return {"w": ParamSpec((d,), ("embed",), init="ones")}
    return {"w": ParamSpec((d,), ("embed",), init="ones"),
            "b": ParamSpec((d,), ("embed",), init="zeros")}


def apply_norm(x, p, kind: str, eps: float = 1e-6):
    if kind == "rms":
        return rms_norm(x, p["w"], eps)
    return layer_norm(x, p["w"], p["b"], eps)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float = 10000.0):
    return theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32)
                     / (head_dim // 2))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                        # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(seq: int, d: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_specs(d: int, ff: int, kind: str = "swiglu") -> dict:
    if kind == "swiglu":
        return {
            "w_gate": ParamSpec((d, ff), ("embed", "d_ff")),
            "w_up": ParamSpec((d, ff), ("embed", "d_ff")),
            "w_down": ParamSpec((ff, d), ("d_ff", "embed")),
        }
    return {  # gelu
        "w_up": ParamSpec((d, ff), ("embed", "d_ff")),
        "b_up": ParamSpec((ff,), ("d_ff",), init="zeros"),
        "w_down": ParamSpec((ff, d), ("d_ff", "embed")),
        "b_down": ParamSpec((d,), ("embed",), init="zeros"),
    }


def apply_mlp(x, p, kind: str = "swiglu"):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
        return h @ p["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype) + p["b_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_specs(vocab: int, d: int) -> dict:
    return {"tok": ParamSpec((vocab, d), ("vocab", "embed"), init="scaled",
                             scale=0.02)}


def embed(tokens, p):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed_specs(d: int, vocab: int) -> dict:
    return {"w": ParamSpec((d, vocab), ("embed", "vocab"))}


def logits(x, p):
    return x @ p["w"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_xent(lg, labels, mask=None):
    """Token-mean cross entropy; fp32 for the reduction."""
    lg = lg.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
