"""Composable blocks: pre-norm residual transformer / moe / ssm variants.

Each block kind provides three functions with a uniform contract:
  *_specs(cfg)                      -> ParamSpec tree
  *_apply(x, p, cfg, **ctx)         -> x            (train / prefill)
  *_decode(x, p, cfg, cache, pos)   -> (x, cache)   (single-token step)

Caches are ParamSpec trees too (init="zeros"), so the same sharding engine
places them on the mesh.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..core.sharding import ParamSpec
from . import attention, layers, moe, ssm


# ---------------------------------------------------------------------------
# Transformer block (attention + MLP or MoE), optional cross-attention
# ---------------------------------------------------------------------------
def tblock_specs(cfg, *, cross: bool = False, use_moe: bool = False) -> dict:
    sp = {
        "ln_attn": layers.norm_specs(cfg.d_model, cfg.norm),
        "attn": attention.attn_specs(
            cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm),
        "ln_mlp": layers.norm_specs(cfg.d_model, cfg.norm),
    }
    if use_moe:
        sp["moe"] = moe.moe_specs(cfg.d_model, cfg.d_ff, cfg.n_experts)
    else:
        sp["mlp"] = layers.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    if cross:
        sp["ln_cross"] = layers.norm_specs(cfg.d_model, cfg.norm)
        sp["cross"] = attention.attn_specs(
            cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, qk_norm=False)
    return sp


def tblock_apply(x, p, cfg, *, impl: str = "chunked", causal: bool = True,
                 positions=None, enc_kv=None):
    h = layers.apply_norm(x, p["ln_attn"], cfg.norm)
    x = x + attention.attn_layer(h, p["attn"], cfg, impl=impl,
                                 positions=positions, causal=causal)
    if "cross" in p:
        h = layers.apply_norm(x, p["ln_cross"], cfg.norm)
        x = x + attention.attn_layer(h, p["cross"], cfg, impl=impl,
                                     kv_override=enc_kv)
    h = layers.apply_norm(x, p["ln_mlp"], cfg.norm)
    if "moe" in p:
        y, aux = moe.apply_moe(h, p["moe"], top_k=cfg.top_k,
                               group_size=cfg.moe_group,
                               dispatch=cfg.moe_dispatch)
        return x + y, aux
    return x + layers.apply_mlp(h, p["mlp"], cfg.mlp_kind), jnp.zeros((), jnp.float32)


def kv_cache_specs(cfg, batch: int, seq: int, n_layers: Optional[int] = None,
                   *, prefix: tuple = ()) -> dict:
    shape = prefix + (batch, seq, cfg.n_kv_heads, cfg.head_dim)
    dims = tuple("layers" for _ in prefix) + ("batch", "kv_seq", "kv_heads",
                                              "head_dim")
    mk = lambda: ParamSpec(shape, dims, dtype=cfg.cache_dtype, init="zeros")
    return {"k": mk(), "v": mk()}


def tblock_decode(x, p, cfg, cache, pos, *, enc_kv=None):
    """x: [B,1,D]; cache: {"k","v"} [B,S,Hkv,hd]; pos: scalar int, or
    ``[B]`` per-row positions (continuous batch, one offset per slot)."""
    h = layers.apply_norm(x, p["ln_attn"], cfg.norm)
    pos = jnp.asarray(pos)
    positions = (pos[:, None] if pos.ndim
                 else jnp.full((h.shape[0], 1), pos))
    q, k, v = attention.project_qkv(
        h, p["attn"], positions=positions,
        rope_theta=cfg.rope_theta, use_rope=cfg.use_rope)
    kc, vc = attention.cache_update(cache["k"], cache["v"], k, v, pos,
                                    mode=cfg.cache_update)
    o = attention.decode_attend(q, kc, vc, pos, window=cfg.sliding_window)
    x = x + jnp.einsum("bqhk,hkd->bqd", o, p["attn"]["wo"].astype(x.dtype))
    new_cache = {"k": kc, "v": vc}
    if "cross" in p:
        h = layers.apply_norm(x, p["ln_cross"], cfg.norm)
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"].astype(x.dtype))
        if "bq" in p["cross"]:
            q = q + p["cross"]["bq"].astype(x.dtype)
        ck, cv = enc_kv if enc_kv is not None else (cache["ck"], cache["cv"])
        o = attention.attend_full(q, ck, cv, causal=False)
        x = x + jnp.einsum("bqhk,hkd->bqd", o,
                           p["cross"]["wo"].astype(x.dtype))
        if enc_kv is None:
            new_cache.update({"ck": ck, "cv": cv})
        else:
            new_cache.update({"ck": ck, "cv": cv})
    h = layers.apply_norm(x, p["ln_mlp"], cfg.norm)
    if "moe" in p:
        y, _ = moe.apply_moe(h, p["moe"], top_k=cfg.top_k,
                             group_size=cfg.moe_group,
                             dispatch=cfg.moe_dispatch)
        x = x + y
    else:
        x = x + layers.apply_mlp(h, p["mlp"], cfg.mlp_kind)
    return x, new_cache


# ---------------------------------------------------------------------------
# Mamba-2 block (pre-norm + mixer; no separate MLP, as in Mamba/Zamba)
# ---------------------------------------------------------------------------
def mamba_block_specs(cfg) -> dict:
    return {
        "ln": layers.norm_specs(cfg.d_model, cfg.norm),
        "mixer": ssm.mamba2_specs(
            cfg.d_model, expand=cfg.expand, head_dim=cfg.ssm_head_dim,
            state=cfg.ssm_state, n_groups=cfg.ssm_groups,
            d_conv=cfg.ssm_d_conv),
    }


def mamba_block_apply(x, p, cfg, *, chunk: int = 256):
    h = layers.apply_norm(x, p["ln"], cfg.norm)
    return x + ssm.mamba2_chunked(h, p["mixer"], cfg, chunk=chunk)


def mamba_block_decode(x, p, cfg, state, pos):
    h = layers.apply_norm(x, p["ln"], cfg.norm)
    y, state = ssm.mamba2_step(h, state, p["mixer"], cfg)
    return x + y, state


def mamba_state_specs(cfg, batch: int, *, prefix: tuple = ()) -> dict:
    d_in = cfg.expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    pdims = tuple("layers" for _ in prefix)
    return {
        "ssm": ParamSpec(prefix + (batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                         pdims + ("batch", "heads", "head_dim", "state"),
                         dtype=jnp.float32, init="zeros"),
        "conv": ParamSpec(prefix + (batch, cfg.ssm_d_conv - 1, conv_ch),
                          pdims + ("batch", "conv", "inner"),
                          dtype=cfg.cache_dtype, init="zeros"),
    }


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------
def mlstm_block_specs(cfg) -> dict:
    return {
        "ln": layers.norm_specs(cfg.d_model, cfg.norm),
        "mixer": ssm.mlstm_specs(cfg.d_model, n_heads=cfg.n_heads,
                                 expand=cfg.expand, d_conv=cfg.ssm_d_conv),
    }


def mlstm_block_apply(x, p, cfg, *, chunk: int = 256):
    h = layers.apply_norm(x, p["ln"], cfg.norm)
    return x + ssm.mlstm_chunked(h, p["mixer"], cfg, chunk=chunk)


def mlstm_block_decode(x, p, cfg, state, pos):
    h = layers.apply_norm(x, p["ln"], cfg.norm)
    y, state = ssm.mlstm_step(h, state, p["mixer"], cfg)
    return x + y, state


def mlstm_state_specs(cfg, batch: int, *, prefix: tuple = ()) -> dict:
    d_in = cfg.expand * cfg.d_model
    H = cfg.n_heads
    P = d_in // H
    pdims = tuple("layers" for _ in prefix)
    f32 = jnp.float32
    return {
        "C": ParamSpec(prefix + (batch, H, P, P),
                       pdims + ("batch", "heads", "head_dim", "state"),
                       dtype=f32, init="zeros"),
        "n": ParamSpec(prefix + (batch, H, P),
                       pdims + ("batch", "heads", "head_dim"),
                       dtype=f32, init="zeros"),
        "m": ParamSpec(prefix + (batch, H), pdims + ("batch", "heads"),
                       dtype=f32, init="zeros"),
        "conv": ParamSpec(prefix + (batch, cfg.ssm_d_conv - 1, d_in),
                          pdims + ("batch", "conv", "inner"),
                          dtype=cfg.cache_dtype, init="zeros"),
    }


def slstm_block_specs(cfg) -> dict:
    return {
        "ln": layers.norm_specs(cfg.d_model, cfg.norm),
        "mixer": ssm.slstm_specs(cfg.d_model, n_heads=cfg.slstm_heads),
    }


def slstm_block_apply(x, p, cfg):
    h = layers.apply_norm(x, p["ln"], cfg.norm)

    class _C:
        n_heads = cfg.slstm_heads
        d_model = cfg.d_model
    return x + ssm.slstm_apply(h, p["mixer"], _C)


def slstm_block_decode(x, p, cfg, state, pos):
    h = layers.apply_norm(x, p["ln"], cfg.norm)

    class _C:
        n_heads = cfg.slstm_heads
        d_model = cfg.d_model
    y, state = ssm.slstm_step(h, state, p["mixer"], _C)
    return x + y, state


def slstm_state_specs(cfg, batch: int, *, prefix: tuple = ()) -> dict:
    H = cfg.slstm_heads
    P = cfg.d_model // H
    pdims = tuple("layers" for _ in prefix)
    f32 = jnp.float32
    mk = lambda *s, dims: ParamSpec(prefix + s, pdims + dims, dtype=f32,
                                    init="zeros")
    return {
        "h": mk(batch, H, P, dims=("batch", "heads", "head_dim")),
        "c": mk(batch, H, P, dims=("batch", "heads", "head_dim")),
        "n": mk(batch, H, P, dims=("batch", "heads", "head_dim")),
        "m": mk(batch, H, dims=("batch", "heads")),
    }


# ---------------------------------------------------------------------------
# Prefill variants: apply + return decode state / populated KV cache
# ---------------------------------------------------------------------------
def tblock_prefill(x, p, cfg, cache_len: int, *, impl: str = "chunked",
                   enc_kv=None):
    """Run the block over a full prompt, returning (x, kv-cache padded to
    cache_len).  Cross-attention K/V (enc-dec) are cached too."""
    h = layers.apply_norm(x, p["ln_attn"], cfg.norm)
    q, k, v = attention.project_qkv(
        h, p["attn"], rope_theta=cfg.rope_theta, use_rope=cfg.use_rope)
    o = attention.attend(q, k, v, impl=impl, causal=True,
                         window=cfg.sliding_window,
                         q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    x = x + jnp.einsum("bqhk,hkd->bqd", o, p["attn"]["wo"].astype(x.dtype))
    pad = cache_len - k.shape[1]
    kc = jnp.pad(k.astype(cfg.cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v.astype(cfg.cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": kc, "v": vc}
    if "cross" in p:
        h = layers.apply_norm(x, p["ln_cross"], cfg.norm)
        x = x + attention.attn_layer(h, p["cross"], cfg, impl=impl,
                                     kv_override=enc_kv)
        cache["ck"], cache["cv"] = enc_kv
    h = layers.apply_norm(x, p["ln_mlp"], cfg.norm)
    if "moe" in p:
        y, aux = moe.apply_moe(h, p["moe"], top_k=cfg.top_k,
                               group_size=cfg.moe_group,
                               dispatch=cfg.moe_dispatch)
        x = x + y
    else:
        x = x + layers.apply_mlp(h, p["mlp"], cfg.mlp_kind)
    return x, cache


def mamba_block_prefill(x, p, cfg, *, chunk: int = 256):
    h = layers.apply_norm(x, p["ln"], cfg.norm)
    y, st = ssm.mamba2_chunked(h, p["mixer"], cfg, chunk=chunk,
                               return_state=True)
    return x + y, st


def mlstm_block_prefill(x, p, cfg, *, chunk: int = 256):
    h = layers.apply_norm(x, p["ln"], cfg.norm)
    y, st = ssm.mlstm_chunked(h, p["mixer"], cfg, chunk=chunk,
                              return_state=True)
    return x + y, st


def slstm_block_prefill(x, p, cfg):
    h = layers.apply_norm(x, p["ln"], cfg.norm)

    class _C:
        n_heads = cfg.slstm_heads
        d_model = cfg.d_model
    y, st = ssm.slstm_apply(h, p["mixer"], _C, return_state=True)
    return x + y, st
