"""Architecture assembly: decoder LMs (dense/moe/xlstm/zamba) and enc-dec.

Full-size configs scan over stacked per-layer parameters (small HLO, fast
512-way SPMD compiles) with per-block rematerialization; tiny configs run
the same code paths on CPU for smoke tests.

Contract (used by core.steps, launch.dryrun, examples):
  m = build_model(cfg)
  m.specs()                                  ParamSpec tree
  m.apply(params, batch)                  -> (logits, aux)     train fwd
  m.loss(params, batch)                   -> scalar
  m.cache_specs(batch, cache_len)            ParamSpec tree (zeros init)
  m.prefill(params, batch, cache_len)     -> (last logits, cache)
  m.decode_step(params, cache, batch, pos)-> (logits, cache)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core import compat
from ..core.sharding import ParamSpec, act_constrain
from . import blocks, layers, moe


def stack_specs(tree, n: int):
    """Prepend a scanned 'layers' dim to every ParamSpec in a tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.dims, s.dtype,
                            s.init, s.scale),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def _maybe_remat(fn, enable: bool):
    return jax.checkpoint(fn, prevent_cse=False) if enable else fn


# ===========================================================================
# Decoder-only LM (dense / moe / xlstm / zamba)
# ===========================================================================
class LM:
    def __init__(self, cfg):
        self.cfg = cfg

    # -- specs ----------------------------------------------------------------
    def specs(self) -> dict:
        cfg = self.cfg
        sp = {
            "embed": layers.embed_specs(cfg.vocab, cfg.d_model),
            "ln_f": layers.norm_specs(cfg.d_model, cfg.norm),
            "unembed": layers.unembed_specs(cfg.d_model, cfg.vocab),
        }
        fam = cfg.family
        if fam in ("dense", "moe"):
            sp["stack"] = stack_specs(
                blocks.tblock_specs(cfg, use_moe=(fam == "moe")), cfg.n_layers)
        elif fam == "xlstm":
            groups = cfg.n_layers // cfg.slstm_every
            per = cfg.slstm_every - 1
            sp["stack"] = {
                "m": stack_specs(stack_specs(blocks.mlstm_block_specs(cfg), per),
                                 groups),
                "s": stack_specs(blocks.slstm_block_specs(cfg), groups),
            }
        elif fam == "zamba":
            groups = cfg.n_layers // cfg.shared_every
            sp["stack"] = {
                "mamba": stack_specs(
                    stack_specs(blocks.mamba_block_specs(cfg),
                                cfg.shared_every), groups),
                "shared": blocks.tblock_specs(cfg),
            }
        else:
            raise ValueError(fam)
        # dtype override for parameters
        sp = jax.tree.map(
            lambda s: dataclasses.replace(s, dtype=cfg.p_dtype)
            if jnp.issubdtype(s.dtype, jnp.floating) else s,
            sp, is_leaf=lambda x: isinstance(x, ParamSpec))
        return sp

    # -- forward ---------------------------------------------------------------
    def _backbone(self, params, x):
        """x: [B, S, D] -> (x, aux)."""
        cfg = self.cfg
        fam = cfg.family

        if fam in ("dense", "moe"):
            def body(carry, p):
                h, aux = carry
                h = act_constrain(h, ("batch", "seq", "embed"))
                h, a = blocks.tblock_apply(h, p, cfg)
                # constrain the OUTPUT too: it is what scan saves for the
                # backward pass (the activation-checkpoint stack)
                h = act_constrain(h, ("batch", "seq", "embed"))
                return (h, aux + a), None
            body = _maybe_remat(body, cfg.remat)
            (x, aux), _ = compat.layer_scan(
                body, (x, jnp.zeros((), jnp.float32)), params["stack"])
            return x, aux

        if fam == "xlstm":
            def m_body(h, p):
                return blocks.mlstm_block_apply(h, p, cfg,
                                                chunk=cfg.ssm_chunk), None

            def g_body(h, gp):
                h, _ = compat.layer_scan(_maybe_remat(m_body, cfg.remat),
                                         h, gp["m"])
                h = blocks.slstm_block_apply(h, gp["s"], cfg)
                return h, None
            x, _ = compat.layer_scan(g_body, x, params["stack"])
            return x, jnp.zeros((), jnp.float32)

        if fam == "zamba":
            shared = params["stack"]["shared"]

            def m_body(h, p):
                return blocks.mamba_block_apply(h, p, cfg,
                                                chunk=cfg.ssm_chunk), None

            def g_body(h, gp):
                h = act_constrain(h, ("batch", "seq", "embed"))
                h, _ = compat.layer_scan(_maybe_remat(m_body, cfg.remat),
                                         h, gp)
                h, _ = blocks.tblock_apply(h, shared, cfg)
                h = act_constrain(h, ("batch", "seq", "embed"))
                return h, None
            g_fn = _maybe_remat(g_body, cfg.remat)
            x, _ = compat.layer_scan(g_fn, x, params["stack"]["mamba"])
            return x, jnp.zeros((), jnp.float32)

        raise ValueError(fam)

    def apply(self, params, batch):
        cfg = self.cfg
        x = layers.embed(batch["tokens"], params["embed"]).astype(cfg.c_dtype)
        x, aux = self._backbone(params, x)
        x = layers.apply_norm(x, params["ln_f"], cfg.norm)
        return layers.logits(x, params["unembed"]), aux

    def loss(self, params, batch):
        lg, aux = self.apply(params, batch)
        mask = batch.get("mask")
        return layers.softmax_xent(lg, batch["labels"], mask) \
            + self.cfg.aux_weight * aux

    # -- decode cache -----------------------------------------------------------
    def cache_specs(self, batch: int, cache_len: int) -> dict:
        cfg = self.cfg
        fam = cfg.family
        if fam in ("dense", "moe"):
            return blocks.kv_cache_specs(cfg, batch, cache_len,
                                         prefix=(cfg.n_layers,))
        if fam == "xlstm":
            groups = cfg.n_layers // cfg.slstm_every
            per = cfg.slstm_every - 1
            return {
                "m": blocks.mlstm_state_specs(cfg, batch, prefix=(groups, per)),
                "s": blocks.slstm_state_specs(cfg, batch, prefix=(groups,)),
            }
        if fam == "zamba":
            groups = cfg.n_layers // cfg.shared_every
            return {
                "mamba": blocks.mamba_state_specs(
                    cfg, batch, prefix=(groups, cfg.shared_every)),
                "shared": blocks.kv_cache_specs(cfg, batch, cache_len,
                                                prefix=(groups,)),
            }
        raise ValueError(fam)

    # -- prefill -----------------------------------------------------------------
    def prefill(self, params, batch, cache_len: int):
        cfg = self.cfg
        fam = cfg.family
        x = layers.embed(batch["tokens"], params["embed"]).astype(cfg.c_dtype)

        if fam in ("dense", "moe"):
            def body(h, p):
                h, c = blocks.tblock_prefill(h, p, cfg, cache_len)
                return h, c
            x, cache = jax.lax.scan(_maybe_remat(body, False), x,
                                    params["stack"])
        elif fam == "xlstm":
            def m_body(h, p):
                return blocks.mlstm_block_prefill(h, p, cfg,
                                                  chunk=cfg.ssm_chunk)

            def g_body(h, gp):
                h, mc = jax.lax.scan(m_body, h, gp["m"])
                h, sc = blocks.slstm_block_prefill(h, gp["s"], cfg)
                return h, {"m": mc, "s": sc}
            x, cache = jax.lax.scan(g_body, x, params["stack"])
        elif fam == "zamba":
            shared = params["stack"]["shared"]

            def m_body(h, p):
                return blocks.mamba_block_prefill(h, p, cfg,
                                                  chunk=cfg.ssm_chunk)

            def g_body(h, gp):
                h, mc = jax.lax.scan(m_body, h, gp)
                h, sc = blocks.tblock_prefill(h, shared, cfg, cache_len)
                return h, {"mamba": mc, "shared": sc}
            x, cache_t = jax.lax.scan(g_body, x, params["stack"]["mamba"])
            cache = {"mamba": cache_t["mamba"], "shared": cache_t["shared"]}
        else:
            raise ValueError(fam)

        x = layers.apply_norm(x[:, -1:], params["ln_f"], cfg.norm)
        return layers.logits(x, params["unembed"])[:, 0], cache

    # -- decode ------------------------------------------------------------------
    def decode_step(self, params, cache, batch, pos):
        """batch["tokens"]: [B, 1]; pos: scalar int32, or ``[B]`` int32 for
        per-row positions (the gateway's continuous batch; recurrent
        SSM/xLSTM blocks ignore pos, attention blocks broadcast it)."""
        cfg = self.cfg
        fam = cfg.family
        x = layers.embed(batch["tokens"], params["embed"]).astype(cfg.c_dtype)

        if fam in ("dense", "moe"):
            def body(h, pc):
                p, c = pc
                h, c2 = blocks.tblock_decode(h, p, cfg, c, pos)
                return h, c2
            x, cache = jax.lax.scan(body, x, (params["stack"], cache))
        elif fam == "xlstm":
            def m_body(h, pc):
                p, c = pc
                h, c2 = blocks.mlstm_block_decode(h, p, cfg, c, pos)
                return h, c2

            def g_body(h, gpc):
                gp, gc = gpc
                h, mc = jax.lax.scan(m_body, h, (gp["m"], gc["m"]))
                h, sc = blocks.slstm_block_decode(h, gp["s"], cfg, gc["s"], pos)
                return h, {"m": mc, "s": sc}
            x, cache = jax.lax.scan(g_body, x, (params["stack"], cache))
        elif fam == "zamba":
            shared = params["stack"]["shared"]

            def m_body(h, pc):
                p, c = pc
                h, c2 = blocks.mamba_block_decode(h, p, cfg, c, pos)
                return h, c2

            def g_body(h, gpc):
                gp, gc = gpc
                h, mc = jax.lax.scan(m_body, h, (gp, gc["mamba"]))
                h, sc = blocks.tblock_decode(h, shared, cfg, gc["shared"], pos)
                return h, {"mamba": mc, "shared": sc}
            x, cache = jax.lax.scan(
                g_body, x, (params["stack"]["mamba"], cache))
        else:
            raise ValueError(fam)

        x = layers.apply_norm(x, params["ln_f"], cfg.norm)
        return layers.logits(x, params["unembed"])[:, 0], cache


# ===========================================================================
# Encoder-decoder (whisper-style; frontend is a stub: precomputed frames)
# ===========================================================================
class EncDec:
    def __init__(self, cfg):
        self.cfg = cfg

    def specs(self) -> dict:
        cfg = self.cfg
        sp = {
            "embed": layers.embed_specs(cfg.vocab, cfg.d_model),
            "pos_dec": ParamSpec((cfg.max_dec_len, cfg.d_model),
                                 (None, "embed"), init="scaled", scale=0.01),
            "enc_stack": stack_specs(blocks.tblock_specs(cfg),
                                     cfg.n_enc_layers),
            "ln_enc": layers.norm_specs(cfg.d_model, cfg.norm),
            "dec_stack": stack_specs(blocks.tblock_specs(cfg, cross=True),
                                     cfg.n_layers),
            "ln_f": layers.norm_specs(cfg.d_model, cfg.norm),
            "unembed": layers.unembed_specs(cfg.d_model, cfg.vocab),
        }
        sp = jax.tree.map(
            lambda s: dataclasses.replace(s, dtype=cfg.p_dtype)
            if jnp.issubdtype(s.dtype, jnp.floating) else s,
            sp, is_leaf=lambda x: isinstance(x, ParamSpec))
        return sp

    def encode(self, params, frames):
        """frames: [B, S_enc, D] stub frontend output."""
        cfg = self.cfg
        x = frames.astype(cfg.c_dtype)
        x = x + layers.sinusoidal_embedding(x.shape[1], cfg.d_model
                                            ).astype(cfg.c_dtype)[None]

        def body(h, p):
            h, _ = blocks.tblock_apply(h, p, cfg, causal=False)
            return h, None
        x, _ = compat.layer_scan(_maybe_remat(body, cfg.remat), x,
                                 params["enc_stack"])
        return layers.apply_norm(x, params["ln_enc"], cfg.norm)

    def _dec_embed(self, params, tokens, pos0=0):
        cfg = self.cfg
        x = layers.embed(tokens, params["embed"]).astype(cfg.c_dtype)
        pe = jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos0,
                                          tokens.shape[1], axis=0)
        return x + pe.astype(cfg.c_dtype)[None]

    def apply(self, params, batch):
        """batch: frames [B,S_enc,D], tokens/labels [B,S_dec]."""
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        x = self._dec_embed(params, batch["tokens"])

        def body(h, p):
            # per-layer cross K/V from encoder output
            ck = jnp.einsum("bsd,dhk->bshk", enc,
                            p["cross"]["wk"].astype(enc.dtype))
            cv = jnp.einsum("bsd,dhk->bshk", enc,
                            p["cross"]["wv"].astype(enc.dtype))
            h, _ = blocks.tblock_apply(h, p, cfg, enc_kv=(ck, cv))
            return h, None
        x, _ = compat.layer_scan(_maybe_remat(body, cfg.remat), x,
                                 params["dec_stack"])
        x = layers.apply_norm(x, params["ln_f"], cfg.norm)
        return layers.logits(x, params["unembed"]), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        lg, _ = self.apply(params, batch)
        return layers.softmax_xent(lg, batch["labels"], batch.get("mask"))

    def cache_specs(self, batch: int, cache_len: int) -> dict:
        cfg = self.cfg
        sp = blocks.kv_cache_specs(cfg, batch, cache_len,
                                   prefix=(cfg.n_layers,))
        cross = blocks.kv_cache_specs(cfg, batch, cfg.enc_frames,
                                      prefix=(cfg.n_layers,))
        sp["ck"], sp["cv"] = cross["k"], cross["v"]
        return sp

    def prefill(self, params, batch, cache_len: int):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        x = self._dec_embed(params, batch["tokens"])

        def body(h, p):
            ck = jnp.einsum("bsd,dhk->bshk", enc,
                            p["cross"]["wk"].astype(enc.dtype))
            cv = jnp.einsum("bsd,dhk->bshk", enc,
                            p["cross"]["wv"].astype(enc.dtype))
            h, c = blocks.tblock_prefill(h, p, cfg, cache_len,
                                         enc_kv=(ck, cv))
            return h, c
        x, cache = jax.lax.scan(body, x, params["dec_stack"])
        x = layers.apply_norm(x[:, -1:], params["ln_f"], cfg.norm)
        return layers.logits(x, params["unembed"])[:, 0], cache

    def decode_step(self, params, cache, batch, pos):
        cfg = self.cfg
        x = self._dec_embed(params, batch["tokens"], pos0=pos)

        def body(h, pc):
            p, c = pc
            h, c2 = blocks.tblock_decode(h, p, cfg, c, pos,
                                         enc_kv=(c["ck"], c["cv"]))
            c2["ck"], c2["cv"] = c["ck"], c["cv"]
            return h, c2
        x, cache = jax.lax.scan(body, x, (params["dec_stack"], cache))
        x = layers.apply_norm(x, params["ln_f"], cfg.norm)
        return layers.logits(x, params["unembed"])[:, 0], cache


def build_model(cfg):
    return EncDec(cfg) if cfg.family == "encdec" else LM(cfg)
