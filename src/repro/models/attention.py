"""Grouped-query attention: training (chunked/flash-style), prefill, decode.

Implementations:
  * ``full``    - materialized logits; oracle for tests and small models.
  * ``chunked`` - online-softmax over query blocks (lax.scan + checkpoint),
                  the memory shape of FlashAttention expressed in pure jnp;
                  this is what full-size dry-run configs lower.
  * Pallas kernel (kernels/flash_attention.py) plugs in through the same
    signature on TPU via kernels/ops.py.

Decode attends a single new token against a KV cache; for long contexts the
cache's sequence dim may be sharded (tiling plan "kv_seq"), in which case the
softmax reduction spans shards - XLA partitions those reductions, and the
optimized path uses the explicit flash-decoding combine in core.collectives.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import compat
from ..core.sharding import ParamSpec
from . import layers

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------
def attn_specs(d: int, n_heads: int, n_kv: int, head_dim: int, *,
               qkv_bias: bool = False, qk_norm: bool = False) -> dict:
    sp = {
        "wq": ParamSpec((d, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((n_heads, head_dim, d), ("heads", "head_dim", "embed")),
    }
    if qkv_bias:
        sp["bq"] = ParamSpec((n_heads, head_dim), ("heads", "head_dim"), init="zeros")
        sp["bk"] = ParamSpec((n_kv, head_dim), ("kv_heads", "head_dim"), init="zeros")
        sp["bv"] = ParamSpec((n_kv, head_dim), ("kv_heads", "head_dim"), init="zeros")
    if qk_norm:
        sp["q_norm"] = ParamSpec((head_dim,), ("head_dim",), init="ones")
        sp["k_norm"] = ParamSpec((head_dim,), ("head_dim",), init="ones")
    return sp


def project_qkv(x, p, *, positions=None, rope_theta: float = 10000.0,
                use_rope: bool = True):
    """x: [B, S, D] -> q [B,S,H,hd], k/v [B,S,Hkv,hd]."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if "q_norm" in p:
        q = layers.rms_norm(q, p["q_norm"])
        k = layers.rms_norm(k, p["k_norm"])
    if use_rope:
        if positions is None:
            positions = jnp.arange(x.shape[1])[None, :]
        q = layers.apply_rope(q, positions, rope_theta)
        k = layers.apply_rope(k, positions, rope_theta)
    return q, k, v


def _expand_kv(k, n_heads: int):
    """[B,S,Hkv,hd] -> [B,S,H,hd] by repeating each kv head (GQA)."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """[.., Sq, Sk] additive bias from position grids."""
    m = jnp.zeros(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]),
                  jnp.float32)
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        m = jnp.where(diff < 0, NEG_INF, m)
    if window is not None:
        m = jnp.where(diff >= window, NEG_INF, m)
    return m


# ---------------------------------------------------------------------------
# Training / prefill attention
# ---------------------------------------------------------------------------
def attend_full(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                q_offset: int = 0, scale: Optional[float] = None):
    """Oracle: materialized [B,H,Sq,Sk] logits."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = scale or (1.0 / math.sqrt(hd))
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    lg = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    q_pos = (jnp.arange(Sq) + q_offset)[None, :]
    k_pos = jnp.arange(Sk)[None, :]
    lg = lg + _mask_bias(q_pos, k_pos, causal=causal, window=window)[:, None]
    pr = jax.nn.softmax(lg, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", pr, v)


def _row_blocks(iq: int, nk: int, q_chunk: int, kv_chunk: int,
                q_offset: int, causal: bool, window: Optional[int]):
    """kv-block indices visible to query chunk ``iq`` (static)."""
    q_lo = iq * q_chunk + q_offset
    q_hi = q_lo + q_chunk - 1
    out = []
    for ik in range(nk):
        k_lo = ik * kv_chunk
        k_hi = k_lo + kv_chunk - 1
        if causal and q_hi < k_lo:
            continue  # entirely in the future
        if window is not None and q_lo - k_hi >= window:
            continue  # entirely behind the window
        out.append(ik)
    return out


def attend_chunked(q, k, v, *, causal: bool = True,
                   window: Optional[int] = None, q_chunk: int = 1024,
                   kv_chunk: int = 1024, remat_chunks: bool = True,
                   q_offset: int = 0, scale: Optional[float] = None):
    """Online-softmax blocked attention (FlashAttention's shape in jnp).

    The outer loop over query chunks is a *Python* unroll, so each chunk's
    inner lax.scan runs over exactly the kv blocks it can see - causal
    attention pays the triangle's FLOPs, not the square's, with a small
    per-chunk carry (O(q_chunk*hd)).  Probabilities are never stored: the
    block body is rematerialized in the backward pass.
    """
    from ..core.sharding import act_constrain
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = scale or (1.0 / math.sqrt(hd))
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    # pin attention tensors to head-TP: without this the partitioner may
    # shard the kv-block dim instead and all-gather K/V per query row
    # (observed at 4.8 TB/step wire on chameleon prefill_32k, §Perf)
    q = act_constrain(q, ("batch", None, "heads", "head_dim"))
    k = act_constrain(k, ("batch", None, "heads", "head_dim"))
    v = act_constrain(v, ("batch", None, "heads", "head_dim"))

    def _snap(S, c):
        c = min(c, S)
        while S % c:
            c -= 1
        return c

    q_chunk = _snap(Sq, q_chunk)
    kv_chunk = _snap(Sk, kv_chunk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk

    kb = jnp.moveaxis(k.reshape(B, nk, kv_chunk, H, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, kv_chunk, H, hd), 1, 0)

    outs = []
    for iq in range(nq):
        qi = jax.lax.slice_in_dim(q, iq * q_chunk, (iq + 1) * q_chunk, axis=1)
        blocks = _row_blocks(iq, nk, q_chunk, kv_chunk, q_offset, causal,
                             window)
        if not blocks:
            outs.append(jnp.zeros((B, q_chunk, H, hd), q.dtype))
            continue
        lo, hi = blocks[0], blocks[-1]       # always a contiguous range

        def block(carry, inputs, iq=iq):
            o, m, l = carry                  # [B,H,qc,hd],[B,H,qc],[B,H,qc]
            kj, vj, ik = inputs
            lg = jnp.einsum("bqhk,bshk->bhqs", qi, kj
                            ).astype(jnp.float32) * scale
            q_pos = iq * q_chunk + q_offset + jnp.arange(q_chunk)
            k_pos = ik * kv_chunk + jnp.arange(kv_chunk)
            diff = q_pos[:, None] - k_pos[None, :]
            bias = jnp.zeros_like(diff, jnp.float32)
            if causal:
                bias = jnp.where(diff < 0, NEG_INF, bias)
            if window is not None:
                bias = jnp.where(diff >= window, NEG_INF, bias)
            lg = lg + bias[None, None]
            m_new = jnp.maximum(m, lg.max(-1))
            m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)  # all-masked rows
            p = jnp.exp(lg - m_safe[..., None])
            corr = jnp.exp(m - m_safe)
            l = l * corr + p.sum(-1)
            o = o * corr[..., None] + jnp.einsum(
                "bhqs,bshk->bhqk", p.astype(qi.dtype), vj).astype(jnp.float32)
            return (o, m_new, l), None

        o0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        body = jax.checkpoint(block) if remat_chunks else block
        (o, m, l), _ = compat.layer_scan(
            body, (o0, m0, l0),
            (jax.lax.slice_in_dim(kb, lo, hi + 1, axis=0),
             jax.lax.slice_in_dim(vb, lo, hi + 1, axis=0),
             jnp.arange(lo, hi + 1, dtype=jnp.int32)))
        o = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        outs.append(o.transpose(0, 2, 1, 3))     # [B, qc, H, hd]
    return jnp.concatenate(outs, axis=1)


def attend(q, k, v, *, impl: str = "chunked", **kw):
    qc = kw.get("q_chunk", 1024)
    kc = kw.get("kv_chunk", 1024)
    indivisible = (q.shape[1] % min(qc, q.shape[1]) != 0
                   or k.shape[1] % min(kc, k.shape[1]) != 0)
    if impl == "full" or indivisible:
        kw.pop("q_chunk", None); kw.pop("kv_chunk", None); kw.pop("remat_chunks", None)
        return attend_full(q, k, v, **kw)
    return attend_chunked(q, k, v, **kw)


# ---------------------------------------------------------------------------
# Decode (KV-cache) attention
# ---------------------------------------------------------------------------
def decode_attend(q, k_cache, v_cache, pos, *, scale: Optional[float] = None,
                  window: Optional[int] = None):
    """q: [B,1,H,hd]; caches [B,S,Hkv,hd]; pos: scalar current index, or a
    ``[B]`` vector when batch rows sit at different offsets (the serving
    gateway's continuous batch, where each slot decodes its own token
    index - DESIGN.md §14).

    Grouped-GQA form: KV heads are never expanded, so the only shardable
    names are (batch, kv_heads, kv_seq) - a sequence-sharded cache keeps its
    sharding through the softmax (partial max/sum + psum) instead of being
    re-sharded by heads (which costs a full-cache all-gather; see §Perf
    granite-decode iterations).
    """
    from ..core.sharding import act_constrain
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = scale or (1.0 / math.sqrt(hd))
    qg = q.reshape(B, 1, Hkv, G, hd)
    lg = jnp.einsum("bqhgk,bshk->bhgqs", qg, k_cache
                    ).astype(jnp.float32) * scale      # [B,Hkv,G,1,S]
    lg = act_constrain(lg, ("batch", "kv_heads", None, None, "kv_seq"))
    k_pos = jnp.arange(S)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        valid = k_pos <= pos
        if window is not None:
            valid = valid & (k_pos > pos - window)
        mask = valid[None, None, None, None, :]
    else:                                   # per-row positions: [B] -> [B,S]
        valid = k_pos[None, :] <= pos[:, None]
        if window is not None:
            valid = valid & (k_pos[None, :] > pos[:, None] - window)
        mask = valid[:, None, None, None, :]
    lg = jnp.where(mask, lg, NEG_INF)
    pr = jax.nn.softmax(lg, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqs,bshk->bqhgk", pr, v_cache)
    return o.reshape(B, 1, H, hd)


def cache_update(k_cache, v_cache, k_new, v_new, pos, *, mode: str = "dus"):
    """Write the new token's K/V at ``pos`` (scalar, or ``[B]`` for
    per-row write offsets).

    mode="dus": dynamic-update-slice (minimal write, but the SPMD
    partitioner reshards a cache whose sequence dim is sharded).
    mode="masked": one-hot select over the sequence dim - elementwise, so a
    sequence-sharded cache updates locally with zero collectives at the cost
    of a full cache rewrite.  A ``[B]`` pos always takes this form: there
    is no per-row dynamic-update-slice, and the one-hot write is exactly
    row-independent, which the gateway's bit-parity guarantees rely on.
    """
    pos = jnp.asarray(pos)
    if mode == "masked" or pos.ndim:
        S = k_cache.shape[1]
        hit = ((jnp.arange(S) == pos)[None, :, None, None] if pos.ndim == 0
               else (jnp.arange(S)[None, :] == pos[:, None])[:, :, None,
                                                             None])
        k_cache = jnp.where(hit, k_new.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(hit, v_new.astype(v_cache.dtype), v_cache)
        return k_cache, v_cache
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# Whole attention layer (projections + attend + out proj)
# ---------------------------------------------------------------------------
def attn_layer(x, p, cfg, *, impl: str = "chunked", positions=None,
               kv_override=None, causal: bool = True):
    """cfg needs: n_heads, n_kv_heads, head_dim, rope_theta, use_rope,
    sliding_window, q_chunk/kv_chunk optional.

    kv_override: (k, v) from an encoder for cross-attention.
    """
    dt = x.dtype
    q, k, v = project_qkv(
        x, p, positions=positions, rope_theta=cfg.rope_theta,
        use_rope=cfg.use_rope and kv_override is None)
    if kv_override is not None:
        k, v = kv_override
        causal = False
    o = attend(q, k, v, impl=impl, causal=causal,
               window=cfg.sliding_window,
               q_chunk=getattr(cfg, "q_chunk", 1024),
               kv_chunk=getattr(cfg, "kv_chunk", 1024))
    return jnp.einsum("bqhk,hkd->bqd", o, p["wo"].astype(dt))
