"""Elastic scale-out: join latency, work-steal uptake, and AGAS
rebalance cost when a locality dials into a RUNNING session
(DESIGN.md §13).

Three cells:

  * ``static``     - 1- and 2-locality reference trains (median
                     steady-state step time, same hook timing as
                     ``ddp_throughput``).
  * ``elastic``    - a 1-locality elastic train that gains a worker at
                     the end of warmup: reports the blocking
                     ``add_locality`` latency (spawn + hello + gossip +
                     rebalance), post-join step time, ``stolen_tasks``
                     and the final-loss delta vs the static run (must
                     be exactly 0.0 - stealing moves placement, never
                     values; re-asserted here outside pytest).
  * ``rebalance``  - a bare graph with pinned driver objects: join
                     latency as a function of migrated state, plus the
                     stale-ref deref cost through forwarding stubs.

Writes the versioned ``BENCH_elastic_scaleout.json`` (repo root;
commit it when regenerating on a reference machine):

  PYTHONPATH=src python -m benchmarks.elastic_scaleout            # full
  PYTHONPATH=src python -m benchmarks.elastic_scaleout --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.distrib import DistributedGraph
from repro.frontend.plan import Plan

VERSION = 1


def _plan(**kw):
    kw.setdefault("arch", "qwen2.5-3b")
    kw.setdefault("batch", 4)
    kw.setdefault("seq", 16)
    kw.setdefault("seed", 0)
    return Plan(**kw)


class _Stamps:
    def __init__(self, session=None, join_at=None):
        self.times: list = []
        self.session = session
        self.join_at = join_at
        self.join_s = None

    def on_step(self, it, metrics):
        if it == self.join_at:
            t0 = time.perf_counter()
            self.session.add_locality()
            self.join_s = time.perf_counter() - t0
        if self.join_at is not None and it == self.join_at + 2:
            # one device-step-sized stall: the joiner drains, goes
            # hungry, and steerable prefetch builds start diverting to
            # it - the deterministic steal window (same as the drill in
            # tests/test_elastic.py)
            time.sleep(0.25)
        self.times.append(time.perf_counter())


def _median_dt(times, skip):
    deltas = sorted(b - a for a, b in zip(times[skip:], times[skip + 1:]))
    return max(deltas[len(deltas) // 2], 1e-6)


def run_static(localities: int, *, warmup: int, timed: int) -> dict:
    plan = _plan(localities=localities) if localities > 1 else _plan()
    stamps = _Stamps()
    with plan.compile() as session:
        out = session.train(steps=warmup + timed, hooks=stamps,
                            log_every=warmup + timed, verbose=False)
    dt = _median_dt(stamps.times, warmup)
    return {"cell": "static", "localities": localities,
            "steps_per_s": round(1.0 / dt, 3),
            "step_ms": round(1e3 * dt, 3),
            "final_loss": float(out["final_loss"])}


def run_elastic(*, warmup: int, timed: int, ref_loss: float) -> dict:
    with _plan(elastic=True).compile() as session:
        stamps = _Stamps(session, join_at=warmup)
        out = session.train(steps=warmup + timed, hooks=stamps,
                            log_every=warmup + timed, verbose=False)
        d = out["runtime_stats"]["distributed"]
    if d["joined_localities"] != 1:
        raise AssertionError(f"join never completed: {d}")
    if d["stolen_tasks"] <= 0:
        raise AssertionError(f"the joiner stole nothing: {d}")
    loss_delta = abs(float(out["final_loss"]) - ref_loss)
    if loss_delta > 1e-6:
        raise AssertionError(
            f"elastic join changed the loss by {loss_delta} - stealing "
            f"must move placement, never values")
    dt = _median_dt(stamps.times, warmup + 3)     # post-join steady state
    return {"cell": "elastic", "localities": "1+1",
            "join_ms": round(1e3 * stamps.join_s, 3),
            "steps_per_s": round(1.0 / dt, 3),
            "step_ms": round(1e3 * dt, 3),
            "stolen_tasks": int(d["stolen_tasks"]),
            "migrated_objects": int(d["migrated_objects"]),
            "membership_gen": int(d["membership_gen"]),
            "final_loss": float(out["final_loss"]),
            "loss_delta_vs_static": loss_delta}


def _make_blob(i, kb):
    import numpy as np
    return np.full((kb * 256,), i, np.float32)      # kb KiB of payload


def run_rebalance(n_objects: int, kb: int) -> dict:
    g = DistributedGraph(localities=1, elastic=True)
    try:
        refs = [g.defer(_make_blob, i, kb, name=f"blob{i}",
                        pin=True).result(timeout=60)
                for i in range(n_objects)]
        t0 = time.perf_counter()
        g.add_locality(timeout=120)
        join_s = time.perf_counter() - t0
        s = g.stats()
        if s["migrated_objects"] <= 0:
            raise AssertionError(f"rebalance moved nothing: {s}")
        t0 = time.perf_counter()
        for ref in refs:                            # stale refs: stub-chased
            g.fetch(ref)
        deref_s = (time.perf_counter() - t0) / max(len(refs), 1)
        return {"cell": "rebalance", "objects": n_objects,
                "object_kib": kb,
                "join_ms": round(1e3 * join_s, 3),
                "migrated_objects": int(s["migrated_objects"]),
                "stale_deref_us": round(1e6 * deref_s, 1),
                "forwarded_fetches":
                    int(g.directory.audit()["forwarded_fetches"])}
    finally:
        g.shutdown()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--warmup", type=int, default=5)
    ap.add_argument("--timed", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes (2 warmup / 8 timed steps, one "
                         "rebalance point); asserts join + steal still")
    ap.add_argument("--out", default=str(Path(__file__).resolve()
                                         .parent.parent
                                         / "BENCH_elastic_scaleout.json"))
    args = ap.parse_args()
    warmup, timed = (2, 8) if args.smoke else (args.warmup, args.timed)
    results = []
    for loc in (1, 2):
        r = run_static(loc, warmup=warmup, timed=timed)
        results.append(r)
        print(f"static  W={loc}  {r['steps_per_s']:8.2f} steps/s "
              f"({r['step_ms']:.2f} ms)", flush=True)
    ref_loss = results[0]["final_loss"]
    r = run_elastic(warmup=warmup, timed=timed, ref_loss=ref_loss)
    results.append(r)
    print(f"elastic 1+1 join {r['join_ms']:7.1f} ms  "
          f"{r['steps_per_s']:8.2f} steps/s  stolen {r['stolen_tasks']}  "
          f"loss delta {r['loss_delta_vs_static']:.1e}", flush=True)
    for n, kb in ((8, 4),) if args.smoke else ((8, 4), (64, 4), (64, 64)):
        r = run_rebalance(n, kb)
        results.append(r)
        print(f"rebal   {n:3d} x {kb:3d} KiB  join {r['join_ms']:7.1f} ms  "
              f"migrated {r['migrated_objects']:3d}  stale deref "
              f"{r['stale_deref_us']:7.1f} us", flush=True)
    doc = {"bench": "elastic_scaleout", "version": VERSION,
           "arch": "qwen2.5-3b", "batch": 4, "seq": 16,
           "warmup_steps": warmup, "timed_steps": timed,
           "smoke": bool(args.smoke), "results": results}
    Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
