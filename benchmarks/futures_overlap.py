"""Futurized vs. serial host step loop: the overlap win, measured.

Both loops run the same work per step on CPU devices:
  * host data load   - ``LMStream.batch_at`` behind a storage-latency model
                       (``--load-ms`` of blocking wait, as a remote fetch
                       would be; GIL released, like real file/network I/O)
  * device compute   - a jit'd embedding + matmul-chain step
  * checkpoint I/O   - a periodic ``CheckpointManager.save`` of the params

The *serial* loop is the naive ordering: fetch batch, dispatch, force the
outputs, write the checkpoint synchronously - nothing overlaps, so a step
costs load + compute + amortised save.  The *futurized* loop runs the
identical work through ``core.futures``: batches prefetch as
``Lane.PREFETCH`` graph nodes, up to 2 steps stay in flight via
``Pipeline``, metric forcing is a COMPUTE-lane node, and checkpoint writes
are CHECKPOINT-lane nodes depending on step retirement - a step costs
~max(load, compute).  Wall-clock ratio is the paper's async-I/O-overlap
argument at the host boundary.

With ``--load-ms 0`` the workload degenerates to pure-compute on an
already-saturated CPU device; there is nothing to hide and the runtime's
job is merely to not get in the way.

``--localities N`` (N > 1) adds a *multi-locality* variant: the same
futurized loop, but batch builds run on N-1 worker processes via the
active-message runtime (`repro.distrib`) and stream back as futures
resolve.  The storage-latency sleep then burns in another process - true
overlap across the wire, bought at the cost of shipping each batch back
(the printed wire bytes).  This quantifies the paper's claim that the
futurized tree survives distribution.

    PYTHONPATH=src python benchmarks/futures_overlap.py [--steps 40]
    PYTHONPATH=src python benchmarks/futures_overlap.py --localities 2

Exits non-zero if the futurized loop is slower than the serial loop (the
distributed variant is informative, not gating: wire cost vs load-ms is
a real trade, not a regression).
"""
import argparse
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from repro.checkpoint.checkpoint import CheckpointManager  # noqa: E402
from repro.core.futures import FuturizedGraph, Lane, Pipeline  # noqa: E402
from repro.data.pipeline import LMStream, Prefetcher  # noqa: E402


class LatencyStream:
    """A stream whose ``batch_at`` waits ``load_ms`` first - the storage /
    network fetch a real input pipeline blocks on (GIL released)."""

    def __init__(self, stream: LMStream, load_ms: float):
        self.stream = stream
        self.load_s = load_ms / 1e3

    def batch_at(self, step: int) -> dict:
        if self.load_s:
            time.sleep(self.load_s)
        return self.stream.batch_at(step)


def make_step(vocab: int, d: int):
    @jax.jit
    def step(params, batch):
        h = params["emb"][batch["tokens"]]
        for _ in range(4):
            h = jnp.tanh(h @ params["w"])
        logits = h @ params["emb"].T
        loss = -jnp.mean(jax.nn.log_softmax(logits)[..., 0])
        return {"loss": loss, "h": h}

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {"emb": jax.random.normal(k1, (vocab, d)) * 0.02,
              "w": jax.random.normal(k2, (d, d)) * 0.02}
    return step, params


def serial_loop(step, params, stream, steps, ckpt_dir, ckpt_every) -> float:
    ckpt = CheckpointManager(ckpt_dir, async_save=False)
    t0 = time.perf_counter()
    for it in range(steps):
        batch = stream.batch_at(it)                    # host build, blocking
        out = step(params, batch)
        jax.block_until_ready(out)                     # force every step
        float(out["loss"])
        if (it + 1) % ckpt_every == 0:
            ckpt.save(it + 1, params)                  # synchronous write
    return time.perf_counter() - t0


def futurized_loop(step, params, stream, steps, ckpt_dir, ckpt_every) -> tuple:
    runtime = FuturizedGraph(max_workers=4, name="bench")
    prefetch = Prefetcher(stream, shardings=None, depth=2, graph=runtime)
    ckpt = CheckpointManager(ckpt_dir, graph=runtime)
    inflight = Pipeline(depth=2)
    loss_futs = []
    t0 = time.perf_counter()
    for it in range(steps):
        batch = prefetch.get(it)                       # built ahead, off-thread
        out = step(params, batch)
        inflight.push(it, out)                         # bounded async dispatch
        loss_futs.append(runtime.defer(
            lambda m: float(m["loss"]), out, lane=Lane.CHECKPOINT,
            name=f"force:{it}"))
        if (it + 1) % ckpt_every == 0:
            retired = runtime.defer(jax.block_until_ready, out,
                                    lane=Lane.CHECKPOINT,
                                    name=f"retire:{it}")
            ckpt.save(it + 1, params, deps=(retired,)) # background write
    inflight.drain()
    ckpt.wait()
    runtime.barrier()
    dt = time.perf_counter() - t0
    assert len(loss_futs) == steps
    runtime.gather(loss_futs)
    stats = runtime.stats()
    runtime.shutdown(wait=True)
    return dt, stats


def distributed_loop(step, params, stream, steps, ckpt_dir, ckpt_every,
                     localities) -> tuple:
    """The futurized loop with batch builds placed on worker localities:
    ``Prefetcher(dgraph=...)`` ships ``stream.batch_at`` across the wire
    and the results stream back as the loop's prefetch futures."""
    from repro.distrib import DistributedGraph

    runtime = FuturizedGraph(max_workers=4, name="bench-distrib")
    dgraph = DistributedGraph(localities=localities, graph=runtime,
                              name="bench")
    prefetch = Prefetcher(stream, shardings=None, depth=2, graph=runtime,
                          dgraph=dgraph)
    ckpt = CheckpointManager(ckpt_dir, graph=runtime)
    inflight = Pipeline(depth=2)
    t0 = time.perf_counter()
    for it in range(steps):
        batch = prefetch.get(it)
        out = step(params, batch)
        inflight.push(it, out)
        if (it + 1) % ckpt_every == 0:
            retired = runtime.defer(jax.block_until_ready, out,
                                    lane=Lane.CHECKPOINT,
                                    name=f"retire:{it}")
            ckpt.save(it + 1, params, deps=(retired,))
    inflight.drain()
    ckpt.wait()
    dgraph.barrier()
    runtime.barrier()
    dt = time.perf_counter() - t0
    dstats = dgraph.stats()
    dgraph.shutdown()
    runtime.shutdown(wait=True)
    return dt, dstats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--load-ms", type=float, default=25.0)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--localities", type=int, default=1,
                    help="> 1 adds the multi-locality variant (N-1 worker "
                         "processes build batches over the wire)")
    args = ap.parse_args()

    step, params = make_step(args.vocab, args.d)
    stream = LatencyStream(
        LMStream(vocab=args.vocab, batch=args.batch, seq=args.seq),
        args.load_ms)
    # warm the jit cache + stream codepaths outside both timed regions
    jax.block_until_ready(step(params, stream.batch_at(0)))

    t_dist = dstats = None
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2, \
            tempfile.TemporaryDirectory() as d3:
        t_serial = serial_loop(step, params, stream, args.steps, d1,
                               args.ckpt_every)
        t_fut, stats = futurized_loop(step, params, stream, args.steps, d2,
                                      args.ckpt_every)
        if args.localities > 1:
            t_dist, dstats = distributed_loop(
                step, params, stream, args.steps, d3, args.ckpt_every,
                args.localities)

    ms = 1e3 / args.steps
    print(f"serial    : {t_serial:7.3f}s  ({t_serial * ms:6.1f} ms/step)")
    print(f"futurized : {t_fut:7.3f}s  ({t_fut * ms:6.1f} ms/step)")
    print(f"speedup   : {t_serial / t_fut:7.2f}x")
    if t_dist is not None:
        print(f"distrib   : {t_dist:7.3f}s  ({t_dist * ms:6.1f} ms/step) "
              f"x{args.localities} localities")
        print(f"wire      : dispatched={dict(dstats['dispatched'])} "
              f"sent={dstats['bytes_sent']}B recv={dstats['bytes_recv']}B "
              f"respawned={dstats['respawned']}")
    print(f"runtime   : tasks={stats.completed} "
          f"max_in_flight={stats.max_in_flight} "
          f"idle={stats.idle_s:.2f}s busy={stats.busy_s:.2f}s "
          f"lanes={stats.per_lane}")
    if t_fut > t_serial:
        print("FAIL: futurized loop slower than serial")
        raise SystemExit(1)
    print("OK: futurized <= serial")


if __name__ == "__main__":
    main()
