"""Shared helpers for the benchmark harness."""
import os
import subprocess
import sys
import textwrap
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if p.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{p.stderr[-3000:]}")
    return p.stdout


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median-ish wall time per call in seconds."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
