"""Paper Table 1, made executable: the framework-requirement matrix as
measured behaviour instead of checkmarks.

For each strategy/feature we measure a step on an 8-device mesh and report
wall time plus the collective inventory from the compiled HLO - i.e. the
evidence behind every check mark in the phyrax row of Table 1.
"""
from __future__ import annotations

import json

from .common import emit, run_devices

_SNIPPET = """
import json, time
import jax
from repro.configs import get_config
from repro.core import steps as steps_lib, hlo_costs
from repro.data.pipeline import LMStream
from repro.launch.mesh import make_local_mesh
from repro.optim.optimizers import OptConfig

cfg = get_config('qwen2.5-3b', tiny=True)
mesh = make_local_mesh(data={data}, model={model})
shape = {{'seq_len': 64, 'global_batch': 8, 'kind': 'train'}}
step = steps_lib.make_train_step(
    cfg, mesh, steps_lib.Strategy(name='{strategy}',
                                  sequence_parallel={sp}), shape)
stream = LMStream(vocab=cfg.vocab, batch=8, seq=64)
params, opt = step.init(jax.random.PRNGKey(0))
b = {{k: jax.device_put(v, step.batch_shardings[k])
     for k, v in stream.batch_at(0).items()}}
co = step.fn.lower(params, opt, b).compile()
costs = hlo_costs.analyze(co.as_text(), {ndev})
m, p2, o2 = step.fn(params, opt, b)
jax.block_until_ready(p2)
params, opt = p2, o2
t0 = time.perf_counter()
for i in range(1, 4):
    b = {{k: jax.device_put(v, step.batch_shardings[k])
         for k, v in stream.batch_at(i).items()}}
    m, params, opt = step.fn(params, opt, b)
jax.block_until_ready(params)
dt = (time.perf_counter() - t0) / 3
print('RESULT', json.dumps({{
    'dt': dt, 'coll_counts': costs.coll_counts,
    'coll_operands': costs.coll_operands,
    'wire_bytes': costs.total_wire_bytes,
    'payload': {{k: float(v) for k, v in costs.coll_payload.items()}}}}))
"""

ROWS = [
    # name, strategy, data, model, sp
    ("data_par_horovod", "horovod", 8, 1, False),
    ("data_par_phylanx", "phylanx", 8, 1, False),
    ("hybrid_dp_tp", "phylanx", 4, 2, False),
    ("hybrid_dp_tp_sp", "phylanx", 4, 2, True),
    ("zero1_sharded_solver", "zero1", 8, 1, False),
    ("onebit_compressed", "onebit", 8, 1, False),
]


def main():
    for name, strategy, data, model, sp in ROWS:
        r = run_devices(_SNIPPET.format(strategy=strategy, data=data,
                                        model=model, sp=sp,
                                        ndev=data * model), n_devices=8)
        res = json.loads(r.split("RESULT", 1)[1])
        n_ar = sum(int(v) for v in res["coll_counts"].values())
        n_launch = sum(int(v) for v in res["coll_operands"].values())
        emit(f"table1_{name}", res["dt"] * 1e6,
             f"collective_ops={n_ar};logical_launches={n_launch};"
             f"wire_bytes={res['wire_bytes']:.0f}")


if __name__ == "__main__":
    main()
