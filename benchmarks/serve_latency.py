"""Serving gateway latency: per-token p50/p95, queue-wait and end-to-end
request percentiles, plus admission/paged-cache accounting (DESIGN.md
§14).

Three cells over the same request load:

  * ``wave``       - the fixed-wave baseline (``Session.serve``): slots
    prefill/decode in lockstep, idle slots padded.
  * ``stream``     - the gateway (``Session.serve_stream``), every
    request arriving at round 0.
  * ``stream-mid`` - the gateway with staggered mid-flight arrivals
    (requests > slots), the shape the paged cache exists for.
  * ``stream-x2`` - the stream-mid load over 2 model replicas (DESIGN.md
    §15): the router spreads requests across two decode chains; steady
    state must show zero cross-replica page fetches.

Percentiles come from the run's own ``request_latency_hist`` (the
histograms ``RuntimeStats`` already ships) via linear interpolation
inside the hit bucket - the benchmark consumes exactly what production
stats expose.  The paged-cache accounting is re-asserted here outside
pytest: every refill must be a page hit, the prefill-recompute fallback
must never run, and every page must be reclaimed - any mismatch fails
the benchmark.

Writes the versioned ``BENCH_serve_latency.json`` (repo root; commit it
when regenerating on a reference machine):

  PYTHONPATH=src python -m benchmarks.serve_latency            # full
  PYTHONPATH=src python -m benchmarks.serve_latency --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.frontend.plan import Plan

VERSION = 2
PHASES = ("queue_wait", "prefill", "decode_token", "total")


def hist_quantile(edges_s, counts, q):
    """Approximate the ``q``-quantile (seconds) of a bucketed histogram
    by linear interpolation inside the hit bucket (the final unbounded
    bucket interpolates up to 10x the last edge)."""
    total = sum(counts)
    if total == 0:
        return None
    target = q * total
    lo, cum = 0.0, 0
    bounds = list(edges_s) + [edges_s[-1] * 10]
    for hi, c in zip(bounds, counts):
        if c and cum + c >= target:
            return lo + (hi - lo) * (target - cum) / c
        cum += c
        lo = hi
    return bounds[-1]


def _percentiles(stats_json):
    hist = stats_json["request_latency_hist"]
    edges = hist["edges_s"]
    out = {}
    for phase in PHASES:
        counts = hist["counts"][phase]
        for q in (0.50, 0.95):
            v = hist_quantile(edges, counts, q)
            out[f"{phase}_p{int(q * 100)}_ms"] = \
                None if v is None else round(1e3 * v, 4)
        out[f"{phase}_n"] = sum(counts)
    return out


def _assert_paging(out):
    serve = out["runtime_stats"]["serve"]
    cache = out["cache"]
    if serve.get("refills", 0) != serve.get("page_hits", 0):
        raise AssertionError(f"refill accounting broke: "
                             f"{serve.get('page_hits', 0)} page hits != "
                             f"{serve.get('refills', 0)} refills")
    if serve.get("prefill_recompute", 0) != 0:
        raise AssertionError("prefill recompute fallback ran "
                             f"{serve['prefill_recompute']}x")
    if cache["pages_live"] != 0 or cache["cache_entries"] != 0:
        raise AssertionError(f"pages leaked: {cache}")


def run_cells(*, requests: int, slots: int, prompt_len: int, gen_len: int
              ) -> list[dict]:
    plan = Plan(arch="qwen2.5-3b", tiny=True, seed=0)
    results = []

    with plan.compile() as session:
        wave = session.serve(requests=requests, slots=slots,
                             prompt_len=prompt_len, gen_len=gen_len,
                             verbose=False)
    results.append({"cell": "wave", "tokens": wave["tokens"],
                    "padded_tokens": wave["padded_tokens"],
                    "tokens_per_s": round(wave["tokens_per_s"], 2)})

    # staggered arrivals land a new request every other decode round;
    # the x2 cell runs the same staggered load across 2 replicas
    mid_trace = [{"at_round": 2 * (i // slots)} for i in range(requests)]
    stream_cells = [
        ("stream", [{"at_round": 0} for _ in range(requests)], 1),
        ("stream-mid", mid_trace, 1),
        ("stream-x2", mid_trace, 2),
    ]
    for name, trace, n_replicas in stream_cells:
        with plan.compile() as session:
            out = session.serve_stream(trace=trace, prompt_len=prompt_len,
                                       gen_len=gen_len, slots=slots,
                                       replicas=n_replicas, verbose=False)
        _assert_paging(out)
        serve = out["runtime_stats"]["serve"]
        if serve.get("cross_replica_page_fetches", 0) != 0:
            raise AssertionError(
                f"{name}: steady state crossed replica page boundaries "
                f"{serve['cross_replica_page_fetches']}x")
        cell = {"cell": name, "replicas": n_replicas,
                "tokens": out["tokens"],
                "padded_tokens": out["padded_tokens"],
                "tokens_per_s": round(out["tokens_per_s"], 2),
                "epochs": out["epochs"], "rounds": out["rounds"],
                "admission": {
                    "submitted": out["requests"],
                    "admitted": serve.get("admitted", 0),
                    "completed": out["completed"],
                    "cancelled": out["cancelled"],
                    "expired": out["expired"],
                    "failed": out["failed"],
                    "rejected": out["rejected"]},
                "cache": out["cache"]}
        cell.update(_percentiles(out["runtime_stats"]))
        results.append(cell)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes (6 requests, 2 slots, gen 4)")
    ap.add_argument("--out", default=str(Path(__file__).resolve()
                                         .parent.parent
                                         / "BENCH_serve_latency.json"))
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.slots = 6, 2
        args.prompt_len, args.gen_len = 16, 4

    results = run_cells(requests=args.requests, slots=args.slots,
                        prompt_len=args.prompt_len, gen_len=args.gen_len)
    print(f"{'cell':>10s} {'tok/s':>8s} {'tok p50ms':>10s} "
          f"{'tok p95ms':>10s} {'e2e p95ms':>10s} {'done':>5s}")
    for r in results:
        if r["cell"] == "wave":
            print(f"{r['cell']:>10s} {r['tokens_per_s']:8.1f} "
                  f"{'-':>10s} {'-':>10s} {'-':>10s} {'-':>5s}")
        else:
            print(f"{r['cell']:>10s} {r['tokens_per_s']:8.1f} "
                  f"{r['decode_token_p50_ms']:10.2f} "
                  f"{r['decode_token_p95_ms']:10.2f} "
                  f"{r['total_p95_ms']:10.2f} "
                  f"{r['admission']['completed']:5d}", flush=True)

    doc = {"bench": "serve_latency", "version": VERSION,
           "arch": "qwen2.5-3b", "tiny": True,
           "requests": args.requests, "slots": args.slots,
           "prompt_len": args.prompt_len, "gen_len": args.gen_len,
           "smoke": bool(args.smoke), "results": results}
    Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
