"""§Perf hillclimb driver: run tagged dry-run variants for the three chosen
cells and print before/after roofline terms.

Cells (chosen per the spec's three criteria):
  A. chameleon-34b x train_4k   - most representative of the paper's
     technique (DP+TP training of the largest model); baseline does not fit
     HBM.
  B. granite-moe-1b x decode_32k - the most collective-bound cell.
  C. xlstm-350m x train_4k       - worst train-shape roofline fraction.

Each variant is one hypothesis->change->measure iteration; EXPERIMENTS.md
§Perf narrates them with the numbers this script records.

  PYTHONPATH=src python -m benchmarks.perf_iterations [--mesh single]
"""
from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS=512 first)

import argparse
from pathlib import Path

RUNS = [
    # (arch, shape, kwargs, tag)
    ("chameleon-34b", "train_4k", {}, ""),  # baseline (cached)
    ("chameleon-34b", "train_4k", {"strategy_name": "zero1"}, "zero1"),
    ("chameleon-34b", "train_4k", {"seq_parallel": True}, "sp"),
    ("chameleon-34b", "train_4k",
     {"strategy_name": "zero1", "seq_parallel": True}, "zero1_sp"),
    ("granite-moe-1b-a400m", "decode_32k", {}, ""),
    ("granite-moe-1b-a400m", "decode_32k", {"moe_dispatch": "sort"},
     "sortdisp"),
    ("granite-moe-1b-a400m", "decode_32k",
     {"overrides": {"cache_update": "masked"}}, "maskedcache"),
    ("granite-moe-1b-a400m", "decode_32k",
     {"moe_dispatch": "sort", "overrides": {"cache_update": "masked"}},
     "sort_masked"),
    ("xlstm-350m", "train_4k", {}, ""),
    ("xlstm-350m", "train_4k", {"overrides": {"ssm_chunk": 512}}, "chunk512"),
    ("xlstm-350m", "train_4k", {"overrides": {"ssm_chunk": 128}}, "chunk128"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out = Path(args.out)
    print(f"{'cell':44s} {'tag':12s} {'tc':>10s} {'tm':>10s} {'tx':>10s} "
          f"{'dom':>10s} {'peakGB':>7s} {'fit':>5s}")
    for arch, shape, kw, tag in RUNS:
        rec = dryrun.run_cell(arch, shape, args.mesh,
                              kw.get("strategy_name", "phylanx"), out,
                              tag=tag, force=args.force and bool(tag),
                              seq_parallel=kw.get("seq_parallel", False),
                              moe_dispatch=kw.get("moe_dispatch", ""),
                              overrides=kw.get("overrides"))
        if rec["status"] != "ok":
            print(f"{arch + 'x' + shape:44s} {tag or 'BASE':12s} "
                  f"{rec['status']}: {rec.get('error', '')[:80]}")
            continue
        rr = rec["roofline"]
        print(f"{arch + ' x ' + shape:44s} {tag or 'BASE':12s} "
              f"{rr['t_compute_s']:10.3e} {rr['t_memory_s']:10.3e} "
              f"{rr['t_collective_s']:10.3e} {rr['dominant']:>10s} "
              f"{rec['memory'].get('peak_bytes_est', 0) / 1e9:7.1f} "
              f"{str(rec['fits_hbm']):>5s}", flush=True)


if __name__ == "__main__":
    main()
