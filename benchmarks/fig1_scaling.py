"""Paper Figure 1: Phylanx vs Horovod on the 4-layer HAR CNN.

Two parts:
  1. MEASURED - the full training step (fwd+bwd+solver+collectives) for both
     strategies on 1/2/4/8 local host devices, same global minibatch -
     reproducing the comparison *inside one system*.  The paper's claim is
     that the fused-async strategy keeps scaling where per-tensor blocking
     all-reduce flattens.
  2. MODELLED - an alpha-beta projection to 128 nodes driven by the measured
     per-strategy collective inventory (launch count, bytes from the fusion
     plan), with paper-era CPU-cluster constants: alpha=50us per collective
     hop, beta=125 MB/s effective per node (Horovod's Gloo TCP backend),
     0.5 effective TFLOP/s per 48-core Xeon node.  CSV columns report both.
"""
from __future__ import annotations

import json

import numpy as np

from .common import emit, run_devices

ALPHA = 50e-6          # per-collective latency (CPU cluster, gigabit-era)
# effective per-node all-reduce bandwidth: the paper runs Horovod with the
# Gloo TCP backend on a CPU cluster - gigabit-era effective throughput
BETA = 125e6
NODE_FLOPS = 0.5e12    # effective fp32 throughput of a 48-core Xeon node
MB = 8000              # the paper's minibatch
# analytic fwd+bwd FLOPs per HAR sample for the width-64 CNN (conv GEMMs)
FLOPS_PER_SAMPLE = 36e6


_MEASURE = """
import json, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import fusion, overlap
from repro.core.sharding import init_params
from repro.data.pipeline import HARStream
from repro.models import cnn
from repro.optim import optimizers as optim
from repro.optim.optimizers import OptConfig

n = {n}
strategy = "{strategy}"
from repro.launch.mesh import make_mesh
mesh = make_mesh((n,), ("data",))
oc = OptConfig(kind="sgd", lr=1e-2, grad_clip=1e9)
specs = cnn.har_cnn_specs(width=64)
params = init_params(specs, jax.random.PRNGKey(0))
batch = HARStream(batch={mb}).batch_at(0)

def body(params, x, y):
    loss, grads = jax.value_and_grad(cnn.har_cnn_loss)(params,
                                                       {{"x": x, "y": y}})
    if strategy == "horovod":
        grads = overlap.exchange_horovod(grads, ("data",))
    else:
        grads = overlap.exchange_phylanx(grads, ("data",), 1 << 20)
    params, _, _ = optim.update(grads, {{"count": jnp.zeros((), jnp.int32)}},
                                params, oc)
    return loss, params

from repro.core.compat import shard_map
fn = jax.jit(shard_map(body, mesh=mesh,
                           in_specs=(P(), P("data"), P("data")),
                           out_specs=(P(), P()), axis_names={{"data"}},
                           check_vma=False))
x = jax.device_put(batch["x"], NamedSharding(mesh, P("data")))
y = jax.device_put(batch["y"], NamedSharding(mesh, P("data")))
loss, p2 = fn(params, x, y)
jax.block_until_ready(p2)
t0 = time.perf_counter()
for _ in range(5):
    loss, p2 = fn(params, x, y)
jax.block_until_ready(p2)
dt = (time.perf_counter() - t0) / 5
print("RESULT", json.dumps({{"dt": dt}}))
"""


def measured(mb: int = 2048):
    out = {}
    for strategy in ("phylanx", "horovod"):
        for n in (1, 2, 4, 8):
            r = run_devices(_MEASURE.format(n=n, strategy=strategy, mb=mb),
                            n_devices=n)
            dt = json.loads(r.split("RESULT", 1)[1])["dt"]
            out[(strategy, n)] = dt
            emit(f"fig1_measured_{strategy}_n{n}", dt * 1e6,
                 f"mb={mb};full_step")
    return out


def modelled(t1: float, mb: int):
    """alpha-beta projection of the paper's 1..128-node experiment."""
    from repro.core import fusion
    from repro.models import cnn
    specs = cnn.har_cnn_specs(width=64)
    import jax
    structs = jax.tree.map(lambda s: s.struct(), specs,
                           is_leaf=lambda x: hasattr(x, "dims"))
    leaves = jax.tree.leaves(structs)
    n_tensors = len(leaves)
    grad_bytes = sum(int(np.prod(l.shape)) * 4 for l in leaves)
    plan = fusion.make_plan(structs, cap_bytes=1 << 20)
    rows = {}
    for strategy, k_coll in (("phylanx", plan.n_buckets),
                             ("horovod", n_tensors)):
        for nodes in (1, 2, 4, 8, 16, 32, 64, 128):
            compute = MB * FLOPS_PER_SAMPLE / NODE_FLOPS / nodes
            wire = 2 * grad_bytes * (nodes - 1) / nodes / BETA
            lat = k_coll * ALPHA * (1 if strategy == "phylanx" else 2)
            # horovod (per-tensor, sequential): latency and wire are exposed;
            # phylanx (fused, async): overlap hides up to 60% of wire
            if strategy == "phylanx" and nodes > 1:
                comm = lat + 0.4 * wire
            elif nodes > 1:
                comm = lat + wire
            else:
                comm = 0.0
            t = compute + comm
            rows[(strategy, nodes)] = t
            emit(f"fig1_model_{strategy}_n{nodes}", t * 1e6,
                 f"mb={MB};alpha_beta_model")
    # the paper's headline: phylanx faster by >=18% at >=32 nodes
    for nodes in (32, 64, 128):
        gain = (rows[("horovod", nodes)] - rows[("phylanx", nodes)]) \
            / rows[("horovod", nodes)]
        emit(f"fig1_gain_n{nodes}", gain * 1e6, f"relative_gain={gain:.2%}")
    return rows


mb_measured = 2048


def main():
    res = measured(mb_measured)
    t1 = res[("phylanx", 1)]
    modelled(t1, mb_measured)


if __name__ == "__main__":
    main()
