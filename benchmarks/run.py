"""Benchmark harness entry point (deliverable d).

One section per paper table/figure, printing ``name,us_per_call,derived``
CSV lines:
  * fig1_*    - Figure 1 (Phylanx vs Horovod, 4-layer HAR CNN): measured on
                1..8 local devices + alpha-beta projection to 128 nodes
  * table1_*  - Table 1 as measured strategy/feature matrix
  * kernel_*  - Pallas kernel oracles + tile models
  * roofline_* - per (arch x shape x mesh) dry-run roofline terms

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig1|table1|kernels|roofline]
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    sections = []
    if not args.only or args.only == "fig1":
        from . import fig1_scaling
        sections.append(("fig1", fig1_scaling.main))
    if not args.only or args.only == "table1":
        from . import table1_features
        sections.append(("table1", table1_features.main))
    if not args.only or args.only == "kernels":
        from . import kernels_bench
        sections.append(("kernels", kernels_bench.main))
    if not args.only or args.only == "roofline":
        from . import roofline
        sections.append(("roofline", roofline.main))
    failed = []
    for name, fn in sections:
        try:
            fn()
        except Exception as e:  # keep the harness going; report at the end
            traceback.print_exc()
            failed.append((name, str(e)))
    if failed:
        print(f"FAILED sections: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
