"""Pallas kernel micro-benchmarks vs jnp oracles.

On CPU the kernels run in interpret mode (Python evaluation), so wall time
is NOT meaningful for the kernel path - the honest derived metric here is
oracle wall time plus the kernel's modelled VMEM working set / arithmetic
intensity, which is what the TPU roofline cares about.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import emit, timeit


def main():
    from repro.kernels import ref
    # flash attention oracle timings + kernel tile model
    for (B, H, Hkv, S, d) in [(1, 8, 2, 1024, 128), (1, 16, 8, 2048, 64)]:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, S, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (B, Hkv, S, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (B, Hkv, S, d), jnp.bfloat16)
        f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
        dt = timeit(f, q, k, v)
        bq, bk = 128, 128
        vmem = (bq * d + 2 * bk * d + bq * bk) * 4
        flops = 4 * B * H * S * S * d / 2  # causal triangle
        emit(f"kernel_flash_oracle_B{B}H{H}S{S}d{d}", dt * 1e6,
             f"tile_vmem_bytes={vmem};causal_tflops={flops / 1e12:.3f}")

    # mamba2 chunk scan
    for (B, H, L, P, N, c) in [(1, 8, 2048, 64, 64, 128)]:
        ks = jax.random.split(jax.random.PRNGKey(1), 4)
        xdt = jax.random.normal(ks[0], (B, H, L, P)) * 0.5
        a = -jnp.abs(jax.random.normal(ks[1], (B, H, L))) * 0.1
        Bm = jax.random.normal(ks[2], (B, H, L, N)) * 0.5
        Cm = jax.random.normal(ks[3], (B, H, L, N)) * 0.5
        f = jax.jit(lambda *t: ref.mamba2_scan_ref(*t)[0])
        dt = timeit(f, xdt, a, Bm, Cm)
        vmem = (3 * c * N + 2 * c * P + c * c + P * N) * 4
        emit(f"kernel_mamba2_oracle_L{L}P{P}N{N}", dt * 1e6,
             f"chunk={c};tile_vmem_bytes={vmem}")

    # onebit pack/unpack
    g = jax.random.normal(jax.random.PRNGKey(2), (4096, 1024))
    e = jnp.zeros_like(g)
    f = jax.jit(lambda g, e: ref.onebit_quantize_ref(g, e)[2])
    dt = timeit(f, g, e)
    ratio = g.size * 4 / (g.size // 32 * 4 + g.shape[0] * 4)
    emit("kernel_onebit_oracle_4Mx", dt * 1e6,
         f"wire_compression={ratio:.1f}x")


if __name__ == "__main__":
    main()
