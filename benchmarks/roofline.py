"""Roofline aggregation (deliverable g): read every dry-run artifact and
emit the per-(arch x shape x mesh) three-term table + dominant bottleneck.

Also writes artifacts/roofline.csv and artifacts/roofline.md (the table
embedded in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def load(mesh: str, tag: str = ""):
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        r = json.load(open(f))
        if r.get("tag", "") != tag:
            continue
        rows.append(r)
    return rows


def fix_note(r) -> str:
    rr = r["roofline"]
    dom = rr["dominant"]
    if dom == "memory":
        return ("shard activation checkpoints (SP) / raise arithmetic "
                "intensity (fused kernels)")
    if dom == "collective":
        return "fewer/larger collectives: SP reduce-scatter, EP all-to-all layout"
    return "compute-bound: increase per-chip batch or accept"


def main(emit_csv: bool = True):
    md = ["| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
          "dominant | 6ND/HLO | roofline frac | fits |",
          "|---|---|---|---|---|---|---|---|---|---|"]
    csv_rows = ["arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
                "dominant,useful_flops_ratio,roofline_fraction,fits_hbm"]
    for mesh in ("single", "multipod"):
        for r in load(mesh):
            if r["status"] == "skipped":
                md.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | - |"
                          f" - | skipped | - | - | - |")
                continue
            if r["status"] != "ok":
                continue
            rr = r["roofline"]
            name = f"roofline_{mesh}_{r['arch']}_{r['shape']}"
            emit(name, rr["bound_step_s"] * 1e6,
                 f"dom={rr['dominant']};frac={rr['roofline_fraction']:.3f};"
                 f"fits={r['fits_hbm']}")
            md.append(
                f"| {r['arch']} | {r['shape']} | {mesh} "
                f"| {rr['t_compute_s']:.3e} | {rr['t_memory_s']:.3e} "
                f"| {rr['t_collective_s']:.3e} | {rr['dominant']} "
                f"| {rr['useful_flops_ratio']:.2f} "
                f"| {rr['roofline_fraction'] * 100:.1f}% "
                f"| {'Y' if r['fits_hbm'] else 'N'} |")
            csv_rows.append(
                f"{r['arch']},{r['shape']},{mesh},{rr['t_compute_s']:.6e},"
                f"{rr['t_memory_s']:.6e},{rr['t_collective_s']:.6e},"
                f"{rr['dominant']},{rr['useful_flops_ratio']:.4f},"
                f"{rr['roofline_fraction']:.4f},{r['fits_hbm']}")
    if emit_csv:
        os.makedirs(OUT, exist_ok=True)
        with open(os.path.join(OUT, "roofline.md"), "w") as f:
            f.write("\n".join(md) + "\n")
        with open(os.path.join(OUT, "roofline.csv"), "w") as f:
            f.write("\n".join(csv_rows) + "\n")


if __name__ == "__main__":
    main()
