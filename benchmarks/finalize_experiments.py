"""Regenerate the tables embedded in EXPERIMENTS.md from artifacts.

  PYTHONPATH=src python -m benchmarks.finalize_experiments
"""
import glob
import io
import json
import os
import re
from contextlib import redirect_stdout

ROOT = os.path.join(os.path.dirname(__file__), "..")


def opt_table() -> str:
    rows = ["| arch | shape | baseline bound (s) | opt bound (s) | gain | "
            "baseline dom | opt dom |",
            "|---|---|---|---|---|---|---|"]
    for f in sorted(glob.glob(os.path.join(
            ROOT, "artifacts/dryrun/single/*__opt.json"))):
        o = json.load(open(f))
        if o.get("status") != "ok":
            continue
        base_f = f.replace("__opt.json", ".json")
        if not os.path.exists(base_f):
            continue
        b = json.load(open(base_f))
        if b.get("status") != "ok":
            continue
        br, orr = b["roofline"], o["roofline"]
        gain = br["bound_step_s"] / orr["bound_step_s"]
        rows.append(f"| {o['arch']} | {o['shape']} "
                    f"| {br['bound_step_s']:.3e} | {orr['bound_step_s']:.3e} "
                    f"| {gain:.2f}x | {br['dominant']} | {orr['dominant']} |")
    return "\n".join(rows)


def main():
    from . import roofline
    buf = io.StringIO()
    with redirect_stdout(buf):
        roofline.main(emit_csv=True)
    table = open(os.path.join(ROOT, "artifacts/roofline.md")).read()

    exp_path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(exp_path).read()
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n\nReading of the baseline table)",
        "<!-- ROOFLINE_TABLE -->\n\n" + table, text, flags=re.S)
    ot = opt_table()
    text = re.sub(r"<!-- OPT_TABLE -->.*?(?=\n\n## Reproduction commands)",
                  "<!-- OPT_TABLE -->\n\n" + ot, text, flags=re.S)
    open(exp_path, "w").write(text)
    print("EXPERIMENTS.md tables regenerated "
          f"({table.count(chr(10))} roofline rows, {ot.count(chr(10)) - 1} opt rows)")


if __name__ == "__main__":
    main()
