"""Forensics for one dry-run cell: top collectives and byte contributors
with shapes + loop multipliers - the 'profile' of the dry-run methodology.

  PYTHONPATH=src python -m benchmarks.analyze_cell --arch X --shape Y [opts]
"""
from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS=512 first)

import argparse

from repro.configs import get_config
from repro.core import hlo_costs, steps as steps_lib
from repro.launch.mesh import make_production_mesh, mesh_devices


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--strategy", default="phylanx")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--moe-dispatch", default="")
    ap.add_argument("--override", action="append", default=[])
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--dump", default="")
    args = ap.parse_args()

    import dataclasses
    cfg = get_config(args.arch)
    if args.moe_dispatch:
        cfg = dataclasses.replace(cfg, moe_dispatch=args.moe_dispatch)
    for ov in args.override:
        k, v = ov.split("=")
        cur = getattr(cfg, k)
        cfg = dataclasses.replace(
            cfg, **{k: type(cur)(v) if cur is not None else v})
    mesh = make_production_mesh()
    n_dev = mesh_devices(mesh)
    strategy = steps_lib.Strategy(name=args.strategy,
                                  sequence_parallel=args.seq_parallel)
    step, lowered, compiled, tl, tc = dryrun.lower_cell(
        cfg, mesh, args.shape, strategy)
    txt = compiled.as_text()
    if args.dump:
        open(args.dump, "w").write(txt)
        print(f"dumped HLO to {args.dump}")

    comps, entry = hlo_costs.parse_module(txt)
    mult, fusion_comps = hlo_costs._multipliers(comps, entry)

    colls, bytes_rows = [], []
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        table = {i.name: i.shape_str for i in instrs}
        in_fusion = cname in fusion_comps
        for ins in instrs:
            op = ins.opcode.removesuffix("-start")
            if op in ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute"):
                s = ins.result_bytes()
                g = hlo_costs._group_size(ins.attrs, n_dev)
                if s and g > 1:
                    w = {"all-reduce": 2 * s * (g - 1) / g,
                         "all-gather": s * (g - 1) / g,
                         "reduce-scatter": s * (g - 1),
                         "all-to-all": s * (g - 1) / g,
                         "collective-permute": s}[op]
                    colls.append((m * w, m, op, g, ins.shape_str[:70],
                                  cname[:34]))
            if not in_fusion and ins.opcode not in hlo_costs._SKIP_BYTES \
                    and not ins.opcode.endswith("-done"):
                b = hlo_costs._instr_bytes(ins, table, comps)
                bytes_rows.append((m * b, m, ins.opcode,
                                   ins.shape_str[:60], cname[:34]))

    print(f"\n=== top collectives by wire bytes "
          f"(total {sum(c[0] for c in colls) / 1e9:.2f} GB/dev) ===")
    for w, m, op, g, shape, comp in sorted(colls, reverse=True)[:args.top]:
        print(f"{w / 1e9:9.3f}GB x{m:6.0f} g={g:4d} {op:18s} {shape}  [{comp}]")

    print(f"\n=== top HBM-byte contributors "
          f"(total {sum(b[0] for b in bytes_rows) / 1e12:.2f} TB/dev) ===")
    for b, m, op, shape, comp in sorted(bytes_rows, reverse=True)[:args.top]:
        print(f"{b / 1e9:9.2f}GB x{m:6.0f} {op:26s} {shape}  [{comp}]")


if __name__ == "__main__":
    main()
