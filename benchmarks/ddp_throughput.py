"""DDP throughput: steps/s and gradient wire bytes, 1 vs 2 localities,
fp32 vs onebit (DESIGN.md §11).

Each cell is one ``warmup + timed``-step run; an ``on_step`` hook
timestamps every step on the driver, the first ``warmup`` deltas
(compile, ring warm-up) are discarded, and the cell reports the MEDIAN
steady-state step time - robust to scheduler noise, no subtraction of
separately-launched runs needed.

The wire numbers are not estimates: ``grad_wire_bytes`` is the driver's
exact payload-byte counter and is re-asserted here against
``steps * (localities - 1) * codec_bytes`` - the benchmark doubles as
the accounting check outside pytest.

Writes the versioned ``BENCH_ddp_throughput.json`` (repo root; commit
it when regenerating on a reference machine):

  PYTHONPATH=src python -m benchmarks.ddp_throughput            # full
  PYTHONPATH=src python -m benchmarks.ddp_throughput --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.frontend.plan import Plan

VERSION = 1
CELLS = [(1, "fp32"), (1, "onebit"), (2, "fp32"), (2, "onebit")]


def run_cell(localities: int, codec: str, *, warmup: int, timed: int,
             batch: int = 4, seq: int = 16) -> dict:
    plan = Plan(arch="qwen2.5-3b", tiny=True, batch=batch, seq=seq,
                ddp=True, ddp_shards=2, grad_codec=codec,
                localities=localities, seed=0)

    class Stamps:
        times: list = []

        def on_step(self, it, metrics):
            Stamps.times.append(time.perf_counter())

    with plan.compile() as session:
        out = session.train(steps=warmup + timed, hooks=Stamps(),
                            log_every=warmup + timed, verbose=False)
    deltas = sorted(b - a for a, b in zip(Stamps.times[warmup:],
                                          Stamps.times[warmup + 1:]))
    dt = max(deltas[len(deltas) // 2], 1e-6)          # median, steady state
    per_step = (localities - 1) * out["codec_bytes"]
    expect = (warmup + timed) * per_step
    if out["grad_wire_bytes"] != expect:
        raise AssertionError(
            f"wire accounting broke: counted {out['grad_wire_bytes']}B, "
            f"expected {expect}B")
    return {"localities": localities, "codec": codec,
            "steps_per_s": round(1.0 / dt, 3),
            "step_ms": round(1e3 * dt, 3),
            "codec_bytes_per_exchange": out["codec_bytes"],
            "wire_bytes_per_step": per_step,
            "grad_wire_bytes": out["grad_wire_bytes"],
            "final_loss": round(float(out["final_loss"]), 6)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--warmup", type=int, default=5)
    ap.add_argument("--timed", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes (2 warmup / 6 timed steps)")
    ap.add_argument("--out", default=str(Path(__file__).resolve()
                                         .parent.parent
                                         / "BENCH_ddp_throughput.json"))
    args = ap.parse_args()
    warmup, timed = (2, 6) if args.smoke else (args.warmup, args.timed)
    results = []
    print(f"{'W':>2s} {'codec':>7s} {'steps/s':>9s} {'ms/step':>9s} "
          f"{'wire B/step':>12s} {'final loss':>11s}")
    for localities, codec in CELLS:
        r = run_cell(localities, codec, warmup=warmup, timed=timed)
        results.append(r)
        print(f"{r['localities']:2d} {r['codec']:>7s} "
              f"{r['steps_per_s']:9.2f} {r['step_ms']:9.2f} "
              f"{r['wire_bytes_per_step']:12d} {r['final_loss']:11.4f}",
              flush=True)
    fp32 = next(r for r in results if r["localities"] == 2
                and r["codec"] == "fp32")
    onebit = next(r for r in results if r["localities"] == 2
                  and r["codec"] == "onebit")
    ratio = onebit["wire_bytes_per_step"] / fp32["wire_bytes_per_step"]
    print(f"onebit wire = 1/{1 / ratio:.1f} of fp32")
    doc = {"bench": "ddp_throughput", "version": VERSION,
           "arch": "qwen2.5-3b", "tiny": True, "batch": 4, "seq": 16,
           "ddp_shards": 2, "warmup_steps": warmup, "timed_steps": timed,
           "smoke": bool(args.smoke), "onebit_wire_ratio": round(ratio, 5),
           "results": results}
    Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
