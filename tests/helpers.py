"""Subprocess runner for multi-device tests (host platform devices are
fixed at first jax init, so anything needing >1 device runs in a child)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run `code` in a subprocess with n host devices; returns stdout.
    Raises on nonzero exit (stderr in the message)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if p.returncode != 0:
        raise RuntimeError(f"subprocess failed:\nSTDOUT:\n{p.stdout}\n"
                           f"STDERR:\n{p.stderr[-4000:]}")
    return p.stdout
