"""phylint (DESIGN.md §12): static rule catalogue over seeded defects,
dryrun-builder parity with real traced sessions, and the runtime
concurrency sanitizer (deadlock watchdog, protocol checks, AGAS audit)."""
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.analysis import (DeadlockError, LintGraph, plan_traces,
                            sanitize, serve_trace, step_contract,
                            train_trace)
from repro.analysis import lint as lint_mod
from repro.core.futures import FuturizedGraph
from repro.frontend import Plan, tracing

ARCH = "qwen2.5-3b"


@pytest.fixture(autouse=True)
def _clean_sanitizer():
    sanitize.get().clear()
    yield
    sanitize.get().clear()


def _rules(graph, **kw):
    return [f.rule for f in lint_mod.lint(graph, **kw)]


# -- static rules over seeded defects ----------------------------------------

def test_rule_catalogue_ids_are_stable():
    assert sorted(lint_mod.STATIC_RULES) == [
        "PHY001", "PHY002", "PHY003", "PHY004", "PHY005", "PHY006"]
    assert sorted(sanitize.DYNAMIC_RULES) == [
        "PHY101", "PHY102", "PHY103", "PHY104", "PHY105",
        "PHY106", "PHY107"]


def test_seeded_cycle_is_exactly_phy001():
    g = LintGraph(label="cyc")
    a = g.add("a")
    b = g.add("b", deps=[a])
    g.nodes[a].deps = (b,)                      # plant the back edge
    g.mark_forced(b)
    found = lint_mod.lint(g)
    assert [f.rule for f in found] == ["PHY001"]
    assert set(found[0].nodes) == {"a", "b"}


def test_seeded_orphan_promise_is_exactly_phy002():
    g = LintGraph(label="orph")
    p = g.add("entry", kind="promise")           # no producer registered
    g.mark_forced(g.add("consumer", deps=[p]))
    assert _rules(g) == ["PHY002"]
    # a promise with a committed producer is legitimate
    g2 = LintGraph(label="ok")
    p2 = g2.add("entry", kind="promise", producer="L1")
    g2.mark_forced(g2.add("consumer", deps=[p2]))
    assert _rules(g2) == []


def test_seeded_lane_inversion_is_exactly_phy003():
    g = LintGraph(label="inv")
    s = g.add("ckpt:shard", lane="CHECKPOINT")
    g.mark_forced(g.add("step", lane="COMPUTE", deps=[s]))
    assert _rules(g) == ["PHY003"]


def test_prefetch_feed_edge_exempt_unless_strict():
    g = LintGraph(label="feed")
    pf = g.add("prefetch:0", lane="PREFETCH")
    g.mark_forced(g.add("step:0", lane="COMPUTE", deps=[pf]))
    assert _rules(g) == []
    assert _rules(g, strict_lanes=True) == ["PHY003"]


def test_dead_node_is_phy004_only_with_forced_info():
    g = LintGraph(label="dead")
    g.add("unused")
    g.add("kept", forced=True)
    assert _rules(g) == ["PHY004"]               # add(forced=...) set the flag
    g2 = LintGraph(label="noinfo")
    g2.add("unused")
    assert _rules(g2) == []                      # no liveness info: no verdict
    # cancelled sinks (prefetch lookahead) are not dead
    g3 = LintGraph(label="cancelled")
    g3.add("prefetch:6", lane="PREFETCH", cancelled=True)
    g3.mark_forced(g3.add("kept"))
    assert _rules(g3) == []


def test_seeded_donation_after_use_is_exactly_phy005():
    g = LintGraph(label="don")
    g.add("step:0", kind="device", uses=("params@0", "batch@0"),
          donates=("params@0",))
    g.add("capture:late", kind="device", uses=("params@0",))
    found = lint_mod.lint(g)
    assert [f.rule for f in found] == ["PHY005"]
    assert "params@0" in found[0].message


def test_fanin_hotspot_is_phy006():
    g = LintGraph(label="fan")
    deps = [g.add(f"shard{i}") for i in range(70)]
    g.mark_forced(g.add("manifest", deps=deps))
    assert _rules(g) == ["PHY006"]
    assert _rules(g, fanin_threshold=128) == []


# -- shipped configs lint clean ----------------------------------------------

def test_every_shipped_config_dryrun_lints_clean():
    from repro.configs import ARCH_IDS
    variants = [{}, {"ddp": True, "localities": 2},
                {"spmd": True, "localities": 2}]
    graphs = 0
    for aid in ARCH_IDS:
        for extra in variants:
            for name, g in plan_traces(Plan(arch=aid, tiny=True,
                                            **extra)).items():
                graphs += 1
                assert _rules(g) == [], (aid, extra, name)
    assert graphs >= 3 * len(ARCH_IDS)


def test_phylint_cli_strict_is_clean_and_lists_rules():
    root = Path(__file__).resolve().parent.parent
    out = subprocess.run(
        [sys.executable, str(root / "tools" / "phylint.py"),
         "--arch", ARCH, "--strict"],
        capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "clean" in out.stdout
    rules = subprocess.run(
        [sys.executable, str(root / "tools" / "phylint.py"), "--list-rules"],
        capture_output=True, text=True, timeout=120)
    assert "PHY001" in rules.stdout and "PHY105" in rules.stdout
    assert "PHY106" in rules.stdout and "PHY107" in rules.stdout


def test_multi_locality_standard_train_trace_refuses():
    with pytest.raises(ValueError, match="from_trace"):
        train_trace(Plan(arch=ARCH, localities=2))


def test_step_contract_declares_real_donation_sets():
    from repro.core import steps as steps_lib
    assert steps_lib.TrainStep.donated_buffers == ("params", "opt")
    assert steps_lib.DDPStep.donated_buffers == ("params", "opt")
    assert steps_lib.ServeStep.donated_buffers == ("cache",)
    for ddp in (False, True):
        g = step_contract(Plan(arch=ARCH, ddp=ddp,
                               localities=2 if ddp else 1))
        assert _rules(g) == []


# -- builder parity with a real traced session -------------------------------

def _shape_set(nodes, name_of):
    """{(name, lane, dep-names)} with the timing-dependent ckpt chain edge
    (gate -> previous manifest, present only when the previous save is
    still in flight) normalized away."""
    out = set()
    for n in nodes:
        deps = tuple(name_of(d) for d in n.deps)
        if n.name.startswith("ckpt:gate:"):
            deps = tuple(d for d in deps if not d.startswith("ckpt:manifest:"))
        out.add((n.name, n.lane, deps))
    return out


def test_builders_mirror_traced_session_and_live_graph_lints_clean(tmp_path):
    plan = Plan(arch=ARCH, batch=4, seq=16)
    with plan.compile() as session:
        with tracing(graph=session.runtime) as tr:
            session.train(steps=6, ckpt_dir=str(tmp_path), ckpt_every=2,
                          log_every=2, verbose=False)
        real = _shape_set(tr.nodes, lambda d: tr.nodes[d].name)
        built_g = train_trace(plan, steps=6, ckpt_every=2, log_every=2)
        built = _shape_set(built_g.nodes, lambda d: built_g.nodes[d].name)
        assert real == built

        # the trace-derived graph and the live runtime graph both lint clean
        assert _rules(LintGraph.from_trace(tr)) == []
        assert [f.rule for f in session.lint()] == []

        out = session.serve(requests=4, slots=2, prompt_len=16, gen_len=4,
                            verbose=False)
    sig = out["trace"]
    real_serve = {(n, lane, tuple(sig[d][0] for d in deps))
                  for n, lane, deps in sig}
    g = serve_trace(plan, requests=4, gen_len=4, slots=2)
    built_serve = {(n.name, n.lane, tuple(g.nodes[d].name for d in n.deps))
                   for n in g.nodes}
    assert real_serve == built_serve


# -- dynamic sanitizer -------------------------------------------------------

def test_sanitizer_env_activation(monkeypatch):
    monkeypatch.delenv("PHYRAX_SANITIZE", raising=False)
    assert not sanitize.active()
    monkeypatch.setenv("PHYRAX_SANITIZE", "1")
    assert sanitize.active()
    monkeypatch.setenv("PHYRAX_SANITIZE", "0")
    assert not sanitize.active()


def test_watchdog_raises_on_pool_exhaustion_deadlock():
    g = FuturizedGraph(max_workers=1, name="dl")
    try:
        with sanitize.enabled(deadlock_after=0.3, chunk=0.05):
            def outer():
                return g.defer(lambda: 42, name="inner").result(timeout=30)
            f = g.defer(outer, name="outer")
            with pytest.raises(DeadlockError, match="PHY101"):
                f.result(timeout=15)
        diags = sanitize.get().diagnostics("PHY101")
        assert diags and "inner" in diags[0].detail   # the dumped cycle
    finally:
        g.shutdown(wait=False)


def test_watchdog_raises_on_unproduced_promise_stall():
    g = FuturizedGraph(max_workers=2, name="stall")
    try:
        with sanitize.enabled(deadlock_after=0.2, orphan_after=0.5,
                              chunk=0.05):
            p = g.promise(name="never")          # nobody committed to it
            f = g.defer(lambda x: x, p, name="consumer")
            with pytest.raises(DeadlockError, match="promise"):
                f.result(timeout=15)
        p.set_result(None)                       # unwedge for shutdown
    finally:
        g.shutdown(wait=True)


def test_watchdog_trusts_producer_backed_promises():
    import threading
    g = FuturizedGraph(max_workers=2, name="prod")
    try:
        with sanitize.enabled(deadlock_after=0.2, orphan_after=0.5,
                              chunk=0.05):
            p = g.promise(name="ext", producer="L1")
            f = g.defer(lambda x: x + 1, p, name="consumer")
            threading.Timer(1.0, lambda: p.set_result(41)).start()
            assert f.result(timeout=15) == 42    # waited well past orphan_after
        assert sanitize.get().diagnostics() == []
    finally:
        g.shutdown(wait=True)


def test_unregistered_post_counted_and_warned_once(caplog):
    from repro.distrib.messaging import Endpoint
    a, b = Endpoint(0), Endpoint(1)
    try:
        a.connect(1, b.address)
        with sanitize.enabled():
            with caplog.at_level("WARNING", logger="repro.distrib"):
                a.post(1, "no_such_action", {"x": 1})
                a.post(1, "no_such_action", {"x": 2})
                deadline = time.monotonic() + 10
                while (b.unhandled_posts["no_such_action"] < 2
                       and time.monotonic() < deadline):
                    time.sleep(0.02)
        assert b.unhandled_posts["no_such_action"] == 2
        warned = [r for r in caplog.records if r.name == "repro.distrib"
                  and "no_such_action" in r.getMessage()]
        assert len(warned) == 1                  # warn once per action name
        diags = sanitize.get().diagnostics("PHY102")
        assert len(diags) == 1                   # coalesced by (rank, action)
    finally:
        a.close()
        b.close()


def test_agas_fetch_after_free_and_bad_free_are_phy105():
    from repro.distrib.agas import ObjectDirectory, RemoteRef
    d = ObjectDirectory(rank=0)
    ref = d.put({"w": 1}, summary="weights")
    assert d.fetch(ref) == {"w": 1}
    d.free(ref)
    with sanitize.enabled():
        with pytest.raises(KeyError):
            d.fetch(ref)
        d.free(RemoteRef(gid=(0, 999)))          # never registered
        kinds = [x.message for x in sanitize.get().diagnostics("PHY105")]
    assert any("fetch after free" in m for m in kinds)
    assert any("never-registered" in m for m in kinds)
    assert d.audit() == {"live": 0, "puts": 1, "local_fetches": 1,
                         "frees": 1, "migrated": 0, "forwarded_fetches": 0}


def test_double_spawn_same_tid_is_phy106():
    """Seeded steal-lease violation: the same tid lands on one locality
    twice (a lease raced a re-spawn past the driver's fencing) - the
    duplicate must be dropped and flagged, never run twice."""
    from repro.core.futures import Lane
    from repro.distrib.messaging import Endpoint
    from repro.distrib.runtime import Locality

    drv = Endpoint(0)
    drv.register("task_done", lambda src, msg: None)
    loc = Locality(7, world=2)
    try:
        loc.endpoint.connect(0, drv.address)
        payload = {"tid": "t0", "name": "dup", "lane": int(Lane.COMPUTE),
                   "pin": False, "gen": 0, "fn": sorted,
                   "args": ([3, 1, 2],), "kwargs": {}}
        with sanitize.enabled():
            loc._on_spawn(0, dict(payload))
            loc._on_spawn(0, dict(payload))      # the violation
            diags = sanitize.get().diagnostics("PHY106")
        assert len(diags) == 1 and "spawned here twice" in diags[0].message
    finally:
        loc.graph.shutdown(wait=True, cancel_pending=True)
        loc.endpoint.close()
        drv.close()


def test_stale_generation_steal_request_is_phy106():
    """Seeded membership-generation fence: a steal_request planned under
    a stale peer table is refused with ``stale`` (and the current
    generation to re-sync from), never handed a task."""
    from repro.distrib.runtime import DistributedGraph

    g = DistributedGraph(localities=1, elastic=True)
    try:
        g.group.gen = 3
        with sanitize.enabled():
            out = g._on_steal_request(5, {"thief": 5, "gen": 1})
            diags = sanitize.get().diagnostics("PHY106")
        assert out["stale"] and out["handed"] == 0 and out["gen"] == 3
        assert len(diags) == 1 and "stale membership generation" \
            in diags[0].message
    finally:
        g.shutdown()


def test_dead_forwarding_stub_deref_is_phy107():
    """Seeded dead-stub chase: a forwarding stub whose migrated target
    is gone (freed, or its locality died) must raise AND be flagged."""
    from repro.distrib.agas import ObjectDirectory, RemoteRef, _Forward

    d = ObjectDirectory(rank=0)
    ref = d.put({"w": 1}, summary="weights")
    # seed the defect: the value "migrated" but its new home is gone
    d._store[ref.gid[1]] = _Forward(ref=RemoteRef(gid=(0, 999)))
    with sanitize.enabled():
        with pytest.raises(KeyError):
            d.fetch(ref)
        diags = sanitize.get().diagnostics("PHY107")
    assert len(diags) == 1 and "forwarding stub" in diags[0].message
    assert d.audit()["forwarded_fetches"] == 1


def test_ring_generation_regression_is_phy103():
    import numpy as np

    from repro.core.fusion import make_plan
    from repro.distrib.collectives import RingAllReduce

    ring = RingAllReduce(None, world=1)
    plan = make_plan({"w": np.zeros((4, 4), np.float32)})
    with sanitize.enabled():
        ring.configure("fp32", plan, gen=5)
        ring.configure("fp32", plan, gen=3)      # stale generation resurfaces
        diags = sanitize.get().diagnostics("PHY103")
    assert len(diags) == 1 and "5 -> 3" in diags[0].message
