"""Multi-device behaviour (8 host devices, subprocess): explicit collectives,
DP strategies, halo exchange, flash-decode combine, dryrun on a small cell."""
import json

import pytest

from helpers import run_devices


def test_fused_equals_naive_equals_ring_allreduce():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import collectives, fusion
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ('data',))
        tree = {'a': jnp.arange(32, dtype=jnp.float32).reshape(4, 8),
                'b': jnp.ones((3, 5)) * 2}

        def body(t):
            naive = collectives.naive_psum(t, 'data')
            fused = collectives.fused_psum(t, 'data', cap_bytes=64)
            ring = jax.tree.map(
                lambda x: collectives.ring_all_reduce(x, 'data'), t)
            return naive, fused, ring

        from repro.core.compat import shard_map
        f = shard_map(body, mesh=mesh, in_specs=P(),
                          out_specs=P(), check_vma=False)
        n, fu, r = f(tree)
        for k in tree:
            np.testing.assert_allclose(np.asarray(n[k]),
                                       np.asarray(tree[k]) * 8, rtol=1e-6)
            np.testing.assert_allclose(np.asarray(fu[k]), np.asarray(n[k]),
                                       rtol=1e-6)
            np.testing.assert_allclose(np.asarray(r[k]), np.asarray(n[k]),
                                       rtol=1e-5)
        print('COLLECTIVES_OK')
    """)
    assert "COLLECTIVES_OK" in out


def test_halo_exchange_matches_manual_shift():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import collectives
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ('data',))
        x = jnp.arange(16.0).reshape(16, 1)
        xs = jax.device_put(x, NamedSharding(mesh, P('data')))

        def body(t):
            return collectives.halo_exchange(t, 'data', 1, dim=0)

        from repro.core.compat import shard_map
        f = shard_map(body, mesh=mesh, in_specs=P('data'),
                          out_specs=P('data'), check_vma=False)
        out = np.asarray(f(xs))          # [4 shards x 6 rows, 1]
        out = out.reshape(4, 6)
        # shard 1 holds rows 4..7; halo = row 3 (left) and row 8 (right)
        np.testing.assert_allclose(out[1], [3, 4, 5, 6, 7, 8])
        # edges zero-padded
        assert out[0, 0] == 0 and out[3, -1] == 0
        print('HALO_OK')
    """, n_devices=4)
    assert "HALO_OK" in out


def test_flash_decode_combine_matches_full_softmax():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import collectives
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ('data',))
        S, d = 64, 8
        key = jax.random.PRNGKey(0)
        lg = jax.random.normal(key, (S,))
        v = jax.random.normal(jax.random.PRNGKey(1), (S, d))
        want = jax.nn.softmax(lg) @ v

        def body(lg_l, v_l):
            m = jnp.max(lg_l)[None]
            l = jnp.sum(jnp.exp(lg_l - m))[None]
            o = jnp.exp(lg_l - m) @ v_l
            return collectives.softmax_combine((m, l, o), 'data')

        from repro.core.compat import shard_map
        f = shard_map(body, mesh=mesh, in_specs=(P('data'), P('data')),
                          out_specs=P(), check_vma=False)
        got = f(jax.device_put(lg, NamedSharding(mesh, P('data'))),
                jax.device_put(v, NamedSharding(mesh, P('data'))))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5)
        print('COMBINE_OK')
    """, n_devices=4)
    assert "COMBINE_OK" in out


STRATEGY_SNIPPET = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.core import steps as steps_lib
    from repro.data.pipeline import LMStream
    from repro.launch.mesh import make_local_mesh

    from repro.optim.optimizers import OptConfig
    cfg = get_config('qwen3-4b', tiny=True)
    mesh = make_local_mesh(data=4, model=2)
    shape = {{'seq_len': 32, 'global_batch': 8, 'kind': 'train'}}
    stream = LMStream(vocab=64, batch=8, seq=32, seed=0)
    step = steps_lib.make_train_step(
        cfg, mesh,
        steps_lib.Strategy(name='{name}', opt=OptConfig(lr=1e-3)),
        shape)
    params, opt = step.init(jax.random.PRNGKey(0))
    losses = []
    for it in range(12):
        b = stream.batch_at(it)
        b = {{k: jax.device_put(v, step.batch_shardings[k])
             for k, v in b.items()}}
        metrics, params, opt = step.fn(params, opt, b)
        losses.append(float(metrics['loss']))
    print('LOSSES', losses)
"""


@pytest.mark.parametrize("name", ["phylanx", "horovod", "zero1", "onebit"])
def test_strategy_trains_on_mesh(name):
    out = run_devices(STRATEGY_SNIPPET.format(name=name))
    losses = eval(out.split("LOSSES", 1)[1].strip())
    assert all(l > 0 and l == l for l in losses)
    # mean-of-tail vs mean-of-head: robust to 1-bit quantization noise
    head = sum(losses[:3]) / 3
    tail = sum(losses[-3:]) / 3
    assert tail < head - 0.05, f"{name}: no learning {losses}"


def test_phylanx_zero1_horovod_same_math():
    """The three exact strategies implement the same optimizer step - the
    loss trajectories must agree to numerical tolerance."""
    runs = {}
    for name in ("phylanx", "horovod", "zero1"):
        out = run_devices(STRATEGY_SNIPPET.format(name=name))
        runs[name] = eval(out.split("LOSSES", 1)[1].strip())
    for a, b in [("phylanx", "horovod"), ("phylanx", "zero1")]:
        diff = max(abs(x - y) for x, y in zip(runs[a], runs[b]))
        assert diff < 5e-2, (a, b, runs)


def test_dp_scaling_changes_nothing_semantically():
    """Same global batch on 1 vs 8 data shards -> same losses (SPMD)."""
    code = """
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.core import steps as steps_lib
        from repro.data.pipeline import LMStream
        from repro.launch.mesh import make_local_mesh
        cfg = get_config('qwen2.5-3b', tiny=True)
        mesh = make_local_mesh(data={dp}, model=1)
        shape = {{'seq_len': 16, 'global_batch': 8, 'kind': 'train'}}
        stream = LMStream(vocab=cfg.vocab, batch=8, seq=16, seed=3)
        step = steps_lib.make_train_step(cfg, mesh, steps_lib.Strategy(),
                                         shape)
        params, opt = step.init(jax.random.PRNGKey(0))
        ls = []
        for it in range(4):
            b = stream.batch_at(it)
            b = {{k: jax.device_put(v, step.batch_shardings[k])
                 for k, v in b.items()}}
            m, params, opt = step.fn(params, opt, b)
            ls.append(float(m['loss']))
        print('LOSSES', ls)
    """
    l1 = eval(run_devices(code.format(dp=1), n_devices=8)
              .split("LOSSES", 1)[1].strip())
    l8 = eval(run_devices(code.format(dp=8), n_devices=8)
              .split("LOSSES", 1)[1].strip())
    diff = max(abs(a - b) for a, b in zip(l1, l8))
    assert diff < 5e-3, (l1, l8)


def test_dryrun_small_cell_end_to_end(tmp_path):
    """One real dry-run cell (xlstm decode) through the production 512-chip
    mesh in a subprocess - proves the launcher path itself."""
    run_devices(f"""
        import sys
        sys.argv = ['dryrun', '--arch', 'xlstm-350m', '--shape', 'decode_32k',
                    '--mesh', 'single', '--out', r'{tmp_path}', '--force']
        from repro.launch import dryrun
        dryrun.main()
    """, n_devices=512, timeout=560)
    rec = json.loads(
        (tmp_path / "single" / "xlstm-350m__decode_32k.json").read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["roofline"]["t_compute_s"] > 0


def test_gpipe_pipeline_matches_sequential_and_trains():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import pipeline
        S, M, mb, d = 4, 8, 2, 16
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((S,), ('stage',))
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (S, d, d)) * (1.0 / d ** 0.5)

        def stage_fn(W, x):
            return jnp.tanh(x @ W)

        fn = pipeline.make_pipeline_fn(stage_fn, mesh)
        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
        Ws_sharded = jax.device_put(Ws, NamedSharding(mesh, P('stage')))
        y = fn(Ws_sharded, x)

        # sequential reference
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ Ws[s])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)

        # autodiff through the pipeline (backward schedule for free)
        def loss(Ws_s, x):
            return jnp.mean(fn(Ws_s, x) ** 2)
        g = jax.grad(loss)(Ws_sharded, x)

        def loss_ref(Ws, x):
            ref = x
            for s in range(S):
                ref = jnp.tanh(ref @ Ws[s])
            return jnp.mean(ref ** 2)
        g_ref = jax.grad(loss_ref)(Ws, x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-4, atol=1e-6)
        print('PIPELINE_OK bubble', pipeline.bubble_fraction(S, M))
    """, n_devices=4)
    assert "PIPELINE_OK" in out


def test_spatial_parallel_conv_matches_unsharded():
    """Paper §4.1 overlapped tiling: halo-exchanged spatially-sharded conv
    equals the unsharded conv on interior rows (exactly)."""
    out = run_devices("""
        import subprocess, sys, os
        sys.argv = ['x']
        import runpy
        runpy.run_path(os.path.join(os.path.dirname(r'{}'), '..',
                       'examples', 'spatial_parallel_cnn.py'),
                       run_name='__main__')
        print('SPATIAL_OK')
    """.format(__file__), n_devices=4)
    assert "SPATIAL_OK" in out
