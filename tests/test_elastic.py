"""Elastic membership churn drills (DESIGN.md §13): dial-in/spawned
joins mid-train, work stealing across localities, newcomer loss,
simultaneous join+kill churn, and the concurrent bidirectional dial
regression on the parcel layer.

Every drill runs REAL processes (``multiprocessing.spawn``) and asserts
the elastic machinery never changes *what* is computed - final loss
stays bit-identical to the static reference run - only *where*.
Everything a worker runs must be a module-level function here, because
it crosses the wire by reference.
"""
import os
import threading
import time

import pytest

from repro.distrib import DistributedGraph
from repro.distrib.messaging import Endpoint
from repro.frontend import Plan

ARCH = "qwen2.5-3b"


def _plan(**kw):
    kw.setdefault("arch", ARCH)
    kw.setdefault("batch", 4)
    kw.setdefault("seq", 16)
    return Plan(**kw)


# -- module-level task functions (ship by reference) -------------------------

def nap_id(i, delay=0.05):
    time.sleep(delay)
    return i


def _assert_procs_reaped(pids, timeout=30.0):
    """Every worker pid must be gone (reaped, not just zombied) soon
    after close - the no-orphans half of the churn acceptance."""
    deadline = time.time() + timeout
    for pid in pids:
        while time.time() < deadline:
            try:
                os.kill(pid, 0)
            except ProcessLookupError:
                break                      # exited and reaped
            time.sleep(0.05)
        else:
            pytest.fail(f"worker pid {pid} still alive after close")


class _Churn:
    """Training hook that joins (and optionally kills) localities at
    fixed steps; picklable state never crosses the wire - it drives the
    driver-side session only."""

    def __init__(self, session, join_at, kill_newcomer_at=None,
                 kill_rank_at=None, idle_gap_at=None):
        self.session = session
        self.join_at = join_at
        self.kill_newcomer_at = kill_newcomer_at
        self.kill_rank_at = kill_rank_at or {}   # {step: rank}
        self.idle_gap_at = idle_gap_at
        self.joined_rank = None

    def on_step(self, it, metrics):
        if it == self.idle_gap_at:
            # a deliberate device-step-sized stall: the newcomer drains
            # its queue, goes hungry, and the next steerable prefetch
            # build is diverted to it - the deterministic steal window
            time.sleep(0.25)
        if it in self.kill_rank_at:
            # churn both directions in the same step: SIGKILL an
            # original member WHILE the join handshake runs
            t = threading.Thread(
                target=self.session.kill_locality,
                args=(self.kill_rank_at[it],))
            t.start()
            self.joined_rank = self.session.add_locality()
            t.join(timeout=60)
            assert not t.is_alive()
            return
        if it == self.join_at:
            self.joined_rank = self.session.add_locality()
        if self.kill_newcomer_at is not None \
                and it == self.kill_newcomer_at:
            assert self.joined_rank is not None
            self.session.kill_locality(self.joined_rank)


def _reference_loss(steps):
    with _plan().compile() as single:
        return single.train(steps=steps, log_every=6,
                            verbose=False)["final_loss"]


# -- join mid-train: loss parity + real steals --------------------------------

def test_join_mid_train_matches_reference_and_steals():
    """The acceptance drill: an elastic session that starts alone and
    gains a locality at step 3 finishes with the SAME loss as the
    static single-process run, and the newcomer really pulled work
    (``stolen_tasks > 0``) - stealing moves placement, never values."""
    steps = 14
    ref = _reference_loss(steps)
    with _plan(elastic=True).compile() as ses:
        hooks = _Churn(ses, join_at=3, idle_gap_at=7)
        out = ses.train(steps=steps, log_every=6, hooks=hooks,
                        verbose=False)
        dstats = out["runtime_stats"]["distributed"]
        pids = [p.pid for p in ses.distributed.group.procs.values()]
    assert hooks.joined_rank == 1
    assert out["final_loss"] == pytest.approx(ref, abs=1e-6)
    assert dstats["joined_localities"] == 1
    assert dstats["membership_gen"] >= 1
    assert dstats["stolen_tasks"] > 0
    assert dstats["dispatched"].get(1, 0) > 0    # work really landed there
    _assert_procs_reaped(pids)


# -- join then lose the newcomer ---------------------------------------------

def test_join_then_kill_newcomer_train_survives():
    """A joiner that dies mid-run must cost nothing: its in-flight
    tasks re-spawn (idempotent prefetch builds) and the loss trajectory
    is untouched."""
    steps = 12
    ref = _reference_loss(steps)
    with _plan(elastic=True).compile() as ses:
        hooks = _Churn(ses, join_at=2, kill_newcomer_at=6)
        out = ses.train(steps=steps, log_every=6, hooks=hooks,
                        verbose=False)
        dstats = out["runtime_stats"]["distributed"]
        pids = [p.pid for p in ses.distributed.group.procs.values()]
    assert out["final_loss"] == pytest.approx(ref, abs=1e-6)
    assert dstats["joined_localities"] == 1
    assert dstats["alive_workers"] == []         # the kill really landed
    assert dstats["membership_gen"] >= 2         # one join + one loss
    _assert_procs_reaped(pids)


# -- simultaneous join + kill of an original member ---------------------------

def test_simultaneous_join_and_kill_original_peer():
    """Worst-case churn: at one step an ORIGINAL worker is SIGKILLed
    while a newcomer's join handshake is in flight.  Membership gossip
    is generation-keyed, so both events land, the newcomer becomes the
    only live worker, and the loss still matches the static run."""
    steps = 12
    ref = _reference_loss(steps)
    with _plan(localities=2, elastic=True).compile() as ses:
        hooks = _Churn(ses, join_at=None, kill_rank_at={4: 1})
        out = ses.train(steps=steps, log_every=6, hooks=hooks,
                        verbose=False)
        dstats = out["runtime_stats"]["distributed"]
        pids = [p.pid for p in ses.distributed.group.procs.values()]
    assert hooks.joined_rank == 2
    assert out["final_loss"] == pytest.approx(ref, abs=1e-6)
    assert dstats["alive_workers"] == [2]        # newcomer in, original out
    assert dstats["membership_gen"] >= 2
    assert dstats["joined_localities"] == 1
    _assert_procs_reaped(pids)


# -- steal modes on a bare DistributedGraph -----------------------------------

def test_backlog_steal_after_join_spares_pinned_tasks():
    """Victim-lease stealing: a worker with a deep queue of steerable
    tasks loses some of them to a fresh joiner - but explicitly pinned
    (``locality=``) tasks are never stealable."""
    g = DistributedGraph(localities=2, elastic=True)
    try:
        futs = [g.defer(nap_id, i, delay=0.1, name=f"p{i}")
                for i in range(24)]
        rank = g.add_locality(timeout=120)
        assert [f.result(timeout=120) for f in futs] == list(range(24))
        s = g.stats()
        assert s["stolen_tasks"] > 0
        assert s["dispatched"].get(rank, 0) > 0
        # pinned tasks: park the joiner idle, pin everything to rank 1
        g.stolen_tasks = 0
        futs = [g.defer(nap_id, i, name=f"q{i}", locality=1)
                for i in range(10)]
        assert [f.result(timeout=120) for f in futs] == list(range(10))
        assert g.stolen_tasks == 0
    finally:
        g.shutdown()


def test_rebalance_migrates_objects_and_stale_refs_still_resolve():
    """AGAS rebalance at join: pinned driver objects migrate to the
    newcomer behind forwarding stubs; every stale ``RemoteRef`` held
    from before the join keeps dereferencing to the same value."""
    g = DistributedGraph(localities=1, elastic=True)
    try:
        refs = [g.defer(nap_id, i, delay=0.0, name=f"m{i}",
                        pin=True).result(timeout=60) for i in range(10)]
        assert all(r.owner == 0 for r in refs)
        g.add_locality(timeout=120)
        s = g.stats()
        assert s["migrated_objects"] > 0
        for i, ref in enumerate(refs):           # stale gids: stub-chased
            assert g.fetch(ref) == i
        assert g.directory.audit()["forwarded_fetches"] > 0
    finally:
        g.shutdown()


# -- parcel layer: concurrent bidirectional dial ------------------------------

def test_concurrent_bidirectional_dial_is_one_connection():
    """Two endpoints dialing each other at the same instant (both sides
    of a join racing) must converge on ONE logical connection: requests
    flow both ways afterwards and closing the loser socket never fires
    a spurious peer-lost."""
    for _ in range(8):                           # the race needs attempts
        a, b = Endpoint(0), Endpoint(1)
        lost = []
        a.on_peer_lost = lost.append
        b.on_peer_lost = lost.append
        a.register("ping", lambda src, p: ("a", p))
        b.register("ping", lambda src, p: ("b", p))
        gate = threading.Barrier(2)

        def dial(ep, rank, addr):
            gate.wait()
            ep.connect(rank, addr)

        ts = [threading.Thread(target=dial, args=(a, 1, b.address)),
              threading.Thread(target=dial, args=(b, 0, a.address))]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=40)
            assert not t.is_alive()
        deadline = time.time() + 10
        while time.time() < deadline and not (a.peers() == [1]
                                              and b.peers() == [0]):
            time.sleep(0.01)
        assert a.peers() == [1] and b.peers() == [0]
        assert a.request(1, "ping", 7, timeout=30) == ("b", 7)
        assert b.request(0, "ping", 8, timeout=30) == ("a", 8)
        time.sleep(0.2)       # give a dying duplicate time to misfire
        assert lost == []
        a.close()
        b.close()
