"""Optimizers vs numpy reference; data pipeline determinism/learnability;
grain policy; futures pipeline."""
import jax.numpy as jnp
import numpy as np

from repro.core.futures import FuturizedGraph, Pipeline
from repro.data.pipeline import HARStream, LMStream, Prefetcher
from repro.optim import optimizers as optim
from repro.optim.optimizers import OptConfig


def _np_adamw(g, p, m, v, t, oc):
    m = oc.b1 * m + (1 - oc.b1) * g
    v = oc.b2 * v + (1 - oc.b2) * g * g
    mh = m / (1 - oc.b1 ** t)
    vh = v / (1 - oc.b2 ** t)
    return p - oc.lr * (mh / (np.sqrt(vh) + oc.eps) + oc.weight_decay * p), m, v


def test_adamw_matches_numpy_reference():
    oc = OptConfig(lr=1e-2, weight_decay=0.01, grad_clip=1e9)
    rng = np.random.default_rng(0)
    params = {"a": jnp.asarray(rng.standard_normal((4, 5)), jnp.float32),
              "b": jnp.asarray(rng.standard_normal(7), jnp.float32)}
    state = optim.init(params, oc)
    np_p = {k: np.asarray(v) for k, v in params.items()}
    np_m = {k: np.zeros_like(v) for k, v in np_p.items()}
    np_v = {k: np.zeros_like(v) for k, v in np_p.items()}
    for t in range(1, 4):
        grads = {k: jnp.asarray(rng.standard_normal(v.shape), jnp.float32)
                 for k, v in np_p.items()}
        params, state, _ = optim.update(grads, state, params, oc)
        for k in np_p:
            np_p[k], np_m[k], np_v[k] = _np_adamw(
                np.asarray(grads[k]), np_p[k], np_m[k], np_v[k], t, oc)
    for k in np_p:
        np.testing.assert_allclose(np.asarray(params[k]), np_p[k], rtol=1e-5,
                                   atol=1e-6)


def test_grad_clip_scales_to_max_norm():
    grads = {"a": jnp.ones((10,)) * 10.0}
    clipped, gn = optim.clip_by_global_norm(grads, 1.0)
    assert abs(float(gn) - 10.0 * np.sqrt(10)) < 1e-3
    got = float(optim.global_norm(clipped))
    assert abs(got - 1.0) < 1e-5


def test_momentum_and_sgd_update_directions():
    for kind in ("momentum", "sgd"):
        oc = OptConfig(kind=kind, lr=0.1, grad_clip=1e9)
        params = {"w": jnp.ones(3)}
        state = optim.init(params, oc)
        grads = {"w": jnp.ones(3)}
        new_p, state, _ = optim.update(grads, state, params, oc)
        assert float(new_p["w"][0]) < 1.0


def test_lm_stream_is_deterministic_and_learnable():
    s = LMStream(vocab=97, batch=4, seq=32, seed=5)
    b1 = s.batch_at(7)
    b2 = s.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s.batch_at(8)["tokens"], b1["tokens"])
    # ~90% of labels follow the affine bigram rule
    pred = (s.a * b1["tokens"] + s.b) % 97
    agree = (pred == b1["labels"]).mean()
    assert 0.8 < agree <= 1.0


def test_har_stream_shapes_and_classes():
    s = HARStream(batch=16)
    b = s.batch_at(0)
    assert b["x"].shape == (16, 128, 9)
    assert b["y"].min() >= 0 and b["y"].max() < 6


def test_prefetcher_returns_same_batches_in_order():
    s = LMStream(vocab=11, batch=2, seq=8, seed=1)
    pf = Prefetcher(s, shardings=None, depth=2)
    for step in range(4):
        got = pf.get(step)
        want = s.batch_at(step)
        np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                      want["tokens"])


def test_futurized_graph_resolves_dependencies():
    g = FuturizedGraph()
    a = g.defer(lambda: 2)
    b = g.defer(lambda x: x * 3, a)
    c = g.defer(lambda x, y: x + y, a, b)
    assert c.result() == 8
    g.shutdown()


def test_pipeline_keeps_depth_in_flight():
    p = Pipeline(depth=2)
    retired = []
    for i in range(5):
        r = p.push(i, jnp.ones(2) * i)
        if r is not None:
            retired.append(r.step)
    rest = p.drain()
    assert retired == [0, 1, 2]
    assert [r.step for r in rest] == [3, 4]
