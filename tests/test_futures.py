"""The futurized execution runtime: dependency ordering, combinators,
error/cancellation propagation along edges, pytree traversal, priority
lanes, runtime stats, Pipeline depth/drain, shutdown barriers."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.futures import (CancelledError, FuturizedGraph, Lane,
                                Pipeline, TaskState)


@pytest.fixture()
def graph():
    g = FuturizedGraph(max_workers=2, name="test")
    yield g
    g.shutdown(wait=False, cancel_pending=True)


# -- dependency-tracked execution -------------------------------------------

def test_chain_executes_in_dependency_order_without_caller_forcing(graph):
    """A >=3-task chain runs in edge order; the submitting thread never
    calls .result() until the whole tree is built."""
    order = []

    def tag(name, value):
        order.append(name)
        return value

    a = graph.defer(tag, "a", 2, name="a")
    b = graph.defer(lambda x: tag("b", x * 3), a, name="b")
    c = graph.defer(lambda x, y: tag("c", x + y), a, b, name="c")
    # only now does the caller touch a result - of the *root* only
    assert c.result() == 8
    assert order == ["a", "b", "c"]
    assert a.state is TaskState.DONE and b.state is TaskState.DONE


def test_diamond_runs_join_after_both_branches(graph):
    gate = threading.Event()
    src = graph.defer(lambda: (gate.wait(2), 1)[1], name="src")
    left = graph.defer(lambda x: x + 10, src, name="left")
    right = graph.defer(lambda x: x + 100, src, name="right")
    join = graph.defer(lambda l, r: l + r, left, right, name="join")
    assert not join.done()           # src still gated: nothing downstream ran
    gate.set()
    assert join.result() == 112


def test_defer_never_blocks_submitter(graph):
    gate = threading.Event()
    t0 = time.perf_counter()
    f = graph.defer(gate.wait, 5, name="slow")
    g2 = graph.defer(lambda x: x, f, name="dependent")
    assert time.perf_counter() - t0 < 0.5     # both submissions returned fast
    assert not g2.done()
    gate.set()
    assert g2.result() is True


def test_kwarg_and_nested_container_futures_become_edges(graph):
    a = graph.defer(lambda: 5, name="a")
    b = graph.defer(lambda xs, y=None: xs["k"][0] + y, {"k": [a]}, y=a,
                    name="b")
    assert b.result() == 10


# -- combinators -------------------------------------------------------------

def test_when_all_collects_in_order(graph):
    futs = [graph.defer(lambda i=i: i * i, name=f"s{i}") for i in range(6)]
    assert graph.when_all(futs).result() == [0, 1, 4, 9, 16, 25]


def test_when_any_returns_first_success(graph):
    slow_gate = threading.Event()
    slow = graph.defer(slow_gate.wait, 5, name="slow")
    fast = graph.defer(lambda: "fast", name="fast")
    i, v = graph.when_any([slow, fast]).result()
    assert (i, v) == (1, "fast")
    slow_gate.set()


def test_when_any_errors_only_if_all_fail(graph):
    f1 = graph.defer(lambda: 1 / 0, name="f1")
    f2 = graph.defer(lambda: None.x, name="f2")
    any_fut = graph.when_any([f1, f2])
    with pytest.raises((ZeroDivisionError, AttributeError)):
        any_fut.result()


def test_tree_join_resolves_pytree_of_futures(graph):
    a = graph.defer(lambda: jnp.ones(3), name="a")
    b = graph.defer(lambda: 7, name="b")
    tree = {"x": a, "y": [b, "static"], "z": 1.5}
    out = graph.tree_join(tree).result()
    np.testing.assert_array_equal(np.asarray(out["x"]), np.ones(3))
    assert out["y"] == [7, "static"] and out["z"] == 1.5


# -- error & cancellation propagation ---------------------------------------

def test_error_propagates_to_all_transitive_dependents(graph):
    boom = ValueError("injected")

    def explode():
        raise boom

    a = graph.defer(explode, name="a")
    b = graph.defer(lambda x: x + 1, a, name="b")
    c = graph.defer(lambda x: x + 1, b, name="c")       # transitive
    d = graph.defer(lambda x, y: x + y, a, c, name="d")  # multi-edge
    for f in (b, c, d):
        with pytest.raises(ValueError, match="injected"):
            f.result()
        assert f.state is TaskState.ERROR
    assert graph.stats().failed == 4


def test_error_does_not_poison_unrelated_tasks(graph):
    bad = graph.defer(lambda: 1 / 0, name="bad")
    good = graph.defer(lambda: 3, name="good")
    assert good.result() == 3
    with pytest.raises(ZeroDivisionError):
        bad.result()


def test_defer_on_already_failed_dep_fails_immediately(graph):
    bad = graph.defer(lambda: 1 / 0, name="bad")
    with pytest.raises(ZeroDivisionError):
        bad.result()
    late = graph.defer(lambda x: x, bad, name="late")
    with pytest.raises(ZeroDivisionError):
        late.result()


def test_cancel_propagates_to_dependents(graph):
    gate = threading.Event()
    src = graph.defer(gate.wait, 5, name="src")
    pend = graph.defer(lambda x: x, src, name="pend")      # PENDING on src
    leaf = graph.defer(lambda x: x, pend, name="leaf")
    assert pend.cancel() is True
    assert pend.state is TaskState.CANCELLED
    assert leaf.state is TaskState.CANCELLED
    with pytest.raises(CancelledError):
        leaf.result()
    gate.set()
    assert src.result() is True          # upstream unaffected by the cancel
    assert graph.stats().cancelled == 2


def test_cancel_running_task_returns_false(graph):
    started, gate = threading.Event(), threading.Event()

    def body():
        started.set()
        gate.wait(5)
        return "done"

    f = graph.defer(body, name="running")
    started.wait(2)
    assert f.cancel() is False
    gate.set()
    assert f.result() == "done"


# -- priority lanes & stats --------------------------------------------------

def test_lanes_drain_compute_before_checkpoint():
    g = FuturizedGraph(max_workers=1, name="lanes")
    try:
        hold = threading.Event()
        order = []
        g.defer(hold.wait, 5, name="blocker")
        # enqueued while the single worker is held, in "wrong" order:
        g.defer(lambda: order.append("ckpt"), lane=Lane.CHECKPOINT,
                name="ckpt")
        g.defer(lambda: order.append("prefetch"), lane=Lane.PREFETCH,
                name="pf")
        g.defer(lambda: order.append("compute"), lane=Lane.COMPUTE,
                name="comp")
        hold.set()
        g.barrier(timeout=10)
        assert order == ["compute", "prefetch", "ckpt"]
    finally:
        g.shutdown(wait=True)


def test_stats_counts_and_max_in_flight(graph):
    futs = [graph.defer(time.sleep, 0.02, name=f"t{i}") for i in range(6)]
    graph.gather(futs)
    st = graph.stats()
    assert st.submitted >= 6 and st.completed >= 6
    assert 1 <= st.max_in_flight <= 2          # 2 workers
    assert st.per_lane["COMPUTE"] >= 6
    assert st.idle_s >= 0.0 and st.busy_s > 0.0


def test_immediate_future_is_resolved_edge(graph):
    imm = graph.immediate({"v": 1})
    assert imm.done()
    out = graph.defer(lambda d: d["v"] + 1, imm, name="use")
    assert out.result() == 2


# -- Pipeline (in-flight device steps) --------------------------------------

def test_pipeline_keeps_depth_in_flight_and_drains_in_order():
    p = Pipeline(depth=2)
    retired = []
    for i in range(5):
        r = p.push(i, jnp.ones(2) * i)
        if r is not None:
            retired.append(r.step)
    assert retired == [0, 1, 2] and len(p) == 2
    rest = p.drain()
    assert [r.step for r in rest] == [3, 4] and len(p) == 0


# -- shutdown barriers -------------------------------------------------------

def test_shutdown_waits_for_pending_checkpoint_nodes(tmp_path):
    from repro.checkpoint.checkpoint import CheckpointManager
    g = FuturizedGraph(max_workers=2, name="ckpt-shutdown")
    release = threading.Event()
    ckpt = CheckpointManager(tmp_path, graph=g)
    tree = {"w": np.arange(8.0)}
    # the save's write node depends on a still-pending retirement edge
    retired = g.defer(release.wait, 5, name="retire")
    ckpt.save(7, tree, deps=(retired,))
    assert ckpt.all_steps() == []            # nothing on disk yet
    release.set()
    g.shutdown(wait=True)                    # barrier drains checkpoint lane
    assert ckpt.all_steps() == [7]
    step, back = ckpt.restore(tree)
    assert step == 7
    np.testing.assert_array_equal(back["w"], tree["w"])


def test_checkpoint_save_failure_surfaces_on_next_save(tmp_path):
    from repro.checkpoint.checkpoint import CheckpointManager
    g = FuturizedGraph(max_workers=2, name="ckpt-fail")
    try:
        ckpt = CheckpointManager(tmp_path, graph=g)
        boom = g.defer(lambda: 1 / 0, name="dep")   # poisons the write node
        ckpt.save(1, {"w": np.ones(4)}, deps=(boom,))
        g.barrier(timeout=10)
        with pytest.raises(ZeroDivisionError):      # fail fast, not at close
            ckpt.save(2, {"w": np.ones(4)})
        assert ckpt.all_steps() == []
    finally:
        g.shutdown(wait=True)


def test_defer_cross_graph_dep_rejected_without_corrupting_graph():
    g1 = FuturizedGraph(max_workers=1, name="g1")
    g2 = FuturizedGraph(max_workers=1, name="g2")
    try:
        local = g1.defer(lambda: 1, name="local")
        foreign = g2.defer(lambda: 2, name="foreign")
        with pytest.raises(ValueError, match="different graph"):
            g1.defer(lambda a, b: a + b, local, foreign, name="bad")
        g1.barrier(timeout=10)          # must not hang on a phantom node
        assert g1.defer(lambda x: x + 1, local, name="ok").result() == 2
    finally:
        g1.shutdown(wait=True)
        g2.shutdown(wait=True)


def test_defer_after_shutdown_raises():
    g = FuturizedGraph(max_workers=1, name="closed")
    g.shutdown(wait=True)
    with pytest.raises(RuntimeError, match="shut down"):
        g.defer(lambda: 1)


def test_barrier_timeout_raises(graph):
    gate = threading.Event()
    graph.defer(gate.wait, 5, name="held")
    with pytest.raises(TimeoutError):
        graph.barrier(timeout=0.05)
    gate.set()
    graph.barrier(timeout=10)
