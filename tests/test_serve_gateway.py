"""Serving gateway battery (DESIGN.md §14): continuous batching with
mid-flight arrivals and the paged inference cache, plus fault injection
(cancel mid-decode, deadline expiry mid-prefill, poisoned prefill) and
the 2-locality parity / kill-locality drills.

The load-bearing property: prefill runs ONCE per request (at admission,
batch=1) and decode math is row-independent, so a request's token stream
depends only on its prompt.  Every fault test asserts the survivors'
streams are *bit-identical* to an unperturbed run AND that the faulted
request's slot and pages were reclaimed (``pages_live == 0``)."""
import threading
import time
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.analysis import sanitize
from repro.frontend import Plan
from repro.frontend.gateway import (DeadlineExpired, RequestQueue,
                                    RequestRejected)

ARCH = "qwen2.5-3b"
ARRIVALS = (0, 0, 1, 3, 3)           # staggered, requests > slots


def _plan(**kw):
    kw.setdefault("arch", ARCH)
    return Plan(**kw)


def _kwargs(**over):
    kw = dict(prompt_len=16, gen_len=4, slots=2, verbose=False)
    kw.update(over)
    return kw


def _stream(trace, **over):
    """One fresh-session gateway run over a deterministic trace."""
    with _plan().compile() as session:
        return session.serve_stream(trace=trace, **_kwargs(**over))


_BASELINES: dict = {}


def _baseline_streams(arrivals, **over):
    """Unperturbed streams for an arrival script (cached per config):
    rid numbering and prompts depend only on entry order and plan.seed,
    so a fault run over the same script is directly comparable."""
    key = (tuple(arrivals), tuple(sorted(over.items())))
    if key not in _BASELINES:
        out = _stream([{"at_round": r} for r in arrivals], **over)
        assert out["completed"] == len(arrivals)
        _BASELINES[key] = out["streams"]
    return _BASELINES[key]


# -- the tentpole: mid-flight arrivals, zero prefill recomputation -----------

def test_streamed_arrivals_complete_with_zero_prefill_recompute():
    with _plan().compile() as session:
        out = session.serve_stream(
            trace=[{"at_round": r} for r in ARRIVALS], **_kwargs())
        assert session.lint() == []          # live gateway graph is clean
    n, gen = len(ARRIVALS), 4
    assert out["completed"] == n
    assert out["cancelled"] == out["expired"] == out["failed"] == 0
    # every stream: the prefill token plus gen_len decoded tokens
    assert sorted(out["streams"]) == [f"r{i}" for i in range(n)]
    assert all(len(s) == gen + 1 for s in out["streams"].values())
    assert out["tokens"] == n * gen

    # the paged-cache contract: every slot join loaded pages; the prefill
    # recompute fallback never ran; everything was reclaimed
    serve = out["runtime_stats"]["serve"]
    assert serve["refills"] == serve["page_hits"] == n
    assert serve.get("prefill_recompute", 0) == 0
    cache = out["cache"]
    assert cache["cache_puts"] == cache["cache_hits"] == n
    assert cache["pages_live"] == 0 and cache["cache_entries"] == 0
    assert cache["page_allocs"] == cache["page_frees"]

    # staggered arrivals mean epochs were cut mid-run, not one big wave
    assert out["epochs"] >= 2
    names = set(out["nodes"])
    for i in range(n):
        assert {f"stack:r{i}", f"prefill:r{i}", f"finish:r{i}",
                f"request:r{i}"} <= names
    assert "refill:e0" in names and "decode:e0:t0" in names

    # latency histograms: every phase observed, counts match the run
    hist = out["runtime_stats"]["request_latency_hist"]
    assert hist["edges_s"] and len(hist["labels"]) == len(hist["edges_s"]) + 1
    counts = hist["counts"]
    assert sum(counts["queue_wait"]) == n
    assert sum(counts["prefill"]) == n
    assert sum(counts["total"]) == n
    assert sum(counts["decode_token"]) == n * gen

    # padded-slot accounting: real + padded covers every (round, slot)
    assert serve["real_tokens"] == n * gen
    assert serve["real_tokens"] + serve["padded_slot_tokens"] \
        == out["rounds"] * 2
    assert out["padded_tokens"] == serve["padded_slot_tokens"]


def test_gateway_trace_builder_matches_live_run():
    """phylint's static mirror (analysis.gateway_trace) and the live
    gateway build the same tree: same names, lanes and edges."""
    from repro.analysis import gateway_trace

    out = _stream([{"at_round": r} for r in ARRIVALS])
    sig = out["trace"]
    live = {(name, lane, tuple(sig[d][0] for d in deps))
            for name, lane, deps in sig}
    g = gateway_trace(_plan(), requests=len(ARRIVALS), gen_len=4, slots=2,
                      arrivals=list(ARRIVALS))
    mirror = {(n.name, n.lane, tuple(g.nodes[d].name for d in n.deps))
              for n in g.nodes}
    assert live == mirror


# -- fault injection ---------------------------------------------------------

def test_cancel_mid_decode_reclaims_slot_and_preserves_survivors():
    base = _baseline_streams(ARRIVALS)
    trace = [{"at_round": r} for r in ARRIVALS]
    trace[1]["cancel_after"] = 2             # r1: cancel after 2 tokens
    out = _stream(trace)
    assert out["cancelled"] == 1 and out["completed"] == len(ARRIVALS) - 1
    h = next(h for h in out["handles"] if h.rid == "r1")
    assert h.status == "cancelled"
    with pytest.raises(CancelledError):
        h.result(timeout=5)
    assert len(h.tokens) == 1 + 2            # prefill + the 2 decoded
    assert out["streams"]["r1"] == base["r1"][:3]   # a prefix, not junk
    for rid, stream in base.items():         # survivors are bit-identical
        if rid != "r1":
            assert out["streams"][rid] == stream
    assert out["cache"]["pages_live"] == 0
    assert out["cache"]["cache_entries"] == 0


def test_deadline_expiry_while_waiting_for_a_slot():
    """slots=1: r0 monopolizes the slot; r1 is admitted (prefill runs,
    pages park) but its deadline lapses before a slot frees - it must
    expire cleanly with its pages reclaimed, and r0 is untouched."""
    kw = dict(gen_len=8, slots=1)
    base = _baseline_streams((0,), **kw)
    out = _stream([{"at_round": 0}, {"at_round": 0, "deadline_ms": 50}],
                  **kw)
    assert out["completed"] == 1 and out["expired"] == 1
    h = next(h for h in out["handles"] if h.rid == "r1")
    assert h.status == "expired"
    with pytest.raises(DeadlineExpired):
        h.result(timeout=5)
    assert out["streams"]["r0"] == base["r0"]
    assert out["cache"]["pages_live"] == 0
    assert out["cache"]["cache_entries"] == 0
    assert out["runtime_stats"]["serve"].get("prefill_recompute", 0) == 0


def test_poisoned_prefill_is_contained_to_its_chain():
    base = _baseline_streams(ARRIVALS)
    trace = [{"at_round": r} for r in ARRIVALS]
    trace[2]["inject"] = "poison-prefill"
    out = _stream(trace)
    assert out["failed"] == 1 and out["completed"] == len(ARRIVALS) - 1
    h = next(h for h in out["handles"] if h.rid == "r2")
    assert h.status == "failed"
    with pytest.raises(RuntimeError, match="injected prefill poison"):
        h.result(timeout=5)
    assert h.tokens == []                    # never reached a slot
    for rid, stream in base.items():         # the poison never crossed
        if rid != "r2":                      # into the shared decode chain
            assert out["streams"][rid] == stream
    assert out["cache"]["pages_live"] == 0


def test_fault_battery_drains_cleanly_under_sanitizer():
    """All three faults in one run with the concurrency sanitizer armed:
    the gateway must drain without a deadlock diagnostic (every promise
    is producer-backed and resolved, even for killed chains)."""
    trace = [{"at_round": r} for r in ARRIVALS]
    trace[1]["cancel_after"] = 1
    trace[2]["inject"] = "poison-prefill"
    trace[4]["deadline_ms"] = 0.0            # expires before admission
    with sanitize.enabled():
        out = _stream(trace)
        assert sanitize.get().diagnostics() == []
    assert out["completed"] == 2
    assert out["cancelled"] == out["expired"] == out["failed"] == 1
    assert out["cache"]["pages_live"] == 0
    statuses = {h.rid: h.status for h in out["handles"]}
    assert statuses == {"r0": "done", "r1": "cancelled", "r2": "failed",
                        "r3": "done", "r4": "expired"}


# -- the live side: threads, admission, rejection ----------------------------

def test_live_queue_submissions_from_another_thread():
    with _plan().compile() as session:
        rng = np.random.default_rng(11)
        prompts = [rng.integers(0, session.cfg.vocab, 16).astype(np.int32)
                   for _ in range(3)]
        q = RequestQueue()

        def feeder():
            for p in prompts:
                q.submit(p)
                time.sleep(0.02)
            q.close()

        t = threading.Thread(target=feeder)
        t.start()
        out = session.serve_stream(queue=q, **_kwargs())
        t.join()
    assert out["completed"] == 3
    assert all(h.result(timeout=5) == out["streams"][h.rid]
               for h in out["handles"])


def test_request_queue_backlog_and_close_reject():
    q = RequestQueue(max_queue=1)
    ok = q.submit([1, 2])
    full = q.submit([3, 4])
    assert ok.status == "queued" and full.status == "rejected"
    with pytest.raises(RequestRejected, match="capacity"):
        full.result(timeout=1)
    q.close()
    late = q.submit([5, 6])
    assert late.status == "rejected"
    with pytest.raises(RequestRejected, match="closed"):
        late.result(timeout=1)
    assert q.submitted == 1 and q.rejected == 2


def test_wave_serve_accounts_padded_slot_compute():
    """``Session.serve`` pads idle slots into every wave; the padded-slot
    compute must be accounted separately, never folded into tokens."""
    with _plan().compile() as session:
        out = session.serve(requests=3, slots=2, prompt_len=16, gen_len=4,
                            verbose=False)
        serve = session.runtime.stats().serve
    assert out["tokens"] == 3 * 4            # only real requests
    assert out["padded_tokens"] == 1 * 4     # wave 1 ran a padded slot
    assert serve["real_tokens"] == 12
    assert serve["padded_slot_tokens"] == 4


# -- multiproc tier: locality parity + kill drill ----------------------------

@pytest.mark.multiproc
def test_two_locality_gateway_streams_match_single_process():
    trace = [{"at_round": r} for r in ARRIVALS]
    with _plan(localities=2).compile() as multi:
        out2 = multi.serve_stream(trace=trace, **_kwargs())
    assert out2["completed"] == len(ARRIVALS)
    assert out2["cache"]["pages_live"] == 0
    base = _baseline_streams(ARRIVALS)       # 1-process, same script
    assert out2["streams"] == base


@pytest.mark.multiproc
def test_kill_locality_mid_stream_completes_survivors():
    """SIGKILL a worker while the gateway is streaming: its in-flight
    stack tasks re-spawn, requests submitted after the kill still
    complete, and every stream matches the 1-process run."""
    kw = dict(gen_len=6)
    with _plan(localities=2).compile() as session:
        rng = np.random.default_rng(23)
        prompts = [rng.integers(0, session.cfg.vocab, 16).astype(np.int32)
                   for _ in range(6)]
        q = RequestQueue()
        killed = {}

        def feeder():
            for i, p in enumerate(prompts):
                if i == 3:
                    killed["rank"] = session.kill_locality()
                q.submit(p)
                time.sleep(0.05)
            q.close()

        t = threading.Thread(target=feeder)
        t.start()
        out = session.serve_stream(queue=q, **_kwargs(**kw))
        t.join()
    assert killed["rank"] is not None
    assert out["completed"] == len(prompts)
    assert out["cache"]["pages_live"] == 0
    base = _stream([{"prompt": p} for p in prompts], **kw)
    assert out["streams"] == base["streams"]
