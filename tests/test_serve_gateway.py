"""Serving gateway battery (DESIGN.md §14): continuous batching with
mid-flight arrivals and the paged inference cache, plus fault injection
(cancel mid-decode, deadline expiry mid-prefill, poisoned prefill) and
the 2-locality parity / kill-locality drills.

The load-bearing property: prefill runs ONCE per request (at admission,
batch=1) and decode math is row-independent, so a request's token stream
depends only on its prompt.  Every fault test asserts the survivors'
streams are *bit-identical* to an unperturbed run AND that the faulted
request's slot and pages were reclaimed (``pages_live == 0``)."""
import threading
import time
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.analysis import sanitize
from repro.frontend import Plan
from repro.frontend.gateway import (DeadlineExpired, RequestQueue,
                                    RequestRejected)

ARCH = "qwen2.5-3b"
ARRIVALS = (0, 0, 1, 3, 3)           # staggered, requests > slots


def _plan(**kw):
    kw.setdefault("arch", ARCH)
    return Plan(**kw)


def _kwargs(**over):
    kw = dict(prompt_len=16, gen_len=4, slots=2, verbose=False)
    kw.update(over)
    return kw


def _stream(trace, **over):
    """One fresh-session gateway run over a deterministic trace."""
    with _plan().compile() as session:
        return session.serve_stream(trace=trace, **_kwargs(**over))


_BASELINES: dict = {}


def _baseline_streams(arrivals, **over):
    """Unperturbed streams for an arrival script (cached per config):
    rid numbering and prompts depend only on entry order and plan.seed,
    so a fault run over the same script is directly comparable."""
    key = (tuple(arrivals), tuple(sorted(over.items())))
    if key not in _BASELINES:
        out = _stream([{"at_round": r} for r in arrivals], **over)
        assert out["completed"] == len(arrivals)
        _BASELINES[key] = out["streams"]
    return _BASELINES[key]


# -- the tentpole: mid-flight arrivals, zero prefill recomputation -----------

def test_streamed_arrivals_complete_with_zero_prefill_recompute():
    with _plan().compile() as session:
        out = session.serve_stream(
            trace=[{"at_round": r} for r in ARRIVALS], **_kwargs())
        assert session.lint() == []          # live gateway graph is clean
    n, gen = len(ARRIVALS), 4
    assert out["completed"] == n
    assert out["cancelled"] == out["expired"] == out["failed"] == 0
    # every stream: the prefill token plus gen_len decoded tokens
    assert sorted(out["streams"]) == [f"r{i}" for i in range(n)]
    assert all(len(s) == gen + 1 for s in out["streams"].values())
    assert out["tokens"] == n * gen

    # the paged-cache contract: every slot join loaded pages; the prefill
    # recompute fallback never ran; everything was reclaimed
    serve = out["runtime_stats"]["serve"]
    assert serve["refills"] == serve["page_hits"] == n
    assert serve.get("prefill_recompute", 0) == 0
    cache = out["cache"]
    assert cache["cache_puts"] == cache["cache_hits"] == n
    assert cache["pages_live"] == 0 and cache["cache_entries"] == 0
    assert cache["page_allocs"] == cache["page_frees"]

    # staggered arrivals mean epochs were cut mid-run, not one big wave
    assert out["epochs"] >= 2
    names = set(out["nodes"])
    for i in range(n):
        assert {f"stack:r{i}", f"prefill:r{i}", f"finish:r{i}",
                f"request:r{i}"} <= names
    assert "refill:e0" in names and "decode:e0:t0" in names

    # latency histograms: every phase observed, counts match the run
    hist = out["runtime_stats"]["request_latency_hist"]
    assert hist["edges_s"] and len(hist["labels"]) == len(hist["edges_s"]) + 1
    counts = hist["counts"]
    assert sum(counts["queue_wait"]) == n
    assert sum(counts["prefill"]) == n
    assert sum(counts["total"]) == n
    assert sum(counts["decode_token"]) == n * gen

    # padded-slot accounting: real + padded covers every (round, slot)
    assert serve["real_tokens"] == n * gen
    assert serve["real_tokens"] + serve["padded_slot_tokens"] \
        == out["rounds"] * 2
    assert out["padded_tokens"] == serve["padded_slot_tokens"]


def test_gateway_trace_builder_matches_live_run():
    """phylint's static mirror (analysis.gateway_trace) and the live
    gateway build the same tree: same names, lanes and edges."""
    from repro.analysis import gateway_trace

    out = _stream([{"at_round": r} for r in ARRIVALS])
    sig = out["trace"]
    live = {(name, lane, tuple(sig[d][0] for d in deps))
            for name, lane, deps in sig}
    g = gateway_trace(_plan(), requests=len(ARRIVALS), gen_len=4, slots=2,
                      arrivals=list(ARRIVALS))
    mirror = {(n.name, n.lane, tuple(g.nodes[d].name for d in n.deps))
              for n in g.nodes}
    assert live == mirror


# -- fault injection ---------------------------------------------------------

def test_cancel_mid_decode_reclaims_slot_and_preserves_survivors():
    base = _baseline_streams(ARRIVALS)
    trace = [{"at_round": r} for r in ARRIVALS]
    trace[1]["cancel_after"] = 2             # r1: cancel after 2 tokens
    out = _stream(trace)
    assert out["cancelled"] == 1 and out["completed"] == len(ARRIVALS) - 1
    h = next(h for h in out["handles"] if h.rid == "r1")
    assert h.status == "cancelled"
    with pytest.raises(CancelledError):
        h.result(timeout=5)
    assert len(h.tokens) == 1 + 2            # prefill + the 2 decoded
    assert out["streams"]["r1"] == base["r1"][:3]   # a prefix, not junk
    for rid, stream in base.items():         # survivors are bit-identical
        if rid != "r1":
            assert out["streams"][rid] == stream
    assert out["cache"]["pages_live"] == 0
    assert out["cache"]["cache_entries"] == 0


def test_deadline_expiry_while_waiting_for_a_slot():
    """slots=1: r0 monopolizes the slot; r1 is admitted (prefill runs,
    pages park) but its deadline lapses before a slot frees - it must
    expire cleanly with its pages reclaimed, and r0 is untouched."""
    kw = dict(gen_len=8, slots=1)
    base = _baseline_streams((0,), **kw)
    out = _stream([{"at_round": 0}, {"at_round": 0, "deadline_ms": 50}],
                  **kw)
    assert out["completed"] == 1 and out["expired"] == 1
    h = next(h for h in out["handles"] if h.rid == "r1")
    assert h.status == "expired"
    with pytest.raises(DeadlineExpired):
        h.result(timeout=5)
    assert out["streams"]["r0"] == base["r0"]
    assert out["cache"]["pages_live"] == 0
    assert out["cache"]["cache_entries"] == 0
    assert out["runtime_stats"]["serve"].get("prefill_recompute", 0) == 0


def test_poisoned_prefill_is_contained_to_its_chain():
    base = _baseline_streams(ARRIVALS)
    trace = [{"at_round": r} for r in ARRIVALS]
    trace[2]["inject"] = "poison-prefill"
    out = _stream(trace)
    assert out["failed"] == 1 and out["completed"] == len(ARRIVALS) - 1
    h = next(h for h in out["handles"] if h.rid == "r2")
    assert h.status == "failed"
    with pytest.raises(RuntimeError, match="injected prefill poison"):
        h.result(timeout=5)
    assert h.tokens == []                    # never reached a slot
    for rid, stream in base.items():         # the poison never crossed
        if rid != "r2":                      # into the shared decode chain
            assert out["streams"][rid] == stream
    assert out["cache"]["pages_live"] == 0


def test_fault_battery_drains_cleanly_under_sanitizer():
    """All three faults in one run with the concurrency sanitizer armed:
    the gateway must drain without a deadlock diagnostic (every promise
    is producer-backed and resolved, even for killed chains)."""
    trace = [{"at_round": r} for r in ARRIVALS]
    trace[1]["cancel_after"] = 1
    trace[2]["inject"] = "poison-prefill"
    trace[4]["deadline_ms"] = 0.0            # expires before admission
    with sanitize.enabled():
        out = _stream(trace)
        assert sanitize.get().diagnostics() == []
    assert out["completed"] == 2
    assert out["cancelled"] == out["expired"] == out["failed"] == 1
    assert out["cache"]["pages_live"] == 0
    statuses = {h.rid: h.status for h in out["handles"]}
    assert statuses == {"r0": "done", "r1": "cancelled", "r2": "failed",
                        "r3": "done", "r4": "expired"}


# -- the live side: threads, admission, rejection ----------------------------

def test_live_queue_submissions_from_another_thread():
    with _plan().compile() as session:
        rng = np.random.default_rng(11)
        prompts = [rng.integers(0, session.cfg.vocab, 16).astype(np.int32)
                   for _ in range(3)]
        q = RequestQueue()

        def feeder():
            for p in prompts:
                q.submit(p)
                time.sleep(0.02)
            q.close()

        t = threading.Thread(target=feeder)
        t.start()
        out = session.serve_stream(queue=q, **_kwargs())
        t.join()
    assert out["completed"] == 3
    assert all(h.result(timeout=5) == out["streams"][h.rid]
               for h in out["handles"])


def test_request_queue_backlog_and_close_reject():
    q = RequestQueue(max_queue=1)
    ok = q.submit([1, 2])
    full = q.submit([3, 4])
    assert ok.status == "queued" and full.status == "rejected"
    with pytest.raises(RequestRejected, match="capacity"):
        full.result(timeout=1)
    q.close()
    late = q.submit([5, 6])
    assert late.status == "rejected"
    with pytest.raises(RequestRejected, match="closed"):
        late.result(timeout=1)
    assert q.submitted == 1 and q.rejected == 2


def test_wave_serve_accounts_padded_slot_compute():
    """``Session.serve`` pads idle slots into every wave; the padded-slot
    compute must be accounted separately, never folded into tokens."""
    with _plan().compile() as session:
        out = session.serve(requests=3, slots=2, prompt_len=16, gen_len=4,
                            verbose=False)
        serve = session.runtime.stats().serve
    assert out["tokens"] == 3 * 4            # only real requests
    assert out["padded_tokens"] == 1 * 4     # wave 1 ran a padded slot
    assert serve["real_tokens"] == 12
    assert serve["padded_slot_tokens"] == 4


# -- multi-replica routing (DESIGN.md §15) -----------------------------------

def test_two_replica_streams_bit_identical_to_single_replica():
    """The tentpole contract: N-replica streams are bit-identical per
    request to the 1-replica gateway, with zero cross-replica page
    traffic and zero prefill recompute in steady state."""
    base = _baseline_streams(ARRIVALS)
    out = _stream([{"at_round": r} for r in ARRIVALS], replicas=2)
    assert out["completed"] == len(ARRIVALS)
    assert out["replicas"] == 2
    assert out["streams"] == base

    serve = out["runtime_stats"]["serve"]
    assert serve.get("cross_replica_page_fetches", 0) == 0
    assert serve.get("prefill_recompute", 0) == 0
    assert serve["refills"] == serve["page_hits"] == len(ARRIVALS)

    # the router spread work: both replicas admitted and refilled, and
    # the per-replica counter split covers the flat totals
    per = out["runtime_stats"]["serve_replicas"]
    assert sorted(per) == ["0", "1"]
    assert all(per[k]["refills"] > 0 for k in per)
    assert sum(per[k]["refills"] for k in per) == serve["refills"]
    assigned = out["replica_assignments"]
    assert sorted(assigned) == [f"r{i}" for i in range(len(ARRIVALS))]
    assert set(assigned.values()) == {0, 1}

    # page hygiene across both named caches over the shared pool
    cache = out["cache"]
    assert cache["cache_transfers_in"] == cache["cache_transfers_out"] == 0
    assert cache["pages_live"] == 0 and cache["cache_entries"] == 0
    assert cache["page_allocs"] == cache["page_frees"]

    # namespaced decode chains for both replicas coexist in one graph
    names = set(out["nodes"])
    assert "refill:R0:e0" in names and "refill:R1:e0" in names
    assert not any(n.startswith(("refill:e", "decode:e")) for n in names)


def test_replica_trace_builder_matches_live_run():
    """The static mirror replays the live ReplicaRouter, so the 2-replica
    tree matches the live run node for node (phylint's gate)."""
    from repro.analysis import gateway_trace

    out = _stream([{"at_round": r} for r in ARRIVALS], replicas=2)
    sig = out["trace"]
    live = {(name, lane, tuple(sig[d][0] for d in deps))
            for name, lane, deps in sig}
    g = gateway_trace(_plan(), requests=len(ARRIVALS), gen_len=4, slots=2,
                      arrivals=list(ARRIVALS), replicas=2)
    mirror = {(n.name, n.lane, tuple(g.nodes[d].name for d in n.deps))
              for n in g.nodes}
    assert live == mirror


def test_kill_replica_drill_completes_on_survivor():
    """Replica-death rebalance: kill replica 0 at round 2; the survivor
    adopts its pages (a counted cross-replica fetch, never a prefill
    recompute) and completes every request with bit-identical streams."""
    base = _baseline_streams(ARRIVALS)
    with sanitize.enabled():
        out = _stream([{"at_round": r} for r in ARRIVALS], replicas=2,
                      kill_replica_at_round=(0, 2))
        assert sanitize.get().diagnostics() == []
    assert out["completed"] == len(ARRIVALS)
    assert out["cancelled"] == out["expired"] == out["failed"] == 0
    assert out["streams"] == base

    serve = out["runtime_stats"]["serve"]
    assert serve["replica_deaths"] == 1
    assert serve["replica_migrations"] >= 1
    assert serve["cross_replica_page_fetches"] >= 1
    assert serve.get("prefill_recompute", 0) == 0
    # everything ends routed to the survivor; no pages leak either side
    assert set(out["replica_assignments"].values()) == {1}
    cache = out["cache"]
    assert cache["cache_transfers_in"] == cache["cache_transfers_out"] \
        == serve["cross_replica_page_fetches"]
    assert cache["pages_live"] == 0 and cache["cache_entries"] == 0
    assert cache["page_allocs"] == cache["page_frees"]


def test_kill_last_replica_revives_on_driver():
    """Killing the only replica must not strand the queue: the gateway
    revives it (re-homed on the driver) and completes everything."""
    base = _baseline_streams(ARRIVALS)
    out = _stream([{"at_round": r} for r in ARRIVALS], replicas=1,
                  kill_replica_at_round=(0, 2))
    assert out["completed"] == len(ARRIVALS)
    assert out["streams"] == base
    serve = out["runtime_stats"]["serve"]
    assert serve["replica_deaths"] == 1 and serve["replica_revivals"] == 1
    assert serve.get("prefill_recompute", 0) == 0
    assert out["cache"]["pages_live"] == 0


# -- gateway bugfix sweep: CV wake, submit/close race ------------------------

def test_idle_gateway_wakes_on_submit_without_polling_latency():
    """The idle gateway parks on the queue condition variable (no more
    20 Hz poll): a submission to an idle gateway reaches prefill fast, so
    the queue_wait p50 lands strictly below the 10 ms bucket where the
    old 0-50 ms poll jitter used to put it."""
    with _plan().compile() as session:
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, session.cfg.vocab, 16).astype(np.int32)
                   for _ in range(6)]
        q = RequestQueue()

        def feeder():
            # warm-up: first request compiles prefill/decode while the
            # clock is NOT running against later arrivals
            q.submit(prompts[0]).result(timeout=120)
            for p in prompts[1:]:
                time.sleep(0.03)        # gateway is idle-parked each time
                q.submit(p)
            q.close()

        t = threading.Thread(target=feeder)
        t.start()
        out = session.serve_stream(queue=q, **_kwargs(gen_len=2))
        t.join()
    assert out["completed"] == len(prompts)
    hist = out["runtime_stats"]["request_latency_hist"]
    counts = hist["counts"]["queue_wait"]
    total = sum(counts)
    assert total == len(prompts)
    # p50 bucket index: first bucket where the cumulative count crosses
    # half the samples.  Buckets 0..2 are <100us, <1ms, <10ms.
    acc, p50_bucket = 0, len(counts) - 1
    for i, c in enumerate(counts):
        acc += c
        if acc * 2 >= total:
            p50_bucket = i
            break
    assert p50_bucket <= 2, (
        f"queue_wait p50 in bucket {hist['labels'][p50_bucket]} - the CV "
        f"wake regressed to polling latency ({counts})")


def test_submit_racing_close_is_atomic_at_the_queue():
    """Hammer submit() from many threads while close() lands: every
    handle is either queued-before-close (drainable) or deterministically
    rejected - never enqueued into a closed queue, never stranded."""
    for trial in range(25):
        q = RequestQueue()
        handles: list = []
        lock = threading.Lock()
        start = threading.Barrier(5)

        def submitter():
            start.wait()
            for _ in range(20):
                h = q.submit([1, 2, 3])
                with lock:
                    handles.append(h)

        threads = [threading.Thread(target=submitter) for _ in range(4)]
        for t in threads:
            t.start()
        start.wait()
        q.close()
        for t in threads:
            t.join()
        taken = q.take_ready(10**9)
        assert q.drained()
        rejected = [h for h in handles if h.status == "rejected"]
        queued = [h for h in handles if h.status == "queued"]
        # exhaustive: nothing in any third state, nothing left behind
        assert len(rejected) + len(queued) == len(handles)
        assert sorted(h.rid for h in queued) == sorted(h.rid for h in taken)
        assert q.submitted == len(queued) and q.rejected == len(rejected)
        for h in rejected:                   # terminal, not stranded
            assert h.done()
            with pytest.raises(RequestRejected, match="closed|capacity"):
                h.result(timeout=1)


def test_gateway_resolves_every_handle_when_close_races_submit():
    """End to end: a feeder hammers submissions while close() races in;
    the gateway must leave every returned handle terminal (served or
    rejected), with served + rejected == submitted attempts."""
    with _plan().compile() as session:
        rng = np.random.default_rng(13)
        prompts = [rng.integers(0, session.cfg.vocab, 16).astype(np.int32)
                   for _ in range(16)]
        q = RequestQueue()
        handles: list = []

        def feeder():
            for i, p in enumerate(prompts):
                handles.append(q.submit(p))
                time.sleep(0.01)

        def closer():
            time.sleep(0.06)             # lands mid-feed: some submits
            q.close()                    # race the close and must reject

        tf, tc = threading.Thread(target=feeder), \
            threading.Thread(target=closer)
        tf.start(), tc.start()
        out = session.serve_stream(queue=q, **_kwargs(gen_len=2))
        tf.join(), tc.join()
    for h in handles:
        assert h.done(), f"{h.rid} stranded in {h.status!r}"
        assert h.status in ("done", "rejected")
    served = sum(1 for h in handles if h.status == "done")
    rejected = sum(1 for h in handles if h.status == "rejected")
    assert served + rejected == len(prompts)
    assert out["completed"] == served and out["rejected"] == rejected
    assert out["cache"]["pages_live"] == 0


# -- multiproc tier: locality parity + kill drill ----------------------------

@pytest.mark.multiproc
def test_two_locality_gateway_streams_match_single_process():
    trace = [{"at_round": r} for r in ARRIVALS]
    with _plan(localities=2).compile() as multi:
        out2 = multi.serve_stream(trace=trace, **_kwargs())
    assert out2["completed"] == len(ARRIVALS)
    assert out2["cache"]["pages_live"] == 0
    base = _baseline_streams(ARRIVALS)       # 1-process, same script
    assert out2["streams"] == base


@pytest.mark.multiproc
def test_kill_locality_mid_stream_completes_survivors():
    """SIGKILL a worker while the gateway is streaming: its in-flight
    stack tasks re-spawn, requests submitted after the kill still
    complete, and every stream matches the 1-process run."""
    kw = dict(gen_len=6)
    with _plan(localities=2).compile() as session:
        rng = np.random.default_rng(23)
        prompts = [rng.integers(0, session.cfg.vocab, 16).astype(np.int32)
                   for _ in range(6)]
        q = RequestQueue()
        killed = {}

        def feeder():
            for i, p in enumerate(prompts):
                if i == 3:
                    killed["rank"] = session.kill_locality()
                q.submit(p)
                time.sleep(0.05)
            q.close()

        t = threading.Thread(target=feeder)
        t.start()
        out = session.serve_stream(queue=q, **_kwargs(**kw))
        t.join()
    assert killed["rank"] is not None
    assert out["completed"] == len(prompts)
    assert out["cache"]["pages_live"] == 0
    base = _stream([{"prompt": p} for p in prompts], **kw)
    assert out["streams"] == base["streams"]


@pytest.mark.multiproc
def test_two_locality_two_replica_streams_match_single_process():
    """2 replicas homed on 2 localities (replica 0 on the worker,
    replica 1 on the driver): streams match the 1-process 1-replica run
    and steady state never crosses replica page boundaries."""
    trace = [{"at_round": r} for r in ARRIVALS]
    with _plan(localities=2, replicas=2).compile() as multi:
        out2 = multi.serve_stream(trace=trace, **_kwargs())
    assert out2["completed"] == len(ARRIVALS)
    assert out2["replicas"] == 2
    assert out2["cache"]["pages_live"] == 0
    serve = out2["runtime_stats"]["serve"]
    assert serve.get("cross_replica_page_fetches", 0) == 0
    assert serve.get("prefill_recompute", 0) == 0
    assert out2["streams"] == _baseline_streams(ARRIVALS)


@pytest.mark.multiproc
def test_kill_locality_retires_its_replica_and_survivor_absorbs():
    """SIGKILL the worker locality hosting replica 0 mid-stream: the
    liveness sweep retires that replica, the driver-homed survivor
    adopts its pages and every request completes bit-identically."""
    with _plan(localities=2, replicas=2).compile() as session:
        rng = np.random.default_rng(31)
        prompts = [rng.integers(0, session.cfg.vocab, 16).astype(np.int32)
                   for _ in range(6)]
        q = RequestQueue()
        killed = {}

        def feeder():
            for i, p in enumerate(prompts):
                if i == 3:
                    killed["rank"] = session.kill_locality()
                q.submit(p)
                time.sleep(0.05)
            q.close()

        t = threading.Thread(target=feeder)
        t.start()
        out = session.serve_stream(queue=q, **_kwargs())
        t.join()
    assert killed["rank"] is not None
    assert out["completed"] == len(prompts)
    serve = out["runtime_stats"]["serve"]
    assert serve["replica_deaths"] == 1
    assert serve.get("prefill_recompute", 0) == 0
    assert out["cache"]["pages_live"] == 0
    base = _stream([{"prompt": p} for p in prompts])
    assert out["streams"] == base["streams"]
