"""Multi-host SPMD checkpointing drills (DESIGN.md §10), run as REAL
``jax.distributed`` worlds in subprocesses (the coordination service
must initialize before any jax backend use, so these cannot share the
pytest process's jax).

The acceptance story:
  * a 2-process SPMD run writes each host's ADDRESSABLE shards only -
    the manifest's ownership map covers both ranks, leaves are split
    into device-shard segments, and the byte load is balanced;
  * the messaging-layer counter proves ZERO checkpoint leaf bytes
    crossed the wire (host-copy mode ships them; SPMD mode must not);
  * a host loss mid-run (the injected failure after a committed save)
    leaves the committed checkpoint as latest, and an N=2 -> M=1
    ``--resume`` continues with a final loss BIT-IDENTICAL to an
    uninterrupted single-process run.
"""
import json
import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.spmd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
BASE = ["--arch", "qwen2.5-3b", "--batch", "4", "--seq", "16",
        "--log-every", "4"]


def _train(extra, *, check=True, timeout=360):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run(
        [sys.executable, "-u", "-m", "repro.launch.train"] + BASE + extra,
        env=env, text=True, capture_output=True, timeout=timeout)
    if check and p.returncode != 0:
        raise AssertionError(
            f"train {extra} failed ({p.returncode}):\n{p.stdout[-2000:]}"
            f"\n{p.stderr[-2000:]}")
    return p


def _final_loss(out: str) -> str:
    return re.findall(r"final loss ([0-9.]+)", out)[-1]


def test_spmd_save_is_addressable_shards_with_zero_leaf_wire_bytes(tmp_path):
    ck = str(tmp_path / "ck")
    p = _train(["--localities", "2", "--spmd", "--steps", "4",
                "--ckpt", ck, "--ckpt-every", "4"])
    m = json.loads(
        (Path(ck) / "step_00000004" / "manifest.json").read_text())
    # both hosts wrote - and wrote only their own shard
    assert set(m["ownership"]) == {"0", "1"}
    assert m["ownership"] == {"0": [0], "1": [1]}
    # leaves really were split into device-shard segments, ~half each
    sliced = [leaf for s in m["shards"] for leaf in s["leaves"]
              if "slice" in leaf]
    assert sliced, "no device-shard segments: SPMD split did not happen"
    nbytes = [s["nbytes"] for s in m["shards"]]
    assert min(nbytes) > 0.4 * max(nbytes)       # balanced byte load
    # the PR 3 messaging counters: zero checkpoint leaf bytes shipped
    assert "ckpt-leaf-wire 0B" in p.stdout


def test_spmd_host_loss_then_2_to_1_restore_is_bit_identical(tmp_path):
    """save -> lose a process -> restore into 1 process.  The injected
    failure kills the run AFTER the step-4 save committed (an SPMD
    world does not survive host loss; recovery is restart-from-
    checkpoint with any process count)."""
    ck = str(tmp_path / "ck")
    p = _train(["--localities", "2", "--spmd", "--steps", "8",
                "--ckpt", ck, "--ckpt-every", "4", "--fail-at-step", "6"],
               check=False)
    assert p.returncode != 0
    assert "injected node failure" in p.stdout + p.stderr
    steps = sorted(d.name for d in Path(ck).glob("step_*"))
    assert steps == ["step_00000004"]             # committed, nothing torn
    resumed = _train(["--steps", "8", "--resume", "--ckpt", ck])
    assert "resumed from step 4" in resumed.stdout
    ref = _train(["--steps", "8"])
    assert _final_loss(resumed.stdout) == _final_loss(ref.stdout)
