import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))   # tests/_property_fallback

import pytest  # noqa: E402

# Test tiers (split the CI matrix; `-m fast` is the single-process tier):
#   fast      - everything single-process (the default, applied here)
#   multiproc - drives 2-3 real worker processes over TCP active messages
#   spmd      - multi-process jax.distributed drills (subprocess-spawned)
TIERS = ("fast", "multiproc", "spmd")

# file -> tier for suites whose every test belongs to one tier; files can
# also mark themselves (tests/test_spmd.py sets `pytestmark`)
_FILE_TIERS = {"test_distrib.py": "multiproc",
               "test_elastic.py": "multiproc"}


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "fast: single-process tier-1 tests (default tier)")
    config.addinivalue_line(
        "markers",
        "multiproc: drives 2-3 real worker processes (TCP active messages)")
    config.addinivalue_line(
        "markers",
        "spmd: multi-process jax.distributed drills (subprocess-spawned)")


def pytest_collection_modifyitems(config, items):
    for item in items:
        tier = _FILE_TIERS.get(os.path.basename(str(item.fspath)))
        if tier is not None:
            item.add_marker(getattr(pytest.mark, tier))
        if not any(item.get_closest_marker(t) for t in TIERS[1:]):
            item.add_marker(pytest.mark.fast)
