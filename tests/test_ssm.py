"""Chunked SSM algorithms vs their exact sequential recurrences."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sharding import init_params
from repro.models import ssm


class _Cfg:
    d_model = 64
    expand = 2
    ssm_head_dim = 16
    ssm_state = 8
    ssm_groups = 1
    ssm_d_conv = 4
    n_heads = 4


def _roll_decode(step_fn, init_state, x, p, cfg):
    """Run the single-token step over a sequence."""
    B, L, D = x.shape
    state = init_state
    outs = []
    for t in range(L):
        y, state = step_fn(x[:, t:t + 1], state, p, cfg)
        outs.append(y)
    return jnp.concatenate(outs, axis=1), state


def test_mamba2_chunked_equals_stepwise():
    cfg = _Cfg()
    p = init_params(ssm.mamba2_specs(cfg.d_model, expand=cfg.expand,
                                     head_dim=cfg.ssm_head_dim,
                                     state=cfg.ssm_state),
                    jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.5
    y_par, st_par = ssm.mamba2_chunked(x, p, cfg, chunk=16, return_state=True)
    y_seq, st_seq = _roll_decode(ssm.mamba2_step,
                                 ssm.mamba2_init_state(2, cfg), x, p, cfg)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_par["ssm"]),
                               np.asarray(st_seq["ssm"]), rtol=2e-3,
                               atol=2e-4)


def test_mamba2_chunk_size_invariance():
    cfg = _Cfg()
    p = init_params(ssm.mamba2_specs(cfg.d_model, expand=cfg.expand,
                                     head_dim=cfg.ssm_head_dim,
                                     state=cfg.ssm_state),
                    jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 96, cfg.d_model)) * 0.5
    y1 = ssm.mamba2_chunked(x, p, cfg, chunk=8)
    y2 = ssm.mamba2_chunked(x, p, cfg, chunk=32)
    y3 = ssm.mamba2_chunked(x, p, cfg, chunk=96)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), rtol=2e-4,
                               atol=2e-5)


def test_mlstm_chunked_equals_stepwise():
    cfg = _Cfg()
    p = init_params(ssm.mlstm_specs(cfg.d_model, n_heads=cfg.n_heads,
                                    expand=cfg.expand),
                    jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 48, cfg.d_model)) * 0.5
    y_par = ssm.mlstm_chunked(x, p, cfg, chunk=12)
    y_seq, _ = _roll_decode(ssm.mlstm_step, ssm.mlstm_init_state(2, cfg),
                            x, p, cfg)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=3e-3, atol=3e-4)


def test_mlstm_state_carries_across_prefill_decode():
    cfg = _Cfg()
    p = init_params(ssm.mlstm_specs(cfg.d_model, n_heads=cfg.n_heads,
                                    expand=cfg.expand),
                    jax.random.PRNGKey(6))
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 33, cfg.d_model)) * 0.5
    # full stepwise
    y_all, _ = _roll_decode(ssm.mlstm_step, ssm.mlstm_init_state(1, cfg),
                            x, p, cfg)
    # chunked prefill on first 32, then one decode step
    _, st = ssm.mlstm_chunked(x[:, :32], p, cfg, chunk=16, return_state=True)
    y_last, _ = ssm.mlstm_step(x[:, 32:33], st, p, cfg)
    np.testing.assert_allclose(np.asarray(y_last[:, 0]),
                               np.asarray(y_all[:, -1]), rtol=3e-3,
                               atol=3e-4)


def test_slstm_apply_equals_stepwise():
    cfg = _Cfg()
    p = init_params(ssm.slstm_specs(cfg.d_model, n_heads=cfg.n_heads),
                    jax.random.PRNGKey(8))
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 20, cfg.d_model)) * 0.5
    y_par = ssm.slstm_apply(x, p, cfg)
    y_seq, _ = _roll_decode(ssm.slstm_step, ssm.slstm_init_state(2, cfg),
                            x, p, cfg)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-5)


def test_causal_conv_matches_cache_mode():
    w = jax.random.normal(jax.random.PRNGKey(0), (4, 6)) * 0.3
    b = jnp.zeros(6)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 6))
    full, _ = ssm.causal_conv1d(x, w, b)
    cache = jnp.zeros((2, 3, 6))
    ys = []
    for t in range(12):
        y, cache = ssm.causal_conv1d(x[:, t:t + 1], w, b, cache=cache)
        ys.append(y)
    step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), rtol=1e-5,
                               atol=1e-6)


def test_mamba2_decay_is_stable_long_sequence():
    cfg = _Cfg()
    p = init_params(ssm.mamba2_specs(cfg.d_model, expand=cfg.expand,
                                     head_dim=cfg.ssm_head_dim,
                                     state=cfg.ssm_state),
                    jax.random.PRNGKey(10))
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 512, cfg.d_model))
    y = ssm.mamba2_chunked(x, p, cfg, chunk=64)
    assert bool(jnp.isfinite(y).all())
