"""Attention correctness: chunked (flash-shape) vs full oracle, decode path,
cache updates, GQA/windows/offsets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention


@pytest.mark.parametrize("B,S,H,KV,hd,window,causal", [
    (2, 128, 4, 2, 32, None, True),
    (1, 256, 8, 8, 16, None, True),
    (2, 192, 4, 1, 32, None, True),
    (1, 256, 2, 2, 64, 64, True),
    (2, 128, 4, 4, 32, None, False),
])
def test_chunked_matches_full(B, S, H, KV, hd, window, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    full = attention.attend_full(q, k, v, causal=causal, window=window)
    for qc, kc in [(64, 64), (32, 64), (128, 32)]:
        ch = attention.attend_chunked(q, k, v, causal=causal, window=window,
                                      q_chunk=qc, kv_chunk=kc)
        np.testing.assert_allclose(np.asarray(ch), np.asarray(full),
                                   rtol=1e-4, atol=1e-5)


def test_chunked_gradients_match_full():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))

    def loss_full(q):
        return jnp.sum(attention.attend_full(q, k, v) ** 2)

    def loss_chunk(q):
        return jnp.sum(attention.attend_chunked(q, k, v, q_chunk=16,
                                                kv_chunk=16) ** 2)
    g1 = jax.grad(loss_full)(q)
    g2 = jax.grad(loss_chunk)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3,
                               atol=1e-4)


def test_decode_attend_matches_full_row():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, H, KV, hd = 2, 40, 4, 2, 16
    q_all = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    full = attention.attend_full(q_all, k, v, causal=True)
    pos = S - 1
    # cache longer than S: slots after pos must be masked out
    pad = 8
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    one = attention.decode_attend(q_all[:, -1:], kc, vc, pos)
    np.testing.assert_allclose(np.asarray(one[:, 0]),
                               np.asarray(full[:, -1]), rtol=1e-4, atol=1e-5)


def test_cache_update_writes_position():
    B, S, KV, hd = 2, 16, 2, 8
    kc = jnp.zeros((B, S, KV, hd))
    vc = jnp.zeros((B, S, KV, hd))
    k_new = jnp.ones((B, 1, KV, hd))
    v_new = 2 * jnp.ones((B, 1, KV, hd))
    kc2, vc2 = attention.cache_update(kc, vc, k_new, v_new, 5)
    assert float(kc2[0, 5].sum()) == KV * hd
    assert float(vc2[0, 5].sum()) == 2 * KV * hd
    assert float(kc2.sum()) == B * KV * hd  # only one row written


def test_fully_masked_rows_are_finite():
    # sliding window smaller than chunk: early rows see nothing in later blocks
    q = jnp.ones((1, 64, 2, 8))
    k = jnp.ones((1, 64, 2, 8))
    v = jnp.ones((1, 64, 2, 8))
    out = attention.attend_chunked(q, k, v, causal=True, window=4,
                                   q_chunk=16, kv_chunk=16)
    assert bool(jnp.isfinite(out).all())


def test_rope_rotation_properties():
    from repro.models import layers
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    r = layers.apply_rope(x, pos)
    # norm preserved per pair
    n1 = jnp.linalg.norm(x, axis=-1)
    n2 = jnp.linalg.norm(r, axis=-1)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), rtol=1e-5)
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(r[:, 0]), np.asarray(x[:, 0]),
                               rtol=1e-6)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def dot_at(m, n):
        qq = layers.apply_rope(q, jnp.array([[m]]))
        kk = layers.apply_rope(k, jnp.array([[n]]))
        return float(jnp.sum(qq * kk))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4
