"""End-to-end behaviour of the system (deliverable c, integration tier):
training convergence, the paper's CNN, serving, TiledArray metadata."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import steps as steps_lib
from repro.core.sharding import default_rules, init_params
from repro.data.pipeline import HARStream, LMStream
from repro.launch.mesh import make_local_mesh
from repro.models import cnn


def test_tiny_lm_learns_the_bigram_stream():
    from repro.optim.optimizers import OptConfig
    cfg = get_config("qwen2.5-3b", tiny=True)
    mesh = make_local_mesh()
    shape = {"seq_len": 64, "global_batch": 8, "kind": "train"}
    strat = steps_lib.Strategy(opt=OptConfig(lr=1e-3))
    step = steps_lib.make_train_step(cfg, mesh, strat, shape)
    stream = LMStream(vocab=64, batch=8, seq=64, seed=0)  # 64-token bigram
    params, opt = step.init(jax.random.PRNGKey(0))
    losses = []
    for it in range(40):
        b = stream.batch_at(it)
        metrics, params, opt = step.fn(params, opt, b)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.5, losses  # clear learning signal


def test_har_cnn_trains_on_paper_task():
    """The paper's own benchmark model (Fig. 1) trains on HAR windows."""
    specs = cnn.har_cnn_specs()
    params = init_params(specs, jax.random.PRNGKey(0))
    stream = HARStream(batch=32, seed=0)
    opt_lr = 1e-2

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(cnn.har_cnn_loss)(params, batch)
        params = jax.tree.map(lambda p, g: p - opt_lr * g, params, grads)
        return loss, params

    losses = []
    for it in range(100):
        loss, params = step(params, stream.batch_at(it))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.6
    # accuracy above chance on fresh data
    b = stream.batch_at(999)
    acc = float((jnp.argmax(cnn.har_cnn_forward(params, b["x"]), -1)
                 == b["y"]).mean())
    assert acc > 1.0 / 6 + 0.03


def test_serve_driver_generates_tokens():
    from repro.launch import serve as serve_mod
    args = serve_mod.parser().parse_args(
        ["--arch", "qwen2.5-3b", "--requests", "4", "--slots", "2",
         "--prompt-len", "16", "--gen-len", "4"])
    out = serve_mod.run(args)
    assert out["tokens_per_s"] > 0


def test_prefill_then_decode_loop_consistent_with_apply():
    """Greedy continuation from prefill+decode equals greedy from repeated
    full forward (same tokens chosen)."""
    from repro.models.model import build_model
    cfg = get_config("qwen3-4b", tiny=True)
    m = build_model(cfg)
    params = init_params(m.specs(), jax.random.PRNGKey(3))
    B, S, G = 1, 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    # reference greedy: recompute full forward each step
    ref_seq = toks
    for _ in range(G):
        lg, _ = m.apply(params, {"tokens": ref_seq})
        nxt = jnp.argmax(lg[:, -1], -1)[:, None]
        ref_seq = jnp.concatenate([ref_seq, nxt.astype(jnp.int32)], 1)
    # cached greedy
    lg0, cache = m.prefill(params, {"tokens": toks}, S + G)
    cur = jnp.argmax(lg0, -1)[:, None].astype(jnp.int32)
    got = [cur]
    for t in range(G - 1):
        lg, cache = m.decode_step(params, cache, {"tokens": cur},
                                  jnp.int32(S + t))
        cur = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        got.append(cur)
    got_seq = jnp.concatenate(got, 1)
    np.testing.assert_array_equal(np.asarray(got_seq),
                                  np.asarray(ref_seq[:, S:]))


def test_tiled_array_metadata_and_retile():
    from repro.core.dist_array import TiledArray
    mesh = make_local_mesh()
    rules = default_rules()
    x = jnp.arange(64.0).reshape(8, 8)
    t = TiledArray.tile(x, ("batch", "embed"), mesh, rules)
    assert t.global_shape == (8, 8)
    assert t.tile_shape() == (8, 8)          # 1 device -> full tile
    r = t.replicated()
    np.testing.assert_array_equal(np.asarray(r.data), np.asarray(x))
    r2 = t.retile(default_rules(sequence_parallel=True))
    assert r2.global_shape == (8, 8)
