"""HPX-style software resilience: replay, replicate+consensus, checksums,
straggler policy (paper R9 / §4.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.resilience import (ResilienceError, ResilientRunner,
                                   StragglerPolicy, finite_check,
                                   tree_checksum)


class Flaky:
    """Injects corruption on the first n calls (the fault_hook seam)."""

    def __init__(self, n_bad: int, kind: str = "nan"):
        self.n_bad = n_bad
        self.calls = 0
        self.kind = kind

    def __call__(self, out):
        self.calls += 1
        if self.calls <= self.n_bad:
            if self.kind == "nan":
                return {"y": out["y"] * jnp.nan}
            return {"y": out["y"] + 1.0}   # silent bit-flip style corruption
        return out


def _step(x):
    return {"y": x * 2.0}


def test_replay_recovers_from_transient_corruption():
    r = ResilientRunner(_step, fault_hook=Flaky(2))
    out = r.replay(jnp.ones(3), max_retries=3)
    np.testing.assert_allclose(np.asarray(out["y"]), 2.0)
    assert r.stats["replays"] == 2


def test_replay_gives_up_on_persistent_corruption():
    r = ResilientRunner(_step, fault_hook=Flaky(100))
    with pytest.raises(ResilienceError):
        r.replay(jnp.ones(3), max_retries=2)


def test_replicate_majority_vote_beats_one_silent_corruption():
    # one corrupted replicate among three: checksum majority picks the pair
    r = ResilientRunner(_step, fault_hook=Flaky(1, kind="flip"))
    out = r.replicate(jnp.ones(3), n=3)
    np.testing.assert_allclose(np.asarray(out["y"]), 2.0)


def test_replicate_falls_back_to_validate():
    # first two replicas are distinct AND invalid (no checksum majority);
    # validate must pick the finite third
    class EachDifferent:
        calls = 0

        def __call__(self, out):
            self.calls += 1
            if self.calls < 3:
                bad = out["y"] * self.calls
                return {"y": bad.at[0].set(jnp.nan)}
            return out
    r = ResilientRunner(_step, fault_hook=EachDifferent())
    out = r.replicate(jnp.ones(3), n=3)
    assert finite_check(out)


def test_consensus_function_is_used():
    r = ResilientRunner(_step,
                        consensus=lambda results: results[-1])
    out = r.replicate(jnp.ones(3), n=2)
    np.testing.assert_allclose(np.asarray(out["y"]), 2.0)


def test_checksum_stable_and_sensitive():
    t = {"a": jnp.arange(4.0)}
    assert tree_checksum(t) == tree_checksum({"a": jnp.arange(4.0)})
    assert tree_checksum(t) != tree_checksum({"a": jnp.arange(4.0) + 1e-7})


def test_straggler_policy_no_sync_cadence():
    p = StragglerPolicy(accumulate_local_steps=4)
    syncs = [p.sync_this_step(i) for i in range(8)]
    assert syncs == [False, False, False, True] * 2
    assert StragglerPolicy().sync_this_step(0)
