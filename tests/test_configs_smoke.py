"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
reduced config of the same family, runs one forward + one train step on CPU
with shape and finiteness assertions.  Full configs are exercised only via
the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, REGISTRY, get_config
from repro.core.sharding import init_params
from repro.models.model import build_model
from repro.core import steps as steps_lib
from repro.launch.mesh import make_local_mesh


def _batch(cfg, B=2, S=32, key=jax.random.PRNGKey(0)):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model)) * 0.1
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, tiny=True)
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    B, S = 2, 32
    logits, aux = model.apply(params, _batch(cfg, B, S))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch, tiny=True)
    mesh = make_local_mesh(data=1, model=1)
    shape = {"seq_len": 32, "global_batch": 2, "kind": "train"}
    step = steps_lib.make_train_step(cfg, mesh, steps_lib.Strategy(), shape)
    params, opt = step.init(jax.random.PRNGKey(0))
    metrics, params2, opt2 = step.fn(params, opt, _batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    before = jax.tree.leaves(step.param_structs())
    moved = jax.tree.leaves(params2)
    assert all(m.shape == s.shape for m, s in zip(moved, before))


@pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-2.7b", "xlstm-350m",
                                  "whisper-medium", "granite-moe-1b-a400m"])
def test_decode_step_matches_full_forward(arch):
    cfg = get_config(arch, tiny=True)
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.PRNGKey(1))
    B, S = 2, 24
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    del batch["labels"]
    full, _ = model.apply(params, batch)
    pf = dict(batch)
    pf["tokens"] = batch["tokens"][:, :S - 1]
    _, cache = model.prefill(params, pf, 32)
    got, _ = model.decode_step(params, cache,
                               {"tokens": batch["tokens"][:, S - 1:]},
                               jnp.int32(S - 1))
    want = full[:, -1].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=2e-2, atol=2e-3)


def test_registry_complete():
    assert len(REGISTRY) == 10
    for name, cfg in REGISTRY.items():
        tot, act = cfg.n_params()
        assert tot > 0 and act > 0 and act <= tot * (1 + 9 / 6 + 1e-6)


def test_param_counts_match_public_sizes():
    # within 20% of the published sizes (embedding/layout conventions vary)
    expect = {"chameleon-34b": 34e9, "phi3.5-moe-42b-a6.6b": 42e9,
              "mistral-nemo-12b": 12e9, "phi3-mini-3.8b": 3.8e9,
              "qwen3-4b": 4e9, "zamba2-2.7b": 2.7e9,
              "whisper-medium": 0.76e9, "granite-moe-1b-a400m": 1.3e9}
    for name, want in expect.items():
        tot, _ = REGISTRY[name].n_params()
        assert abs(tot - want) / want < 0.20, (name, tot, want)
